#!/usr/bin/env python
"""Kernel-substitution analysis: re-price a cell's roofline memory term as
if the validated Pallas kernels (flash_attention, ssd_scan) replaced the
jnp attention/SSD regions.

The dry-run graphs cannot contain Pallas TPU kernels (CPU backend), so the
region-attributed HBM bytes from the analyzer are substituted with each
kernel's true HBM traffic (inputs+outputs only — score blocks, decay masks
and softmax stats are VMEM-resident by construction, see the kernels'
BlockSpecs).  Both numbers are printed so the substitution is transparent.

  python experiments/kernel_substitution.py experiments/dryrun_perf/zamba2-7b__train_4k__pod__ssd_bf16.json
"""

import json
import sys

sys.path.insert(0, "src")

from repro.config import SHAPES, get_arch          # noqa: E402
from repro.roofline.analysis import HW_V5E          # noqa: E402

PASSES = {"train": 3.0, "prefill": 1.0, "decode": 1.0}


def flash_bytes(cfg, shape, n_dev):
    """Global HBM bytes of the flash kernel per step / n_dev."""
    b, s = shape.global_batch, shape.seq_len
    dh = cfg.resolved_head_dim
    if cfg.family == "hybrid":
        layers = cfg.n_layers // max(cfg.attn_every, 1)
    elif cfg.uses_attention:
        layers = cfg.n_layers
    else:
        return 0.0
    qo = 2 * b * s * cfg.n_heads * dh * 2            # q read + o write, bf16
    kv = 2 * b * s * cfg.n_kv_heads * dh * 2
    return (qo + kv) * layers * PASSES[shape.kind] / n_dev


def ssd_bytes(cfg, shape, n_dev):
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    nh, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    per_layer = (2 * b * s * nh * p * 2       # xdt read + y write (bf16)
                 + 2 * b * s * nh * 4         # la read (+dt)
                 + 2 * b * s * n * 2 * 2      # B, C reads
                 + b * nh * p * n * 4)        # final state
    return per_layer * cfg.n_layers * PASSES[shape.kind] / n_dev


def main():
    path = sys.argv[1]
    r = json.load(open(path))
    cfg = get_arch(r["arch"])
    shape = SHAPES[r["shape"]]
    n_dev = r["n_devices"]
    regions = r.get("regions", {})
    total = r["bytes_per_device"]
    subs = {}
    new_total = total
    for region, calc in (("attention", flash_bytes), ("ssd", ssd_bytes)):
        if region not in regions:
            continue
        old = regions[region]["bytes"]
        new = calc(cfg, shape, n_dev)
        subs[region] = (old, new)
        new_total = new_total - old + new
    mem_old = total / HW_V5E["hbm_bw"]
    mem_new = new_total / HW_V5E["hbm_bw"]
    print(f"cell: {r['arch']} x {r['shape']} ({r.get('tag') or 'baseline'})")
    for region, (old, new) in subs.items():
        print(f"  {region:10s}: {old/1e12:8.3f} TB/dev  ->  {new/1e12:8.4f} TB/dev"
              f"  ({old/max(new,1e-9):,.0f}x)")
    print(f"  memory term: {mem_old:.3e} s  ->  {mem_new:.3e} s"
          f"  ({mem_old/mem_new:.2f}x)")
    bound_new = max(r["compute_s"], mem_new, r["collective_s"])
    ideal = max(r.get("ideal_compute_s", 0), r.get("ideal_memory_s", 0))
    if ideal:
        print(f"  roofline fraction: {r.get('roofline_fraction', 0):.4f}"
              f"  ->  {ideal/bound_new:.4f}")
    out = dict(r)
    out["memory_s_kernel_substituted"] = mem_new
    out["kernel_substitutions"] = {k: {"jnp_bytes": o, "kernel_bytes": n}
                                   for k, (o, n) in subs.items()}
    if ideal:
        out["roofline_fraction_kernel_substituted"] = ideal / bound_new
    dst = path.replace(".json", "__kernelsub.json")
    json.dump(out, open(dst, "w"), indent=2, default=float)
    print(f"wrote {dst}")


if __name__ == "__main__":
    main()
