#!/usr/bin/env python
"""Profile one dry-run cell: lower+compile, then dump the top byte/flop
contributors (trip-count weighted) — the §Perf iteration's 'profiler'.

  PYTHONPATH=src python experiments/profile_cell.py gemma3-27b decode_32k
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import sys  # noqa: E402

import jax  # noqa: E402


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    multi = "--multi-pod" in sys.argv
    engine_bits = 0
    for a in sys.argv:
        if a.startswith("--engine-bits="):
            engine_bits = int(a.split("=")[1])

    from repro.config import SHAPES, get_arch
    from repro.config.base import (EngineConfig, MeshConfig, RunConfig,
                                   ServeConfig)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.roofline.hlo_cost import top_contributors

    run = RunConfig(
        model=get_arch(arch), shape=SHAPES[shape_name],
        mesh=MeshConfig(multi_pod=multi),
        serve=ServeConfig(engine=EngineConfig(
            weight_bits=engine_bits, backend="reference")),
    )
    from repro.dist import use_mesh

    mesh = make_production_mesh(multi_pod=multi)
    with use_mesh(mesh):
        fn, args, kind = build_cell(run, mesh)
        compiled = fn.lower(*args).compile()
    text = compiled.as_text()
    print(f"== top contributors for {arch} x {shape_name} ({kind}) ==")
    for nbytes, flops, op, where, meta in top_contributors(text, 25):
        print(f"{nbytes/1e9:10.2f}GB {flops/1e9:12.2f}GF {op:22s} {where:50s} {meta}")


if __name__ == "__main__":
    main()
