#!/usr/bin/env python
"""Orchestrate the full baseline dry-run sweep: every (arch x shape) cell on
the single-pod (16,16) mesh and the multi-pod (2,16,16) mesh.

Each cell runs in its own subprocess (fresh XLA, bounded memory); results
land in experiments/dryrun/*.json.  Cells already done are skipped, so the
sweep is restartable.  Order is smallest-model-first so failures surface
fast.

Usage:  python experiments/run_dryruns.py [--only-missing] [--timeout 4000]
"""

import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
OUT = os.path.join(HERE, "dryrun")

# smallest-first (approx param count)
ARCHS = [
    "mamba2-130m",
    "qwen2.5-3b",
    "zamba2-7b",
    "llava-next-mistral-7b",
    "musicgen-medium",
    "starcoder2-15b",
    "llama4-scout-17b-a16e",
    "gemma3-27b",
    "qwen3-moe-235b-a22b",
    "mistral-large-123b",
]
LONG_OK = {"gemma3-27b", "mamba2-130m", "zamba2-7b"}
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cells():
    for multi in (False, True):
        for arch in ARCHS:
            for shape in SHAPES:
                if shape == "long_500k" and arch not in LONG_OK:
                    continue
                yield arch, shape, multi


def result_path(arch, shape, multi):
    suffix = "multipod" if multi else "pod"
    return os.path.join(OUT, f"{arch}__{shape}__{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=int, default=4200)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT, exist_ok=True)
    todo = [c for c in cells()
            if args.force or not os.path.exists(result_path(*c))]
    print(f"{len(todo)} cells to run")
    failures = []
    for i, (arch, shape, multi) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", OUT]
        if multi:
            cmd.append("--multi-pod")
        t0 = time.time()
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        print(f"[{i+1}/{len(todo)}] {arch} {shape} "
              f"{'multipod' if multi else 'pod'} ...", flush=True)
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout, cwd=REPO, env=env)
            ok = proc.returncode == 0
        except subprocess.TimeoutExpired:
            ok, proc = False, None
        dt = time.time() - t0
        if ok:
            print(f"    done in {dt:.0f}s", flush=True)
        else:
            msg = (proc.stderr[-2000:] if proc else "TIMEOUT")
            failures.append((arch, shape, multi, msg))
            print(f"    FAILED after {dt:.0f}s:\n{msg}", flush=True)

    print(f"\n{len(failures)} failures")
    for arch, shape, multi, msg in failures:
        print(f"  {arch} {shape} multi={multi}: {msg.splitlines()[-1] if msg.splitlines() else msg}")
    with open(os.path.join(OUT, "_sweep_status.json"), "w") as f:
        json.dump({"failures": [(a, s, m) for a, s, m, _ in failures],
                   "total": len(todo)}, f, indent=2)


if __name__ == "__main__":
    main()
