#!/usr/bin/env python
"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json.  Hillclimb (§Perf) entries are appended by hand
with the hypothesis->change->measure log.

  python experiments/make_report.py > /tmp/roofline_tables.md
"""

import glob
import json
import os

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "dryrun")

ARCH_ORDER = [
    "gemma3-27b", "mistral-large-123b", "starcoder2-15b", "qwen2.5-3b",
    "llava-next-mistral-7b", "mamba2-130m", "zamba2-7b", "musicgen-medium",
    "llama4-scout-17b-a16e", "qwen3-moe-235b-a22b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tagged=False):
    recs = {}
    for path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        name = os.path.basename(path)[:-5]
        if name.startswith("_"):
            continue
        r = json.load(open(path))
        if "compute_s" not in r:
            continue
        is_base = (not r.get("engine_bits") and not r.get("split_local")
                   and not r.get("tag"))
        if tagged != (not is_base):
            continue
        recs[(r["arch"], r["shape"], bool(r["multi_pod"]))] = r
    return recs


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    return f"{b/1e6:.1f}M"


def main():
    recs = load()
    print("### Single-pod (16x16 = 256 chips) baseline roofline — all cells\n")
    print("| arch | shape | kind | HLO GFLOP/dev | HBM bytes/dev |"
          " coll bytes/dev | compute s | memory s | collective s |"
          " dominant | roofline frac | fits HBM |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|"[:-1])
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, False))
            if r is None:
                continue
            ma = r.get("memory_analysis", {})
            tot = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)
                   - ma.get("alias_size_in_bytes", 0))
            fits = "yes" if tot < 16e9 else f"NO ({tot/1e9:.0f}GB)"
            print(f"| {arch} | {shape} | {r['kind']} |"
                  f" {r['flops_per_device']/1e9:,.0f} |"
                  f" {fmt_bytes(r['bytes_per_device'])} |"
                  f" {fmt_bytes(r['collective_bytes_per_device']['total'])} |"
                  f" {r['compute_s']:.3e} | {r['memory_s']:.3e} |"
                  f" {r['collective_s']:.3e} |"
                  f" {r['dominant'].replace('_s','')} |"
                  f" {r.get('roofline_fraction', 0):.4f} | {fits} |")

    print("\n### Multi-pod (2x16x16 = 512 chips) — compile proof + terms\n")
    print("| arch | shape | compile s | dominant | roofline frac |"
          " coll bytes/dev |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, True))
            if r is None:
                continue
            print(f"| {arch} | {shape} | {r['compile_s']:.1f} |"
                  f" {r['dominant'].replace('_s','')} |"
                  f" {r.get('roofline_fraction', 0):.4f} |"
                  f" {fmt_bytes(r['collective_bytes_per_device']['total'])} |")

    n_pod = sum(1 for k in recs if not k[2])
    n_multi = sum(1 for k in recs if k[2])
    print(f"\ncells: {n_pod} single-pod + {n_multi} multi-pod, all compiled")


if __name__ == "__main__":
    main()
