#!/usr/bin/env python
"""The paper, end to end, on one GEMV.

1. Assemble the 30-bit ISA program for a tiled integer GEMV (paper Fig. 2/3).
2. Execute it on the cycle-counted tile-controller model — exact result.
3. Run the same GEMV through the TPU engine (bit-plane kernel, interpret
   mode) — identical semantics on the adapted hardware.
4. Report the paper's figures of merit: cycles, execution time @737 MHz,
   and the latency-model comparison against CCB/CoMeFa/SPAR-2/BRAMAC.

    PYTHONPATH=src python examples/gemv_paper_demo.py [--dim 96]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.controller import run_gemv
from repro.core.isa import assemble_gemv, roundtrip
from repro.core.latency_model import FIG6_DESIGNS, IMAGINE_FSYS_MHZ
from repro.engine import EnginePlan, pack_linear


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=96)
    args = ap.parse_args()
    dim = args.dim

    rng = np.random.default_rng(0)
    w = rng.integers(-127, 128, size=(dim, dim))
    x = rng.integers(-127, 128, size=(dim,))

    print("== 1. assemble the ISA program ==")
    prog = assemble_gemv(n_elems=12, n_folds=1, out_rows=16)
    words, decoded = roundtrip(prog)
    print(f"instructions={len(prog)} first 4 encoded: "
          + " ".join(f"{wd:08x}" for wd in words[:4]))
    assert decoded == prog

    print("== 2. execute on the tile-controller model ==")
    res = run_gemv(w, x, rows=16, cols=8)
    assert np.array_equal(res.y, w @ x), "FPGA model must be exact"
    us = res.cycles / IMAGINE_FSYS_MHZ
    print(f"exact={np.array_equal(res.y, w @ x)} cycles={res.cycles} "
          f"exec={us:.2f}us @737MHz  y[:4]={res.y[:4]}")

    print("== 3. the same GEMV on the TPU engine (bit-plane kernel) ==")
    # integer weights map exactly into the int8 engine format
    ql = pack_linear(jnp.asarray(w.T, jnp.float32), bits=8)
    plan = EnginePlan(backend="pallas_interpret", bits=8, radix=1)
    y_tpu = plan.apply(ql, jnp.asarray(x, jnp.float32),
                       out_dtype=jnp.float32)
    err = float(np.max(np.abs(np.asarray(y_tpu) - (w @ x))))
    rel = err / max(1.0, float(np.max(np.abs(w @ x))))
    print(f"bit-plane kernel matches: rel_err={rel:.2e}")

    print("== 4. latency-model comparison (paper Fig. 6) ==")
    for name, (fn, f_mhz) in FIG6_DESIGNS.items():
        cyc = fn(dim, 8)
        t = f"{cyc / f_mhz:8.1f}us" if f_mhz else "   (n/a)"
        print(f"  {name:16s} cycles={cyc:>8d} exec={t}")


if __name__ == "__main__":
    main()
