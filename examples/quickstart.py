#!/usr/bin/env python
"""Quickstart: build a tiny gemma3-family model, run a forward pass, take a
few train steps, quantize for the IMAGine engine, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import get_reduced
from repro.config.base import EngineConfig, TrainConfig
from repro.data import DataPipeline
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    quantize_params,
)
from repro.optim import make_optimizer
from repro.train.trainer import make_train_step


def main():
    cfg = dataclasses.replace(get_reduced("gemma3-27b"), dtype="float32")
    print(f"arch family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model} params={cfg.param_count():,}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(cfg, batch=4, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    logits, _ = forward(params, batch, cfg, remat="none")
    print(f"forward: logits {logits.shape}")

    tcfg = TrainConfig(lr=1e-3, total_steps=20, warmup_steps=2)
    step = make_train_step(cfg, tcfg, donate=False)
    init_fn, _ = make_optimizer(tcfg.optimizer)
    opt = init_fn(params)
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, _, metrics = step(params, opt, {}, batch)
        print(f"train step {i}: loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e}")

    # IMAGine engine: quantize to int8 bit-planes and decode
    qparams = quantize_params(params, cfg, bits=8)
    eng = EngineConfig(weight_bits=8, backend="reference")
    cache = init_cache(cfg, batch=2, max_len=16)
    tok = jnp.asarray([[1], [2]], jnp.int32)
    for i in range(4):
        logits, cache = decode_step(qparams, cache, tok, cfg, eng)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        print(f"decode step {i}: tokens {tok[:, 0].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
