#!/usr/bin/env python
"""End-to-end serving driver (the paper's kind of workload: GEMV-bound
decode).  Trains a small LM briefly so weights are meaningful, then serves
a stream of batched requests — through the legacy fixed-slot engine, the
paged-KV continuous-batching engine (batched chunked prefill + block-table
decode), and the fully-quantized IMAGine mode (int8 bit-plane weights +
int8 KV pages) — and reports the weight- and KV-byte reductions plus the
greedy-token agreement across modes.

    PYTHONPATH=src python examples/serve_decode.py [--tokens 24] [--reqs 6]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.config import get_reduced
from repro.config.base import EngineConfig, ServeConfig, TrainConfig
from repro.data import DataPipeline
from repro.models import init_params
from repro.serve import ServeEngine, ServeFrontend
from repro.train import Trainer


def tree_bytes(t):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t)
               if hasattr(l, "dtype"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--reqs", type=int, default=6)
    ap.add_argument("--train-steps", type=int, default=20)
    args = ap.parse_args()

    # observability on for the whole driver: every engine below carries a
    # live telemetry (metrics + Chrome trace); docs/observability.md
    obs.enable()

    cfg = dataclasses.replace(get_reduced("qwen2.5-3b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    print(f"== train {args.train_steps} steps so the LM is non-random ==")
    tcfg = TrainConfig(lr=1e-3, total_steps=args.train_steps, warmup_steps=2)
    pipe = DataPipeline(cfg, batch=4, seq_len=48, seed=0)
    tr = Trainer(cfg, tcfg, params, pipe)
    hist = tr.run(args.train_steps)["loss"]
    print(f"loss {hist[0]:.3f} -> {hist[-1]:.3f}")
    params = tr.params

    # a shared "system prompt" prefix + per-request tails: the kind of
    # stream the prefix cache collapses to suffix-only prefill
    system = [(3 * j + 1) % cfg.vocab_size for j in range(16)]
    prompts = [system + [(7 * i + j) % cfg.vocab_size
                         for j in range(3 + i % 4)]
               for i in range(args.reqs)]

    results = {}
    for label, mode, engine, prefix_cache in (
        ("slots-dense", "slots", EngineConfig(), False),
        ("paged-dense", "paged", EngineConfig(), False),
        ("paged-prefix-cache", "paged", EngineConfig(), True),
        ("paged-kv8", "paged",
         EngineConfig(kv_bits=8, backend="reference"), False),
        ("paged-imagine-int8", "paged",
         EngineConfig(weight_bits=8, kv_bits=8, backend="reference"), False),
    ):
        eng = ServeEngine(
            cfg, params,
            ServeConfig(max_new_tokens=args.tokens, engine=engine,
                        page_size=8, prefill_chunk=8),
            n_slots=4, max_len=64, mode=mode, prefix_cache=prefix_cache)
        for p in prompts:
            eng.submit(p)
        done = eng.run()
        # no hand-rolled perf_counter math: the engine's own telemetry
        # already timed every step
        m = eng.metrics()
        dt = m["obs"]["metrics"]["histograms"]["serve_step_s"]["sum"]
        wbytes = tree_bytes(eng.params)
        kvbytes = (eng.pages.nbytes() if mode == "paged"
                   else tree_bytes(eng.cache))
        results[label] = done
        extra = (f", preemptions={m['preemptions']}" if mode == "paged"
                 else "")
        if eng.prefix_cache is not None:
            st = m["prefix"]
            extra += (f", prefill computed {m['prefill_computed']} tokens "
                      f"({st['hit_tokens']} from cache, "
                      f"{st['cow_forks']} COW forks)")
        print(f"== {label}: {len(done)} requests, {dt:.1f}s, "
              f"weights={wbytes/1e6:.1f}MB, kv={kvbytes/1e6:.2f}MB{extra} ==")
        for r in sorted(done, key=lambda r: r.rid)[:3]:
            hit = (f" ({r.cached_tokens} prompt tokens from cache)"
                   if r.cached_tokens else "")
            print(f"  req{r.rid}: prompt={r.prompt} -> {r.output}{hit}")

    base = {r.rid: r.output for r in results["slots-dense"]}
    for label in ("paged-dense", "paged-prefix-cache", "paged-kv8",
                  "paged-imagine-int8"):
        agree = sum(
            t1 == t2
            for r in results[label]
            for t1, t2 in zip(base[r.rid], r.output))
        total = sum(len(r.output) for r in results[label])
        print(f"{label}: greedy agreement with slots-dense = "
              f"{agree}/{total}")

    # --- streaming front-end: tokens as they are produced, SLA-aware ---
    # the budget scheduler interleaves chunked prefill with decode under
    # a per-step token budget; priorities get weighted fair shares; the
    # bounded queue sheds overload with a reason instead of queueing it
    print("\n== streaming front-end (budget scheduler, bounded queue) ==")
    eng = ServeEngine(
        cfg, params,
        ServeConfig(max_new_tokens=args.tokens, engine=EngineConfig(),
                    page_size=8, prefill_chunk=8,
                    sched="budget", step_tokens=12, max_queue=3),
        n_slots=2, max_len=64, mode="paged", prefix_cache=True)
    fe = ServeFrontend(eng)
    streams = [
        fe.submit(prompts[0], priority="interactive", tenant="app"),
        fe.submit(prompts[1], priority="batch", tenant="etl"),
        fe.submit(prompts[2], priority="default", deadline_s=30.0),
    ]
    # the admission queue (max_queue=3) is full -> the 4th sheds at the
    # door with a reason instead of growing the tail unboundedly
    shed = fe.submit(prompts[3])
    print(f"  shed stream: state={shed.state!r} "
          f"reason={shed.shed_reason!r} (no exception on the hot path)")
    # pull tokens incrementally, round-robin — each next() drives the
    # shared engine, so all lanes advance together
    for s in streams:
        first = next(s)
        print(f"  stream rid={s.rid} [{s.req.priority}] first token "
              f"{first} after {1e3 * s.ttft():.0f}ms (state={s.state})")
    for s in streams:
        s.result()  # drain the rest
    fe.drain()
    print(f"  done: {[len(s.tokens) for s in fe.streams]} tokens/stream, "
          f"{fe.shed_count} shed, {fe.timeout_count} timed out")

    # --- the observability surface this run produced ---
    snap = eng.metrics()
    o = snap["obs"]["metrics"]
    ttft = o["histograms"]["serve_ttft_s"]
    print("\n== ServeEngine.metrics() snapshot (streaming engine) ==")
    print(f"  steps={snap['obs']['steps']}  "
          f"request_states={snap['obs']['request_states']}")
    for k in ("serve_requests_submitted_total",
              "serve_tokens_generated_total",
              "serve_prefill_tokens_total",
              'serve_requests_shed_total{reason="queue_full"}',
              "prefix_cache_hits_total"):
        if k in o["counters"]:
            print(f"  {k} = {o['counters'][k]}")
    print(f"  serve_ttft_s: count={ttft['count']} p50={ttft['p50']:.4f}s "
          f"max={ttft['max']:.4f}s")
    trace_path = eng.obs.export_chrome_trace("serve_trace.json")
    print(f"  Chrome trace -> {trace_path} "
          f"(load it at https://ui.perfetto.dev)")
    obs.disable()


if __name__ == "__main__":
    main()
