#!/usr/bin/env python
"""Training driver with the full production loop: deterministic sharded
data, AdamW + cosine schedule, gradient accumulation, checkpointing with
auto-resume, a simulated node failure mid-run, and straggler monitoring.

Default is a ~5M-param qwen2.5-family model for CPU friendliness; pass
--arch/--scale to grow it (the same driver lowers the full configs on the
production mesh via launch/dryrun.py).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import dataclasses
import os

import jax

from repro.ckpt import CheckpointManager
from repro.config import get_reduced
from repro.config.base import TrainConfig
from repro.data import DataPipeline
from repro.ft import FailureInjector, StragglerMonitor
from repro.models import init_params
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which to simulate a node failure")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_reduced(args.arch),
        dtype="float32",
        d_model=args.width,
        n_layers=args.layers,
        d_ff=args.width * 3,
        vocab_size=4096,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(l.size for l in jax.tree.leaves(params))
    print(f"model: {cfg.name} {n:,} params")

    tcfg = TrainConfig(lr=3e-4, total_steps=args.steps, warmup_steps=10,
                       microbatches=2)
    pipe = DataPipeline(cfg, batch=args.batch, seq_len=args.seq, seed=0)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    injector = None
    if args.inject_failure >= 0:
        injector = FailureInjector(schedule={args.inject_failure: 0})

    tr = Trainer(
        cfg, tcfg, params, pipe,
        ckpt_manager=CheckpointManager(args.ckpt_dir, keep=2),
        ckpt_every=20,
        straggler_monitor=StragglerMonitor(),
        failure_injector=injector,
    )
    hist = tr.run(args.steps)["loss"]
    print(f"trained {len(hist)} steps (restarts={tr.restarts}): "
          f"loss {hist[0]:.4f} -> {hist[-1]:.4f}")
    stragglers = tr.straggler.chronic_hosts()
    print(f"chronic stragglers: {stragglers or 'none'}")
    print(f"checkpoints under {args.ckpt_dir}: resume by re-running")


if __name__ == "__main__":
    main()
