#!/usr/bin/env python
"""Prefix-cache benchmark: shared-prefix serving with and without the
radix-tree KV cache.

The workload models the dominant production shape: many requests sharing
one long system prompt, each with a short unique suffix.  One priming
request (the bare prefix) is served first, then a batch of
``batch × n_reqs_per_lane`` shared-prefix requests:

  * ``nocache``   — every request re-prefills the whole prompt;
  * ``prefix``    — requests match the radix tree and prefill **only the
    unique suffix** (matched full pages are mapped shared, refcounted).

Gates (enforced under ``--smoke``, recorded always):

  * **token identity** — cached greedy output ≡ no-cache output;
  * **compute ∝ unique suffix** — with the prefix page-aligned, prefill
    tokens computed with the cache is *exactly*
    ``(prefix + 1) + n_requests × suffix`` (the priming prompt plus each
    unique suffix), vs ``(prefix + 1) + n_requests × (prefix + suffix)``
    cold;
  * **throughput** — end-to-end tok/s strictly above no-cache at
    shared-prefix batch ≥ 4.

Results land in ``BENCH_prefix.json`` plus repo-standard CSV rows.

  PYTHONPATH=src python benchmarks/prefix_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/prefix_bench.py --smoke    # CI: batch 4
"""

import argparse
import json

try:
    from benchmarks.common import (build_model, make_engine,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import build_model, make_engine, wall_timer, write_bench


def _workload(cfg, n_reqs: int, prefix_len: int, suffix_len: int):
    """One shared prefix (page-aligned by construction in ``_serve``),
    unique per-request suffixes, plus the priming prompt."""
    prefix = [(3 * j + 1) % cfg.vocab_size for j in range(prefix_len)]
    primer = prefix + [2]
    prompts = [
        prefix + [(5 * i + j + 7) % cfg.vocab_size
                  for j in range(suffix_len)]
        for i in range(n_reqs)
    ]
    return primer, prompts


def _serve(cfg, params, cached: bool, batch: int, primer, prompts,
           max_new: int, max_len: int, page_size: int = 8,
           prefill_chunk: int = 16):
    # the warm request uses a disjoint token range (never matches the
    # prefix), so it cannot seed the radix tree with workload pages
    eng = make_engine(cfg, params, n_slots=batch, max_len=max_len,
                      max_new=max_new, page_size=page_size,
                      prefill_chunk=prefill_chunk, prefix_cache=cached)

    mode = "prefix" if cached else "nocache"
    with wall_timer(f"{mode}_b{batch}") as w:
        eng.submit(list(primer), max_new_tokens=1)
        eng.run()  # priming completes (and, when cached, populates the tree)
        computed0 = eng.prefill_computed
        for p in prompts:
            eng.submit(list(p))
        done = eng.run()
    wall = w.wall

    done = [r for r in done]
    gen = sum(len(r.output) for r in done)
    ttfts = [r.ttft for r in done if r.ttft is not None]
    metrics = eng.metrics()
    stats = metrics.get("prefix") or {}
    return {
        "mode": mode,
        "batch": batch,
        "requests": len(done) + 1,  # + primer
        "prompt_tokens": len(primer) + sum(len(p) for p in prompts),
        "prefill_computed": int(metrics["prefill_computed"]),
        "prefill_computed_batch": int(metrics["prefill_computed"]
                                      - computed0),
        "gen_tokens": gen,
        "wall_s": round(wall, 4),
        "tok_per_s": round(gen / wall, 2) if wall > 0 else 0.0,
        "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4) if ttfts else None,
        "hit_tokens": int(stats.get("hit_tokens", 0)),
        "cow_forks": int(stats.get("cow_forks", 0)),
        "cached_pages": int(stats.get("cached_pages", 0)),
        "preemptions": metrics["preemptions"],
    }, {r.rid: r.output for r in done}


def run(batches=(2, 4), arch: str = "qwen2.5-3b", n_reqs_per_lane: int = 2,
        prefix_len: int = 128, suffix_len: int = 4, max_new: int = 6,
        page_size: int = 8, out: str = "BENCH_prefix.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns the
    repo-standard (name, us_per_call, derived) CSV rows."""
    assert prefix_len % page_size == 0, "keep the shared prefix page-aligned"
    cfg, params = build_model(arch)
    max_len = prefix_len + suffix_len + max_new + 8
    # warm process-level state for both paths (imports, jit infra, the
    # prefix-cache host structures) so the first measured engine does not
    # bill one-time costs to its mode
    wp, wb = _workload(cfg, 2, page_size, 2)
    for cached in (False, True):
        _serve(cfg, params, cached, 2, wp, wb, 2, max_len, page_size)
    results, rows = [], []
    identical = True
    compute_exact = True
    def best_of(cached, batch, primer, prompts, reps=2):
        """Serve ``reps`` times, keep the fastest wall — the tok/s gate
        compares compute, not a CI runner's noisy-neighbor stalls.  The
        deterministic fields (tokens, prefill_computed) are identical
        across reps by construction."""
        best = outs = None
        for _ in range(reps):
            r, o = _serve(cfg, params, cached, batch, primer, prompts,
                          max_new, max_len, page_size)
            if best is not None:
                assert o == outs and (r["prefill_computed"]
                                      == best["prefill_computed"])
            if best is None or r["wall_s"] < best["wall_s"]:
                best, outs = r, o
            outs = o
        return best, outs

    for batch in batches:
        n_reqs = n_reqs_per_lane * batch
        primer, prompts = _workload(cfg, n_reqs, prefix_len, suffix_len)
        cold, out_cold = best_of(False, batch, primer, prompts)
        hot, out_hot = best_of(True, batch, primer, prompts)
        identical &= out_cold == out_hot
        # prefill compute ∝ unique suffix: every batch request matches the
        # primed prefix exactly (page-aligned), computing only its suffix
        compute_exact &= hot["prefill_computed_batch"] == n_reqs * suffix_len
        compute_exact &= (cold["prefill_computed_batch"]
                          == n_reqs * (prefix_len + suffix_len))
        results.extend([cold, hot])
        for r in (cold, hot):
            us = 1e6 * r["wall_s"] / max(r["gen_tokens"], 1)
            rows.append((f"serve_{r['mode']}_b{batch}", round(us, 1),
                         f"tok/s={r['tok_per_s']}"
                         f";prefill={r['prefill_computed']}"))

    speedup = {
        str(b): round(
            next(r["tok_per_s"] for r in results
                 if r["batch"] == b and r["mode"] == "prefix")
            / max(next(r["tok_per_s"] for r in results
                       if r["batch"] == b and r["mode"] == "nocache"),
                  1e-9), 3)
        for b in batches
    }
    record = {
        "bench": "prefix",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs_per_lane": n_reqs_per_lane,
                     "prefix_len": prefix_len, "suffix_len": suffix_len,
                     "max_new": max_new, "page_size": page_size,
                     "batches": list(batches)},
        "results": results,
        "prefix_over_nocache_tok_per_s": speedup,
        "token_identical": bool(identical),
        "prefill_scales_with_unique_suffix": bool(compute_exact),
        "prefix_faster_at_batch4plus": all(
            v > 1.0 for b, v in speedup.items() if int(b) >= 4),
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: batch 4 only, short generations")
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(batches=tuple(args.batches or (4,)), max_new=5,
                   out=args.out)
    else:
        rows = run(batches=tuple(args.batches or (2, 4)), out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["token_identical"]:
        raise SystemExit("prefix-cache outputs diverged from no-cache")
    if not record["prefill_scales_with_unique_suffix"]:
        raise SystemExit(
            "prefill compute did not scale with unique suffix tokens")
    if args.smoke and not record["prefix_faster_at_batch4plus"]:
        raise SystemExit(
            "prefix-cache throughput fell below no-cache at b>=4")
    print(f"# prefix/nocache tok/s: "
          f"{record['prefix_over_nocache_tok_per_s']}  "
          f"token_identical={record['token_identical']}  "
          f"suffix_scaling={record['prefill_scales_with_unique_suffix']}")


if __name__ == "__main__":
    main()
