#!/usr/bin/env python
"""Robustness benchmark: auditor overhead, recovery drill, chaos storm.

Three claims from the fault-tolerance subsystem, priced and gated:

  * **audit overhead** — the runtime invariant auditor
    (``ServeConfig(audit=1)``: full allocator / prefix-cache /
    scheduler proof after every engine step) serves the identical
    closed-loop workload within 5% of the audit-off throughput.  Reps
    interleave off/on so host drift hits both arms equally; best-of-
    reps walls are compared.

  * **recovery drill** — kill the engine at step *k*, persist a
    crash-consistent snapshot through ``repro.ckpt``, restore into a
    fresh engine, drain: greedy outputs token-identical to the
    uninterrupted run (the serving analogue of bit-exact training
    resume).

  * **chaos storm** — a seeded :class:`repro.ft.ChaosInjector` fires
    page-grant failures, simulated step faults, NaN logits and preempt
    storms across the run with the auditor at level 1: every request
    untouched by a quarantine retires with tokens identical to the
    calm run, and the auditor never trips.

Results land in ``BENCH_chaos.json`` plus the repo-standard CSV rows.

  PYTHONPATH=src python benchmarks/chaos_bench.py            # full run
  PYTHONPATH=src python benchmarks/chaos_bench.py --smoke    # CI-sized
"""

import argparse
import json
import tempfile

try:
    from benchmarks.common import (build_model, make_engine,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import build_model, make_engine, wall_timer, write_bench

AUDIT_BUDGET = 0.05  # audit-on may cost at most 5% tok/s


def _workload(cfg, n_reqs: int, prompt_len: int):
    return [
        [(11 * i + j) % cfg.vocab_size for j in range(prompt_len + i % 4)]
        for i in range(n_reqs)
    ]


def _serve_once(cfg, params, prompts, tag, **kw):
    eng = make_engine(cfg, params, **kw)
    for p in prompts:
        eng.submit(list(p))
    with wall_timer(None) as w:
        done = eng.run()
    gen = sum(len(r.output) for r in done)
    outs = {r.rid: list(r.output) for r in done}
    return {
        "arm": tag,
        "gen_tokens": gen,
        "wall_s": round(w.wall, 5),
        "tok_per_s": round(gen / w.wall, 2) if w.wall > 0 else 0.0,
    }, outs, eng


def _recovery_drill(cfg, params, prompts, *, kill_step, **kw):
    """Token identity through kill -> disk snapshot -> restore."""
    eng = make_engine(cfg, params, **kw)
    for p in prompts:
        eng.submit(list(p))
    with tempfile.TemporaryDirectory() as d:
        for _ in range(kill_step):
            eng.step()
        eng.save_snapshot(d, kill_step)
        ref = {r.rid: list(r.output) for r in eng.run()}

        fresh = make_engine(cfg, params, **kw)
        fresh.load_snapshot(d)
        fresh.audit()
        got = {r.rid: list(r.output) for r in fresh.run()}
    return ref == got


def _chaos_storm(cfg, params, prompts, calm, *, seed, **kw):
    """Seeded storm with the auditor on; returns (ok, summary)."""
    from repro.ft import ChaosInjector

    ch = ChaosInjector(seed=seed,
                       rates={"page_grant": 0.05, "step_fault": 0.05,
                              "nan_logits": 0.03, "preempt_storm": 0.02})
    eng = make_engine(cfg, params, audit=1, chaos=ch,
                      max_request_retries=2, **kw)
    for p in prompts:
        eng.submit(list(p))
    done = eng.run()  # AuditError here fails the bench outright
    eng.audit()
    unaffected_ok = all(
        list(r.output) == calm[r.rid]
        for r in done if r.finish_reason != "error")
    retired = sum(1 for r in done if r.finish_reason != "error")
    return unaffected_ok, {
        "faults_fired": ch.summary(),
        "quarantined": eng.quarantined,
        "retired_clean": retired,
        "n_requests": len(prompts),
    }


def run(arch: str = "qwen2.5-3b", n_reqs: int = 16, n_slots: int = 4,
        prompt_len: int = 12, max_new: int = 8, max_len: int = 64,
        reps: int = 6, kill_step: int = 3, out: str = "BENCH_chaos.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns
    the repo-standard (name, us_per_call, derived) CSV rows."""
    cfg, params = build_model(arch)
    prompts = _workload(cfg, n_reqs, prompt_len)
    kw = dict(n_slots=n_slots, max_len=max_len, max_new=max_new,
              prefix_cache=True)

    # one throwaway pass warms process-global jit state for both arms
    _serve_once(cfg, params, prompts[:2], "warm", **kw)

    best, outs = {}, {}
    for _ in range(reps):
        for tag, audit in (("audit_off", 0), ("audit_on", 1)):
            res, o, _ = _serve_once(cfg, params, prompts, tag,
                                    audit=audit, **kw)
            outs.setdefault(tag, o)
            assert o == outs[tag], f"{tag} arm tokens drifted across reps"
            if tag not in best or res["wall_s"] < best[tag]["wall_s"]:
                best[tag] = res

    identical = outs["audit_off"] == outs["audit_on"]
    tok_off = best["audit_off"]["tok_per_s"]
    tok_on = best["audit_on"]["tok_per_s"]
    overhead_ok = tok_on >= (1.0 - AUDIT_BUDGET) * tok_off

    recovered = _recovery_drill(cfg, params, prompts,
                                kill_step=kill_step, **kw)
    storm_ok, storm = _chaos_storm(cfg, params, prompts,
                                   outs["audit_off"], seed=17, **kw)

    rows = [
        (f"chaos_{tag}",
         round(1e6 * r["wall_s"] / max(r["gen_tokens"], 1), 1),
         f"tok/s={r['tok_per_s']}")
        for tag, r in best.items()
    ]
    record = {
        "bench": "chaos",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs": n_reqs, "n_slots": n_slots,
                     "prompt_len": prompt_len, "max_new": max_new,
                     "max_len": max_len, "reps": reps,
                     "kill_step": kill_step},
        "results": list(best.values()),
        "on_over_off_tok_per_s": round(tok_on / max(tok_off, 1e-9), 4),
        "audit_budget": AUDIT_BUDGET,
        "audit_within_budget": bool(overhead_ok),
        "token_identical": bool(identical),
        "recovery_token_identical": bool(recovered),
        "storm_unaffected_identical": bool(storm_ok),
        "storm": storm,
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, short generations")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(n_reqs=8, max_new=5, reps=4, out=args.out)
    else:
        rows = run(out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    for gate, msg in (
            ("token_identical", "the auditor changed generated tokens"),
            ("recovery_token_identical",
             "snapshot/restore changed generated tokens"),
            ("storm_unaffected_identical",
             "chaos storm changed tokens of unaffected requests")):
        if not record[gate]:
            raise SystemExit(msg)
    if not record["audit_within_budget"]:
        raise SystemExit(
            f"audit-on throughput {record['on_over_off_tok_per_s']:.4f}x "
            f"off exceeds the {record['audit_budget']:.0%} budget")
    print(f"# audit on/off tok/s={record['on_over_off_tok_per_s']}  "
          f"recovery={record['recovery_token_identical']}  "
          f"storm={record['storm']}")


if __name__ == "__main__":
    main()
