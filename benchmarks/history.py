"""Bench-result history + perf regression gate.

Every ``write_bench`` call appends one provenance-stamped line to
``BENCH_history.jsonl`` next to the result file: the bench name (from
the ``BENCH_<name>.json`` filename), the :func:`~common.bench_env`
provenance (``device_kind``, ``interpret_mode``), a UTC timestamp, the
current git commit when one is resolvable, and every *comparable*
numeric metric found in the record — keys whose leaf name contains
``tok_per_s`` (higher is better) or ``bytes_per_tok`` (lower is
better), flattened as dotted paths.

``check_regression`` then compares a fresh record against the **best**
prior history line with the same ``(bench, device_kind,
interpret_mode)`` triple — results from a different device, or from
Pallas interpret mode vs compiled kernels, are never comparable and are
silently skipped.  A metric regresses when it is worse than the best
prior by more than ``tol`` (default 10%).  CLI::

    python -m benchmarks.history --check BENCH_serve.json ...   # gate
    python -m benchmarks.history --self-test                    # prove
                                        # the gate fires on a synthetic
                                        # 20% tok/s regression

The history file is append-only JSONL so concurrent benches cannot
clobber each other and a corrupt line never poisons the file — readers
skip lines that fail to parse.
"""

import json
import os
from datetime import datetime, timezone

HISTORY_NAME = "BENCH_history.jsonl"

# leaf-name substrings that make a numeric metric comparable, with
# direction: +1 = higher is better, -1 = lower is better
_COMPARABLE = (("tok_per_s", +1), ("bytes_per_tok", -1))


def _direction(key):
    """+1 / -1 for a comparable dotted key, else None."""
    leaf = key.rsplit(".", 1)[-1]
    for frag, sign in _COMPARABLE:
        if frag in leaf:
            return sign
    return None


def comparable_metrics(record, prefix=""):
    """Flatten a bench record's comparable numeric leaves to
    ``{dotted.path: value}`` (see module docstring for which leaves
    qualify)."""
    out = {}
    if isinstance(record, dict):
        for k, v in record.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                out.update(comparable_metrics(v, key))
            elif isinstance(v, list):
                for i, item in enumerate(v):
                    if isinstance(item, dict):
                        out.update(comparable_metrics(item, f"{key}[{i}]"))
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                if _direction(key) is not None:
                    out[key] = float(v)
    return out


def _git_commit():
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        return None


def history_path_for(out):
    return os.path.join(os.path.dirname(os.path.abspath(out)),
                        HISTORY_NAME)


def bench_name_for(out):
    """``BENCH_serve.json`` -> ``serve`` (else the bare stem)."""
    stem = os.path.splitext(os.path.basename(out))[0]
    return stem[len("BENCH_"):] if stem.startswith("BENCH_") else stem


def make_entry(out, record):
    """The history line for one written bench record (provenance +
    comparable metrics); None when the record has nothing comparable."""
    metrics = comparable_metrics(record)
    if not metrics:
        return None
    return {
        "bench": bench_name_for(out),
        "device_kind": record.get("device_kind"),
        "interpret_mode": record.get("interpret_mode"),
        "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "commit": _git_commit(),
        "metrics": metrics,
    }


def append_record(out, record, history_path=None):
    """Append the history line for ``record`` (as written to ``out``).
    Returns the history path, or None when nothing comparable exists."""
    entry = make_entry(out, record)
    if entry is None:
        return None
    path = history_path or history_path_for(out)
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return path


def load_history(path):
    """Parsed history lines (corrupt lines skipped, never fatal)."""
    entries = []
    if not os.path.exists(path):
        return entries
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except (ValueError, TypeError):
                continue
    return entries


def best_prior(entries, bench, device_kind, interpret_mode):
    """Per-metric best over matching history lines: ``{key: best}``."""
    best = {}
    for e in entries:
        if (e.get("bench") != bench
                or e.get("device_kind") != device_kind
                or e.get("interpret_mode") != interpret_mode):
            continue
        for key, val in (e.get("metrics") or {}).items():
            sign = _direction(key)
            if sign is None or not isinstance(val, (int, float)):
                continue
            cur = best.get(key)
            if cur is None or (sign > 0) == (val > cur):
                best[key] = float(val)
    return best


def check_regression(record, history_path, bench, tol=0.10):
    """Regressions of ``record`` vs the best matching history line.

    Returns ``[(key, current, best), ...]`` for every comparable metric
    worse than the best prior by more than ``tol`` (relative).  An empty
    history (or no matching triple — different device, interpret mode)
    returns no regressions: absence of a baseline is not a failure.
    """
    current = comparable_metrics(record)
    best = best_prior(load_history(history_path), bench,
                      record.get("device_kind"),
                      record.get("interpret_mode"))
    regressions = []
    for key, val in sorted(current.items()):
        ref = best.get(key)
        if ref is None or ref == 0:
            continue
        sign = _direction(key)
        worse = (val < ref * (1.0 - tol) if sign > 0
                 else val > ref * (1.0 + tol))
        if worse:
            regressions.append((key, val, ref))
    return regressions


def _check_files(paths, history_path, tol):
    failed = False
    for out in paths:
        with open(out) as f:
            record = json.load(f)
        hpath = history_path or history_path_for(out)
        bench = bench_name_for(out)
        regs = check_regression(record, hpath, bench, tol)
        if regs:
            failed = True
            print(f"REGRESSION {out} (vs best in {hpath}):")
            for key, val, ref in regs:
                pct = abs(val - ref) / ref * 100.0
                print(f"  {key}: {val:.6g} vs best {ref:.6g} "
                      f"({pct:.1f}% worse, tol {tol * 100:.0f}%)")
        else:
            n = len(comparable_metrics(record))
            print(f"ok {out}: {n} comparable metric(s), "
                  f"no regression beyond {tol * 100:.0f}%")
    return 1 if failed else 0


def _self_test(tol):
    """Prove the gate fires: a synthetic 20% tok/s regression (and a 20%
    bytes/token inflation) against a recorded baseline MUST fail, and
    the baseline against itself must pass."""
    import tempfile

    base = {"device_kind": "cpu", "interpret_mode": True,
            "decode": {"tok_per_s": 100.0, "bytes_per_tok": 1000.0}}
    bad = {"device_kind": "cpu", "interpret_mode": True,
           "decode": {"tok_per_s": 80.0, "bytes_per_tok": 1200.0}}
    other = {"device_kind": "TPU v4", "interpret_mode": False,
             "decode": {"tok_per_s": 80.0, "bytes_per_tok": 1200.0}}
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "BENCH_selftest.json")
        hpath = append_record(out, base)
        assert hpath and load_history(hpath), "baseline did not append"
        assert not check_regression(base, hpath, "selftest", tol), \
            "baseline regressed against itself"
        regs = check_regression(bad, hpath, "selftest", tol)
        keys = {k for k, _, _ in regs}
        assert "decode.tok_per_s" in keys, \
            f"20% tok/s regression not caught (got {regs})"
        assert "decode.bytes_per_tok" in keys, \
            f"20% bytes/token inflation not caught (got {regs})"
        assert not check_regression(other, hpath, "selftest", tol), \
            "cross-device records must never be compared"
    print("history self-test ok: synthetic 20% regression fails the "
          f"gate at tol {tol * 100:.0f}%, cross-device records skip")
    return 0


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", nargs="+", metavar="BENCH_JSON",
                    help="gate these result files against history")
    ap.add_argument("--history", default=None,
                    help="explicit history file (default: "
                         f"{HISTORY_NAME} next to each result)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="relative regression tolerance (default 0.10)")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the gate fires on a synthetic regression")
    args = ap.parse_args(argv)
    if args.self_test:
        return _self_test(args.tol)
    if not args.check:
        ap.error("nothing to do: pass --check FILE... or --self-test")
    return _check_files(args.check, args.history, args.tol)


if __name__ == "__main__":
    raise SystemExit(main())
