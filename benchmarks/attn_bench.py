#!/usr/bin/env python
"""Paged-attention benchmark: fused in-place kernel vs the gather
reference backend, decode and in-kernel chunked prefill.

For each (context length × page size × kv_bits) sweep point the same
synthetic page pool + block tables are attended through

  * ``gather``           — materialize each lane's logical KV view, then
                           attend (the reference read path);
  * ``pallas_interpret`` — the fused kernel (``kernels.paged_attention``)
                           reading pool pages in place through the block
                           table (CPU hosts run the kernel body
                           interpreted — wall time is a machinery check,
                           like BENCH_shard's scaling curves; the perf
                           claim is the bytes-moved model, which a real
                           TPU run validates as ``pallas_tpu``).

The prefill sweep does the same for a ``chunk``-token query block through
the kernel's prefill grid (mid-page ``pos0``, ragged last lane) vs the
gather path that materializes the full (B, T, Hkv, Dh) view per chunk.

Reported per point: per-call wall time / tok/s for both paths, the
modeled HBM bytes per token (``decode_attn_bytes`` /
``prefill_attn_bytes``), and the fused/gather byte ratio.  Two gates fail
the run: the bytes-moved model must put the fused path below gather at
every sweep point (a *self-consistency check of the analytic model* —
both numbers come from the same function, so this guards edits to the
model, not the kernel's actual traffic, which is the real-TPU ROADMAP
item), and greedy serving through the fused kernel must be
token-identical to the gather backend (the behavioral gate — this one
exercises the kernel).  Results land in ``BENCH_attn.json``.

``--mesh`` adds the shard_mapped rows: the same sweep points through
``sharded_paged_attention`` on a forced-host (4, 2) ``(data, model)``
mesh (KV heads over model), plus the serve identity gate on that mesh.

  PYTHONPATH=src python benchmarks/attn_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/attn_bench.py --smoke    # CI subset
  PYTHONPATH=src python benchmarks/attn_bench.py --smoke --mesh
"""

import argparse
import json
import os

try:
    from benchmarks.common import time_call, write_bench
except ImportError:  # executed as a loose script
    from common import time_call, write_bench


def _sweep_point(context, page, kv_bits, *, batch, hkv, group, dh, reps,
                 mesh=None):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention.ops import synthetic_paged_case
    from repro.models.attention import attend_paged_decode
    from repro.obs.costs import decode_attn_bytes

    rng = np.random.default_rng(0)
    hq = hkv * group
    nblk = max(1, -(-context // page))
    case = synthetic_paged_case(rng, batch=batch, nblk=nblk, page=page,
                                hkv=hkv, group=group, dh=dh,
                                kv_bits=kv_bits)
    q, kp, vp = case["q"], case["k_pages"], case["v_pages"]
    ks, vs, bt = case["k_scale"], case["v_scale"], case["block_tables"]
    pos = jnp.asarray(
        rng.integers(max(1, context // 2), context, (batch,)), jnp.int32)

    outs, secs = {}, {}
    for backend in ("gather", "pallas_interpret"):
        fn = jax.jit(lambda q, kp, vp, bt, pos, _b=backend:
                     attend_paged_decode(q, kp, vp, bt, pos, 0,
                                         k_scale=ks, v_scale=vs,
                                         attn_backend=_b, mesh=mesh))
        secs[backend] = time_call(fn, q, kp, vp, bt, pos, reps=reps,
                                  name=f"attn_{backend}")
        outs[backend] = np.asarray(fn(q, kp, vp, bt, pos))

    tol = 2e-2 if kv_bits else 2e-5
    close = bool(np.allclose(outs["gather"], outs["pallas_interpret"],
                             rtol=tol, atol=tol))
    model_kw = dict(batch=batch, context=nblk * page, n_kv_heads=hkv,
                    head_dim=dh, n_q_heads=hq, page_size=page,
                    kv_bits=kv_bits)
    gb = decode_attn_bytes("gather", **model_kw)
    fb = decode_attn_bytes("pallas_interpret", **model_kw)
    return {
        "context": context,
        "page_size": page,
        "kv_bits": kv_bits,
        "batch": batch,
        "n_kv_heads": hkv,
        "gqa_group": group,
        "head_dim": dh,
        "gather_us": round(secs["gather"] * 1e6, 1),
        "fused_us": round(secs["pallas_interpret"] * 1e6, 1),
        "gather_tok_per_s": round(batch / secs["gather"], 1),
        "fused_tok_per_s": round(batch / secs["pallas_interpret"], 1),
        "gather_bytes_per_tok": gb // batch,
        "fused_bytes_per_tok": fb // batch,
        "fused_over_gather_bytes": round(fb / gb, 4),
        "outputs_close": close,
    }


def _prefill_sweep_point(context, page, kv_bits, *, batch, hkv, group, dh,
                         chunk, reps, mesh=None):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention.ops import synthetic_prefill_case
    from repro.models.attention import attend_paged_prefill
    from repro.obs.costs import prefill_attn_bytes

    rng = np.random.default_rng(0)
    hq = hkv * group
    nblk = max(1, -(-context // page))
    case = synthetic_prefill_case(rng, batch=batch, nblk=nblk, page=page,
                                  hkv=hkv, group=group, dh=dh, chunk=chunk,
                                  kv_bits=kv_bits)
    q, kp, vp = case["q"], case["k_pages"], case["v_pages"]
    ks, vs, bt = case["k_scale"], case["v_scale"], case["block_tables"]
    pos0, seq = case["pos0"], case["seq_lens"]
    positions = pos0[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None]

    outs, secs = {}, {}
    for backend in ("gather", "pallas_interpret"):
        fn = jax.jit(lambda q, kp, vp, bt, _b=backend:
                     attend_paged_prefill(q, kp, vp, bt, positions, pos0,
                                          seq, 0, k_scale=ks, v_scale=vs,
                                          attn_backend=_b, mesh=mesh))
        secs[backend] = time_call(fn, q, kp, vp, bt, reps=reps,
                                  name=f"attn_pf_{backend}")
        outs[backend] = np.asarray(fn(q, kp, vp, bt))

    tol = 2e-2 if kv_bits else 2e-5
    close = bool(np.allclose(outs["gather"], outs["pallas_interpret"],
                             rtol=tol, atol=tol))
    model_kw = dict(batch=batch, chunk=chunk, context=nblk * page,
                    n_kv_heads=hkv, head_dim=dh, n_q_heads=hq,
                    page_size=page, kv_bits=kv_bits)
    gb = prefill_attn_bytes("gather", **model_kw)
    fb = prefill_attn_bytes("pallas_interpret", **model_kw)
    toks = batch * chunk
    return {
        "context": context,
        "page_size": page,
        "kv_bits": kv_bits,
        "batch": batch,
        "chunk": chunk,
        "n_kv_heads": hkv,
        "gqa_group": group,
        "head_dim": dh,
        "gather_us": round(secs["gather"] * 1e6, 1),
        "fused_us": round(secs["pallas_interpret"] * 1e6, 1),
        "gather_tok_per_s": round(toks / secs["gather"], 1),
        "fused_tok_per_s": round(toks / secs["pallas_interpret"], 1),
        "gather_bytes_per_tok": gb // toks,
        "fused_bytes_per_tok": fb // toks,
        "fused_over_gather_bytes": round(fb / gb, 4),
        "outputs_close": close,
    }


def _serve_identity(mesh=None):
    """Greedy tokens through the fused kernel == the gather backend on a
    reduced model (the end-to-end gate; mirrors tests/test_paged_attention
    so the bench stays honest when run standalone).  ``mesh``: run both
    backends on that mesh (the shard_mapped kernel vs gather)."""
    import dataclasses

    import jax

    from repro.config import get_reduced
    from repro.config.base import EngineConfig, ServeConfig
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = dataclasses.replace(get_reduced("qwen2.5-3b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3], [4], [5, 6, 7, 8]]
    # on a mesh, lanes shard over the data axis — size slots to it
    n_slots = 2 if mesh is None else max(2, mesh.devices.shape[0])

    def gen(abk):
        scfg = ServeConfig(max_new_tokens=6, engine=EngineConfig())
        eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=32,
                          mode="paged", page_size=4, prefill_chunk=3,
                          attn_backend=abk, mesh=mesh)
        for p in prompts:
            eng.submit(p)
        return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]

    return gen("gather") == gen("pallas_interpret")


def run(contexts=(64, 256, 1024), pages=(8, 16), kv_bits_sweep=(0, 8),
        batch=4, hkv=4, group=2, dh=64, chunk=16, reps=5,
        mesh_shape=None, out: str = "BENCH_attn.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns the
    repo-standard (name, us_per_call, derived) CSV rows.  ``mesh_shape``:
    a ``(data, model)`` tuple — adds shard_mapped sweep rows on that
    forced-host mesh and runs the serve identity gate on it too."""
    mesh = None
    if mesh_shape is not None:
        from repro.dist import make_mesh

        mesh = make_mesh(tuple(mesh_shape), ("data", "model"))

    def _rows_for(tag, r):
        return [(f"{tag}.gather", r["gather_us"],
                 f"bytes/tok={r['gather_bytes_per_tok']}"),
                (f"{tag}.fused", r["fused_us"],
                 f"bytes/tok={r['fused_bytes_per_tok']}"
                 f" ratio={r['fused_over_gather_bytes']}")]

    results, pf_results, rows = [], [], []
    for context in contexts:
        for page in pages:
            for kb in kv_bits_sweep:
                kw = dict(batch=batch, hkv=hkv, group=group, dh=dh,
                          reps=reps)
                r = _sweep_point(context, page, kb, **kw)
                results.append(r)
                tag = f"attn_c{context}_p{page}" + (f"_kv{kb}" if kb else "")
                rows += _rows_for(tag, r)
                pf = _prefill_sweep_point(context, page, kb, chunk=chunk,
                                          **kw)
                pf_results.append(pf)
                rows += _rows_for(f"attn_pf_c{context}_p{page}"
                                  + (f"_kv{kb}" if kb else ""), pf)
                if mesh is not None:
                    rs = _sweep_point(context, page, kb, mesh=mesh, **kw)
                    rs["mesh"] = list(mesh_shape)
                    results.append(rs)
                    rows += _rows_for(f"{tag}.sh", rs)
                    ps = _prefill_sweep_point(context, page, kb,
                                              chunk=chunk, mesh=mesh, **kw)
                    ps["mesh"] = list(mesh_shape)
                    pf_results.append(ps)
                    rows += _rows_for(f"attn_pf_c{context}_p{page}"
                                      + (f"_kv{kb}" if kb else "")
                                      + ".sh", ps)
    identical = _serve_identity()
    mesh_identical = _serve_identity(mesh) if mesh is not None else None
    every = results + pf_results
    record = {
        "bench": "attn",
        "note": ("CPU wall times run the kernel interpreted (machinery "
                 "check); the bytes gate is a self-consistency check of "
                 "the analytic decode/prefill_attn_bytes models, and "
                 "pallas_tpu on hardware validates the kernel's actual "
                 "traffic"),
        "mesh": list(mesh_shape) if mesh_shape else None,
        "results": results,
        "prefill_results": pf_results,
        "outputs_close_everywhere": all(r["outputs_close"] for r in every),
        "fused_fewer_bytes_everywhere": all(
            r["fused_bytes_per_tok"] < r["gather_bytes_per_tok"]
            for r in every),
        "token_identical": bool(identical),
        "token_identical_on_mesh": mesh_identical,
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: two contexts, one page size")
    ap.add_argument("--mesh", action="store_true",
                    help="add shard_mapped rows on a forced-host (4, 2) "
                         "(data, model) mesh (8 host devices)")
    ap.add_argument("--out", default="BENCH_attn.json")
    args = ap.parse_args()

    mesh_shape = None
    if args.mesh:
        # must land before the first jax import (lazy in the sweeps)
        if "xla_force_host_platform_device_count" not in os.environ.get(
                "XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=8 "
                + os.environ.get("XLA_FLAGS", ""))
        mesh_shape = (4, 2)

    if args.smoke:
        rows = run(contexts=(32, 128), pages=(8,), batch=4, hkv=2, group=2,
                   dh=16, chunk=6, reps=3, mesh_shape=mesh_shape,
                   out=args.out)
    else:
        rows = run(mesh_shape=mesh_shape, out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    print(f"# device_kind={record['device_kind']}  "
          f"interpret_mode={record['interpret_mode']}")
    if not record["fused_fewer_bytes_everywhere"]:
        raise SystemExit("fused path failed to beat gather's modeled "
                         "bytes/token at some sweep point")
    if not record["outputs_close_everywhere"]:
        raise SystemExit("fused kernel output diverged from gather")
    if not record["token_identical"]:
        raise SystemExit("fused greedy serving diverged from the gather "
                         "backend")
    if record["token_identical_on_mesh"] is False:
        raise SystemExit("shard_mapped fused serving diverged from the "
                         "gather backend on the mesh")
    print(f"# fused<gather bytes everywhere="
          f"{record['fused_fewer_bytes_everywhere']}  "
          f"token_identical={record['token_identical']}  "
          f"on_mesh={record['token_identical_on_mesh']}")


if __name__ == "__main__":
    main()
