#!/usr/bin/env python
"""Paged decode-attention benchmark: fused in-place kernel vs the gather
reference backend.

For each (context length × page size × kv_bits) sweep point the same
synthetic page pool + block tables are attended through

  * ``gather``           — materialize each lane's logical KV view, then
                           attend (the reference read path);
  * ``pallas_interpret`` — the fused kernel (``kernels.paged_attention``)
                           reading pool pages in place through the block
                           table (CPU hosts run the kernel body
                           interpreted — wall time is a machinery check,
                           like BENCH_shard's scaling curves; the perf
                           claim is the bytes-moved model, which a real
                           TPU run validates as ``pallas_tpu``).

Reported per point: per-call wall time / decode tok/s for both paths, the
modeled HBM bytes per decode token (``decode_attn_bytes``), and the
fused/gather byte ratio.  Two gates fail the run: the bytes-moved model
must put the fused path below gather at every context length >= one page
(a *self-consistency check of the analytic model* — both numbers come
from ``decode_attn_bytes``, so this guards edits to the model, not the
kernel's actual traffic, which is the real-TPU ROADMAP item), and greedy
serving through the fused kernel must be token-identical to the gather
backend (the behavioral gate — this one exercises the kernel).  Results
land in ``BENCH_attn.json``.

  PYTHONPATH=src python benchmarks/attn_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/attn_bench.py --smoke    # CI subset
"""

import argparse
import json

try:
    from benchmarks.common import time_call
except ImportError:  # executed as a loose script
    from common import time_call


def _sweep_point(context, page, kv_bits, *, batch, hkv, group, dh, reps):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.paged_attention.ops import (decode_attn_bytes,
                                                  synthetic_paged_case)
    from repro.models.attention import attend_paged_decode

    rng = np.random.default_rng(0)
    hq = hkv * group
    nblk = max(1, -(-context // page))
    case = synthetic_paged_case(rng, batch=batch, nblk=nblk, page=page,
                                hkv=hkv, group=group, dh=dh,
                                kv_bits=kv_bits)
    q, kp, vp = case["q"], case["k_pages"], case["v_pages"]
    ks, vs, bt = case["k_scale"], case["v_scale"], case["block_tables"]
    pos = jnp.asarray(
        rng.integers(max(1, context // 2), context, (batch,)), jnp.int32)

    outs, secs = {}, {}
    for backend in ("gather", "pallas_interpret"):
        fn = jax.jit(lambda q, kp, vp, bt, pos, _b=backend:
                     attend_paged_decode(q, kp, vp, bt, pos, 0,
                                         k_scale=ks, v_scale=vs,
                                         attn_backend=_b))
        secs[backend] = time_call(fn, q, kp, vp, bt, pos, reps=reps,
                                  name=f"attn_{backend}")
        outs[backend] = np.asarray(fn(q, kp, vp, bt, pos))

    tol = 2e-2 if kv_bits else 2e-5
    close = bool(np.allclose(outs["gather"], outs["pallas_interpret"],
                             rtol=tol, atol=tol))
    model_kw = dict(batch=batch, context=nblk * page, n_kv_heads=hkv,
                    head_dim=dh, n_q_heads=hq, page_size=page,
                    kv_bits=kv_bits)
    gb = decode_attn_bytes("gather", **model_kw)
    fb = decode_attn_bytes("pallas_interpret", **model_kw)
    return {
        "context": context,
        "page_size": page,
        "kv_bits": kv_bits,
        "batch": batch,
        "n_kv_heads": hkv,
        "gqa_group": group,
        "head_dim": dh,
        "gather_us": round(secs["gather"] * 1e6, 1),
        "fused_us": round(secs["pallas_interpret"] * 1e6, 1),
        "gather_tok_per_s": round(batch / secs["gather"], 1),
        "fused_tok_per_s": round(batch / secs["pallas_interpret"], 1),
        "gather_bytes_per_tok": gb // batch,
        "fused_bytes_per_tok": fb // batch,
        "fused_over_gather_bytes": round(fb / gb, 4),
        "outputs_close": close,
    }


def _serve_identity():
    """Greedy tokens through the fused kernel == the gather backend on a
    reduced model (the end-to-end gate; mirrors tests/test_paged_attention
    so the bench stays honest when run standalone)."""
    import dataclasses

    import jax

    from repro.config import get_reduced
    from repro.config.base import EngineConfig, ServeConfig
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = dataclasses.replace(get_reduced("qwen2.5-3b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3], [4], [5, 6, 7, 8]]

    def gen(abk):
        scfg = ServeConfig(max_new_tokens=6, engine=EngineConfig())
        eng = ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                          mode="paged", page_size=4, prefill_chunk=3,
                          attn_backend=abk)
        for p in prompts:
            eng.submit(p)
        return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]

    return gen("gather") == gen("pallas_interpret")


def run(contexts=(64, 256, 1024), pages=(8, 16), kv_bits_sweep=(0, 8),
        batch=4, hkv=4, group=2, dh=64, reps=5,
        out: str = "BENCH_attn.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns the
    repo-standard (name, us_per_call, derived) CSV rows."""
    results, rows = [], []
    for context in contexts:
        for page in pages:
            for kb in kv_bits_sweep:
                r = _sweep_point(context, page, kb, batch=batch, hkv=hkv,
                                 group=group, dh=dh, reps=reps)
                results.append(r)
                tag = f"attn_c{context}_p{page}" + (f"_kv{kb}" if kb else "")
                rows.append((f"{tag}.gather", r["gather_us"],
                             f"bytes/tok={r['gather_bytes_per_tok']}"))
                rows.append((f"{tag}.fused", r["fused_us"],
                             f"bytes/tok={r['fused_bytes_per_tok']}"
                             f" ratio={r['fused_over_gather_bytes']}"))
    identical = _serve_identity()
    record = {
        "bench": "attn",
        "note": ("CPU wall times run the kernel interpreted (machinery "
                 "check); the bytes gate is a self-consistency check of "
                 "the analytic decode_attn_bytes model, and pallas_tpu on "
                 "hardware validates the kernel's actual traffic"),
        "results": results,
        "outputs_close_everywhere": all(r["outputs_close"] for r in results),
        "fused_fewer_bytes_everywhere": all(
            r["fused_bytes_per_tok"] < r["gather_bytes_per_tok"]
            for r in results),
        "token_identical": bool(identical),
    }
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: two contexts, one page size")
    ap.add_argument("--out", default="BENCH_attn.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(contexts=(32, 128), pages=(8,), batch=2, hkv=2, group=2,
                   dh=16, reps=3, out=args.out)
    else:
        rows = run(out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["fused_fewer_bytes_everywhere"]:
        raise SystemExit("fused path failed to beat gather's modeled "
                         "bytes/token at some sweep point")
    if not record["outputs_close_everywhere"]:
        raise SystemExit("fused kernel output diverged from gather")
    if not record["token_identical"]:
        raise SystemExit("fused greedy serving diverged from the gather "
                         "backend")
    print(f"# fused<gather bytes everywhere="
          f"{record['fused_fewer_bytes_everywhere']}  "
          f"token_identical={record['token_identical']}")


if __name__ == "__main__":
    main()
