"""Paper Fig. 6 — GEMV cycle latency (a) and execution time (b) versus
square-matrix dimension, for IMAGine / IMAGine-slice4 / CCB / CoMeFa /
SPAR-2 / BRAMAC, at 8-bit precision (plus 4/16-bit latency sweeps)."""

from repro.core.latency_model import FIG6_DESIGNS, execution_time_us

DIMS = [64, 128, 256, 512, 1024, 2048]


def run():
    rows = []
    for p in (4, 8, 16):
        for name, (fn, f_mhz) in FIG6_DESIGNS.items():
            cyc = [fn(d, p) for d in DIMS]
            rows.append((f"fig6a.p{p}.{name}", "",
                         "cycles@" + "/".join(map(str, DIMS)) + "="
                         + "/".join(map(str, cyc))))
    for name in FIG6_DESIGNS:
        try:
            times = [round(execution_time_us(name, d, 8), 1) for d in DIMS]
        except ValueError:
            continue  # BRAMAC: no reported f_sys
        rows.append((f"fig6b.{name}", "",
                     "exec_us@" + "/".join(map(str, DIMS)) + "="
                     + "/".join(map(str, times))))
    # headline: IMAGine wins execution time at every dim
    wins = all(
        execution_time_us("IMAGine", d) < min(
            execution_time_us(n, d) for n in ("CCB", "CoMeFa", "SPAR-2"))
        for d in DIMS)
    rows.append(("fig6b.imagine_fastest_exec", "", str(wins)))
    return rows
