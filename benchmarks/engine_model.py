"""IMAGine engine end-to-end: the executable ISA/controller model runs an
exact integer GEMV and its cycle count feeds the latency model; the same
GEMV through the TPU engine (bit-plane path) is validated for equality.

Derived columns give the paper's own figures of merit: cycles, execution
time at 737 MHz, and effective MAC/s for the FPGA overlay, plus the memory
roofline time for the equivalent TPU decode GEMV."""

import numpy as np

from repro.core.controller import CycleModel, run_gemv
from repro.core.latency_model import IMAGINE_FSYS_MHZ, U55
from repro.roofline.analysis import HW_V5E


def run():
    rows = []
    rng = np.random.default_rng(0)
    for dim, rows_pe, cols_pe in ((64, 16, 8), (128, 32, 8), (240, 16, 16)):
        w = rng.integers(-127, 128, size=(dim, dim))
        x = rng.integers(-127, 128, size=(dim,))
        res = run_gemv(w, x, rows=rows_pe, cols=cols_pe)
        exact = bool(np.array_equal(res.y, w @ x))
        us = res.cycles / IMAGINE_FSYS_MHZ
        macs = dim * dim
        rows.append((
            f"engine.isa_gemv.d{dim}", round(us, 2),
            f"cycles={res.cycles} instrs={res.instrs} exact={exact}"
            f" mac_per_cycle={macs / res.cycles:.2f}"))

    # device-level: full-U55 GEMV at max occupancy vs one v5e chip's HBM
    # roofline for the same int8 weight matrix (the TPU adaptation)
    cm = CycleModel(precision=8)
    dim = 1967  # max resident square GEMV on U55 (tile_array capacity)
    pes = U55.max_pes
    elems = -(-dim * dim // pes)
    fpga_cycles = elems * cm.mac() + cm.accum(32) + dim
    fpga_us = fpga_cycles / IMAGINE_FSYS_MHZ
    tpu_us = (dim * dim * 1) / HW_V5E["hbm_bw"] * 1e6  # int8 weights, 1B/w
    rows.append(("engine.u55_vs_v5e_gemv.d1967", round(fpga_us, 1),
                 f"fpga_cycles={fpga_cycles}"
                 f" v5e_hbm_bound_us={tpu_us:.2f}"
                 f" note=same_weight_stationary_int8_gemv"))
    return rows
