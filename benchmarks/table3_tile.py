"""Paper Table III — GEMV tile component utilization and frequency."""

from repro.core.latency_model import TABLE_III


def run():
    rows = []
    tile = TABLE_III["tile"]
    for comp, (lut, ff, dsp, bram, freq) in TABLE_III.items():
        rel_lut = round(lut / tile[0], 3) if tile[0] else 0
        rows.append((f"table3.{comp}", "",
                     f"lut={lut} ff={ff} dsp={dsp} bram={bram}"
                     f" freq={freq}MHz rel_lut={rel_lut}"))
    # the paper's claim: controller+fanout are not the bottleneck
    ctrl = TABLE_III["controller"][4]
    pim = TABLE_III["pim_array"][4]
    rows.append(("table3.check.controller_faster_than_pim", "",
                 f"{ctrl}>{pim}={ctrl > pim}"))
    return rows
