#!/usr/bin/env python
"""Mesh-native serving benchmark: paged decode tokens/sec vs mesh size.

Forces a multi-device CPU host (``xla_force_host_platform_device_count``,
set before jax imports) and serves the same request stream through the
paged engine on a ladder of ``(data, model)`` meshes:

  * ``mesh=None``       — the single-device paged baseline;
  * ``(1, m)``          — model-parallel only: KV heads + TP weights over
                          ``model`` (GEMV bit-planes spread over banks,
                          the paper's scaling axis);
  * ``(d, m)``          — full production layout: lanes + pages over
                          ``data`` on top.

Every mesh point must produce *token-identical* greedy output to the
baseline (the correctness gate — pages and shards move bytes, never
tokens); tokens/sec per mesh is recorded in ``BENCH_shard.json``.  Host
CPU "devices" share the same cores, so absolute scaling here only smoke-
checks the machinery — the recorded curve is the artifact the real-TPU
run fills in.

The full run adds a ``sharded``-backend point (int8 weights shard_mapped
over ``model``, ``EngineConfig.sharded=True``).

  PYTHONPATH=src python benchmarks/shard_bench.py            # full ladder
  PYTHONPATH=src python benchmarks/shard_bench.py --smoke    # CI
"""

import argparse
import dataclasses
import os

N_DEV = int(os.environ.get("SHARD_BENCH_DEVICES", "8"))
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEV} "
        + os.environ.get("XLA_FLAGS", ""))


def _build(arch: str):
    import jax

    from repro.config import get_reduced
    from repro.models import init_params

    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, prompts, *, mesh=None, max_new: int, n_slots: int,
           max_len: int = 64, engine=None, page_size: int = 8,
           prefill_chunk: int = 16):
    from repro.config.base import EngineConfig, ServeConfig
    from repro.serve import ServeEngine

    try:
        from benchmarks.common import wall_timer
    except ImportError:  # executed as a loose script
        from common import wall_timer

    scfg = ServeConfig(max_new_tokens=max_new,
                       engine=engine or EngineConfig(),
                       page_size=page_size, prefill_chunk=prefill_chunk)
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode="paged", mesh=mesh)
    eng.submit(prompts[0][:4], max_new_tokens=2)   # warm the jits
    eng.run()
    for p in prompts:
        eng.submit(p)
    mesh_tag = "1dev" if mesh is None else "x".join(map(str, mesh.devices.shape))
    with wall_timer(f"shard_serve_{mesh_tag}") as w:
        done = eng.run()
    wall = w.wall
    gen = sum(len(r.output) for r in done)
    return {
        "gen_tokens": gen,
        "wall_s": round(wall, 4),
        "tok_per_s": round(gen / wall, 2) if wall > 0 else 0.0,
    }, {r.rid: r.output for r in done}


def run(meshes=((1, 2), (1, 4), (1, 8), (2, 4)), arch: str = "qwen2.5-3b",
        n_slots: int = 4, n_reqs: int = 8, prompt_len: int = 8,
        max_new: int = 8, with_sharded_weights: bool = True,
        out: str = "BENCH_shard.json"):
    """Returns the repo-standard (name, us_per_call, derived) CSV rows."""
    from repro.dist import make_mesh

    try:
        from benchmarks.common import write_bench
    except ImportError:  # executed as a loose script
        from common import write_bench

    cfg, params = _build(arch)
    prompts = [
        [(7 * i + j) % cfg.vocab_size for j in range(prompt_len + i % 4)]
        for i in range(n_reqs)
    ]
    results, rows = [], []

    base_res, base_out = _serve(cfg, params, prompts, mesh=None,
                                max_new=max_new, n_slots=n_slots)
    results.append({"mesh": None, "mode": "paged", **base_res})
    rows.append(("shard_serve_1dev",
                 round(1e6 * base_res["wall_s"]
                       / max(base_res["gen_tokens"], 1), 1),
                 f"tok/s={base_res['tok_per_s']}"))

    identical = True
    for shape in meshes:
        mesh = make_mesh(tuple(shape), ("data", "model"))
        res, outs = _serve(cfg, params, prompts, mesh=mesh,
                           max_new=max_new, n_slots=n_slots)
        identical &= outs == base_out
        results.append({"mesh": list(shape), "mode": "paged", **res})
        name = f"shard_serve_{shape[0]}x{shape[1]}"
        rows.append((name,
                     round(1e6 * res["wall_s"]
                           / max(res["gen_tokens"], 1), 1),
                     f"tok/s={res['tok_per_s']}"))

    if with_sharded_weights:
        from repro.config.base import EngineConfig

        shape = tuple(meshes[-1])
        mesh = make_mesh(shape, ("data", "model"))
        eng8 = EngineConfig(weight_bits=8, backend="reference")
        ref_res, ref_out = _serve(cfg, params, prompts, mesh=None,
                                  max_new=max_new, n_slots=n_slots,
                                  engine=eng8)
        res, outs = _serve(
            cfg, params, prompts, mesh=mesh, max_new=max_new,
            n_slots=n_slots,
            engine=dataclasses.replace(eng8, sharded=True))
        identical &= outs == ref_out
        results.append({"mesh": list(shape), "mode": "paged_sharded_w8",
                        **res})
        results.append({"mesh": None, "mode": "paged_w8", **ref_res})
        rows.append((f"shard_serve_w8_{shape[0]}x{shape[1]}",
                     round(1e6 * res["wall_s"]
                           / max(res["gen_tokens"], 1), 1),
                     f"tok/s={res['tok_per_s']}"))

    record = {
        "bench": "shard",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "host_devices": N_DEV,
        "workload": {"n_slots": n_slots, "n_reqs": n_reqs,
                     "prompt_len": prompt_len, "max_new": max_new},
        "results": results,
        "token_identical": bool(identical),
        "tok_per_s_by_mesh": {
            ("1dev" if r["mesh"] is None else "x".join(map(str, r["mesh"])))
            + ("" if r["mode"] == "paged" else f":{r['mode']}"):
                r["tok_per_s"]
            for r in results
        },
    }
    write_bench(out, record)
    return rows, record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: one mesh point, short generations")
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_args()

    if args.smoke:
        rows, record = run(meshes=((2, 4),), max_new=6, n_reqs=4,
                           with_sharded_weights=False, out=args.out)
    else:
        rows, record = run(out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))
    if not record["token_identical"]:
        raise SystemExit(
            "sharded paged outputs diverged from the single-device engine")
    print(f"# tok/s by mesh: {record['tok_per_s_by_mesh']}  "
          f"token_identical={record['token_identical']}")


if __name__ == "__main__":
    main()
