"""Paper Fig. 4 / Table IV — 100% BRAM-as-PIM scaling across devices.

For every representative Virtex-7/UltraScale+ device: PE count at 100% BRAM
utilization and the geometry's BRAM coverage."""

from repro.core.latency_model import TABLE_IV
from repro.core.tile_array import BRAMS_PER_TILE, TileArrayGeometry


def run():
    rows = []
    for dev in TABLE_IV:
        g = TileArrayGeometry(dev)
        coverage = g.n_tiles * BRAMS_PER_TILE / dev.brams
        rows.append((
            f"fig4.{dev.short_id}", "",
            f"brams={dev.brams} ratio={dev.lut_bram_ratio}"
            f" max_pe={dev.max_pes} tiles={g.n_tiles}"
            f" bram_coverage={coverage:.3f}"
            f" max_gemv_dim={g.max_square_gemv(8)}"))
    return rows
