#!/usr/bin/env python
"""Cost-ledger benchmark: modeled vs compiler-measured traffic, and the
ledger's own overhead.

Two questions, one bench:

1. **Is the model honest?**  For one paged decode step and one chunked
   prefill at the bench shapes, the analytic ``repro.obs.costs`` tables
   are compared against what XLA actually compiled —
   ``jax.jit(...).lower().compile().cost_analysis()`` routed through
   ``repro.roofline.analysis.compiled_costs`` (trip-count-aware HLO
   reanalysis; XLA's own counter visits scan bodies once).  Modeled vs
   measured FLOPs and bytes/token are recorded per attention backend
   (``gather`` and the fused kernel).  The hard 5% FLOPs gate lives in
   ``tests/test_costs.py``; this bench records the same comparison at
   bench scale.  On a non-TPU host the fused backend runs the Pallas
   *interpreter*, whose compiled HLO measures the interpreter loop, not
   the kernel — its measured column is recorded but carries
   ``measured_is_interpreter: true`` and is compared on bytes only
   informationally.

2. **Is the ledger free enough?**  The identical closed-loop workload is
   served with the ledger off (``NULL_TELEMETRY``) and on
   (``Telemetry(trace=False)`` — metrics + cost ledger, the production
   configuration), reps interleaved, best-of-reps compared, and the
   ledger-on/ledger-off tok/s ratio recorded.  A second byte-identical
   ledger-off arm runs interleaved with the other two and its spread
   against the first is recorded as a *noise witness*: on shared CI
   hosts two identical arms routinely differ by 5-10% (measured here),
   so the end-to-end ratio is informational.  The **enforced** 3%
   overhead gate is deterministic instead: the telemetry hot-path calls
   (``on_costs`` with the engine's real cost table, ``on_token``, the
   step frame) are microbenchmarked in a tight loop, scaled by the
   serve run's actual call counts, and the implied µs/token is compared
   against 3% of the ledger-off per-token wall.  That measures the code
   being gated — not the host's scheduler luck — and still fails hard
   if a change makes the charge path an order of magnitude slower.
   Token identity between arms is asserted always.

Results land in ``BENCH_costs.json`` plus the repo-standard CSV rows.

  PYTHONPATH=src python benchmarks/costs_bench.py            # full run
  PYTHONPATH=src python benchmarks/costs_bench.py --smoke    # CI-sized
"""

import argparse
import functools
import gc
import json

try:
    from benchmarks.common import (build_model, make_engine,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import build_model, make_engine, wall_timer, write_bench

OVERHEAD_BUDGET = 0.03  # ledger-on may cost at most 3% tok/s

# decode/prefill validation shapes (mirrors the serve bench geometry)
B, PAGE, NBLK, CHUNK = 4, 8, 4, 16


def _workload(cfg, n_reqs: int, prompt_len: int):
    return [
        [(5 * i + j) % cfg.vocab_size for j in range(prompt_len + i % 4)]
        for i in range(n_reqs)
    ]


def _serve_once(cfg, params, prompts, telemetry, tag, *, n_slots, max_len,
                max_new):
    eng = make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                      max_new=max_new, telemetry=telemetry)
    for p in prompts:
        eng.submit(list(p))
    # GC pauses (10-30ms) would swamp the 3% overhead gate at these
    # ~85ms serve walls; collect up front, then keep the cycle collector
    # out of the timed region
    gc.collect()
    gc.disable()
    try:
        with wall_timer(None) as w:
            done = eng.run()
    finally:
        gc.enable()
    gen = sum(len(r.output) for r in done)
    outs = {r.rid: r.output for r in done}
    return {
        "arm": tag,
        "gen_tokens": gen,
        "wall_s": round(w.wall, 5),
        "tok_per_s": round(gen / w.wall, 2) if w.wall > 0 else 0.0,
    }, outs, eng


def ledger_us_per_token(cfg, *, n_slots: int, max_len: int, page_size: int,
                        tokens_per_step: float, charges_per_step: float,
                        loops: int = 2000, reps: int = 3):
    """Deterministic per-token cost of the telemetry hot path.

    Microbenchmarks the calls the serve loop makes per step — one
    ``on_costs`` charge of the real memoized decode table per dispatch,
    the step frame (``step_begin``/``step_end`` + ``on_decode``), and
    one ``on_token`` per generated token — then scales by the measured
    call rates of the serve run.  Pure-python tight loops: stable to a
    few percent where the end-to-end A/B is stable to ~10% (see module
    docstring).
    """
    from repro.obs import Telemetry, clock, costs

    tel = Telemetry(trace=False)
    dims = costs.model_dims(cfg)
    table = costs.decode_step_costs(
        dims, batch=n_slots, context=max_len, page_size=page_size)
    rids = list(range(n_slots))
    t = clock.now()
    for rid in rids:
        tel.on_submit(rid, 8, t)
    lanes = [(s, rid) for s, rid in enumerate(rids)]

    def loop_us(fn):
        best = None
        for _ in range(reps):
            t0 = clock.now()
            for _ in range(loops):
                fn()
            dt = clock.now() - t0
            best = dt if best is None else min(best, dt)
        return 1e6 * best / loops

    us_costs = loop_us(lambda: tel.on_costs(table, rids))
    us_token = loop_us(lambda: tel.on_token(rids[0], clock.now()))
    def step_frame():
        tel.step_begin()
        tel.on_decode(lanes, clock.now())
        tel.step_end(clock.now())
    us_step = loop_us(step_frame)
    per_tok = ((us_costs * charges_per_step + us_step)
               / max(tokens_per_step, 1e-9)) + us_token
    return {
        "us_on_costs": round(us_costs, 3),
        "us_on_token": round(us_token, 3),
        "us_step_frame": round(us_step, 3),
        "charges_per_step": round(charges_per_step, 3),
        "tokens_per_step": round(tokens_per_step, 3),
        "us_per_token": round(per_tok, 3),
    }


def modeled_vs_measured(cfg, kv_bits: int = 0):
    """Modeled (obs.costs) vs compiled (HLO) FLOPs and bytes/token for
    one decode step and one prefill chunk, per attention backend."""
    import jax
    import jax.numpy as jnp

    from repro.engine.backends import default_interpret
    from repro.models import decode_step_paged, init_params, prefill_chunk
    from repro.obs import costs
    from repro.roofline.analysis import compiled_costs
    from repro.serve.pages import init_kv_pages

    dims = costs.model_dims(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pages = init_kv_pages(cfg, B * NBLK + 1, PAGE, kv_bits=kv_bits)
    bt = jnp.arange(1, 1 + B * NBLK, dtype=jnp.int32).reshape(B, NBLK)
    ctx = NBLK * PAGE
    fused = "pallas_interpret" if default_interpret() else "pallas_tpu"
    rows = []
    for backend in ("gather", fused):
        interp = backend == "pallas_interpret"
        for phase in ("decode", "prefill"):
            if phase == "decode":
                fn = jax.jit(functools.partial(
                    decode_step_paged, cfg=cfg, eng=None,
                    attn_backend=backend))
                args = (params, pages, bt, jnp.full((B,), 5, jnp.int32),
                        jnp.ones((B,), bool), jnp.zeros((B, 1), jnp.int32))
                table = costs.decode_step_costs(
                    dims, batch=B, context=ctx, page_size=PAGE,
                    attn_backend=backend, kv_bits=kv_bits)
                toks = B
            else:
                fn = jax.jit(functools.partial(
                    prefill_chunk, cfg=cfg, eng=None, attn_backend=backend))
                args = (params, pages, bt, jnp.zeros((B, CHUNK), jnp.int32),
                        jnp.zeros((B,), jnp.int32),
                        jnp.full((B,), CHUNK, jnp.int32))
                table = costs.prefill_chunk_costs(
                    dims, batch=B, chunk=CHUNK, context=ctx,
                    page_size=PAGE, attn_backend=backend, kv_bits=kv_bits)
                toks = B * CHUNK
            meas = compiled_costs(fn.lower(*args).compile())
            model = costs.total_cost(table)
            rows.append({
                "phase": phase,
                "attn_backend": backend,
                "kv_bits": kv_bits,
                "tokens": toks,
                "modeled_flops": model.flops,
                "measured_flops": meas["flops"],
                "flops_ratio": round(
                    model.flops / max(meas["flops"], 1.0), 4),
                "modeled_bytes_per_tok": round(model.bytes / toks, 1),
                "measured_bytes_per_tok": round(meas["bytes"] / toks, 1),
                "measured_is_interpreter": interp,
            })
    return rows


def run(arch: str = "qwen2.5-3b", n_reqs: int = 16, n_slots: int = 4,
        prompt_len: int = 12, max_new: int = 8, max_len: int = 64,
        reps: int = 6, out: str = "BENCH_costs.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns
    the repo-standard (name, us_per_call, derived) CSV rows."""
    from repro.obs import Telemetry
    from repro.obs.telemetry import NULL_TELEMETRY

    cfg, params = build_model(arch)
    prompts = _workload(cfg, n_reqs, prompt_len)
    kw = dict(n_slots=n_slots, max_len=max_len, max_new=max_new)

    # "off2" is byte-identical to "off": the pair calibrates how much two
    # arms that *cannot* differ still differ on this host (see docstring)
    arms = {
        "off": lambda: NULL_TELEMETRY,
        "ledger": lambda: Telemetry(trace=False),
        "off2": lambda: NULL_TELEMETRY,
    }
    # one throwaway pass warms process-global jit state for everyone
    _serve_once(cfg, params, prompts[:2], NULL_TELEMETRY, "warm", **kw)

    best = {}
    outs = {}
    ledger = None
    obs_snap = None
    for _ in range(reps):
        for tag, mk in arms.items():  # interleaved off/ledger/off2
            tel = mk()
            res, o, eng = _serve_once(cfg, params, prompts, tel, tag, **kw)
            outs.setdefault(tag, o)
            assert o == outs[tag], f"{tag} arm tokens drifted across reps"
            if tag not in best or res["wall_s"] < best[tag]["wall_s"]:
                best[tag] = res
            if tag == "ledger":
                m = eng.metrics()
                ledger = m["costs"]
                obs_snap = m["obs"]

    identical = outs["off"] == outs["ledger"] == outs["off2"]
    tok_off = best["off"]["tok_per_s"]
    tok_on = best["ledger"]["tok_per_s"]
    w_nulls = (best["off"]["wall_s"], best["off2"]["wall_s"])
    null_spread = max(w_nulls) / min(w_nulls)

    # deterministic overhead gate: microbench the hot path, scale by the
    # serve run's actual call rates (see module docstring for why the
    # end-to-end ratio above is recorded but not gated)
    snap = obs_snap["metrics"]
    n_steps = max(snap["counters"].get("serve_steps_total", 1), 1)
    n_decode = snap["histograms"].get(
        "serve_decode_step_s", {}).get("count", 0)
    n_prefill = snap["histograms"].get(
        "serve_prefill_chunk_s", {}).get("count", 0)
    gen_led = max(best["ledger"]["gen_tokens"], 1)
    micro = ledger_us_per_token(
        cfg, n_slots=n_slots, max_len=max_len, page_size=8,
        tokens_per_step=gen_led / n_steps,
        charges_per_step=(n_decode + n_prefill) / n_steps)
    off_us_per_tok = 1e6 * best["off"]["wall_s"] / max(
        best["off"]["gen_tokens"], 1)
    overhead_share = micro["us_per_token"] / off_us_per_tok
    overhead_ok = overhead_share <= OVERHEAD_BUDGET

    validation = []
    for kv_bits in (0, 8):
        validation.extend(modeled_vs_measured(cfg, kv_bits))
    gen = max(best["ledger"]["gen_tokens"], 1)

    rows = [
        (f"costs_{tag}",
         round(1e6 * r["wall_s"] / max(r["gen_tokens"], 1), 1),
         f"tok/s={r['tok_per_s']}")
        for tag, r in best.items()
    ]
    rows += [
        (f"costs.model.{v['phase']}.{v['attn_backend']}.kv{v['kv_bits']}",
         "",
         f"flops_ratio={v['flops_ratio']}"
         f" modeled_B/tok={v['modeled_bytes_per_tok']}"
         f" measured_B/tok={v['measured_bytes_per_tok']}")
        for v in validation
    ]
    record = {
        "bench": "costs",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs": n_reqs, "n_slots": n_slots,
                     "prompt_len": prompt_len, "max_new": max_new,
                     "max_len": max_len, "reps": reps},
        "results": list(best.values()),
        "ledger_over_off_tok_per_s": round(tok_on / max(tok_off, 1e-9), 4),
        "overhead_budget": OVERHEAD_BUDGET,
        "null_spread": round(null_spread, 4),
        "ledger_microbench": micro,
        "off_us_per_token": round(off_us_per_tok, 1),
        "ledger_overhead_share": round(overhead_share, 4),
        "overhead_within_budget": bool(overhead_ok),
        "token_identical": bool(identical),
        "ledger": {
            "total_flops": ledger["total_flops"],
            "total_bytes": ledger["total_bytes"],
            "wasted_flops": ledger["wasted_flops"],
            "ledger_bytes_per_tok": round(ledger["total_bytes"] / gen, 1),
            "by_op": ledger["by_op"],
        },
        "modeled_vs_measured": validation,
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, short generations")
    ap.add_argument("--out", default="BENCH_costs.json")
    args = ap.parse_args()

    if args.smoke:
        # the 3% overhead gate needs per-rep serve walls long enough
        # (~300ms) that scheduler jitter spikes dilute — short 8-req
        # walls made best-of-reps flicker across the budget line
        rows = run(n_reqs=16, max_new=16, reps=8, out=args.out)
    else:
        rows = run(out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["token_identical"]:
        raise SystemExit("the cost ledger changed the generated tokens")
    if not record["overhead_within_budget"]:
        raise SystemExit(
            f"ledger hot path costs {record['ledger_overhead_share']:.2%} "
            f"of a serve token "
            f"({record['ledger_microbench']['us_per_token']}us vs "
            f"{record['off_us_per_token']}us/token) — over the "
            f"{record['overhead_budget']:.0%} overhead budget")
    bad = [v for v in record["modeled_vs_measured"]
           if not v["measured_is_interpreter"]
           and not 0.95 <= v["flops_ratio"] <= 1.05]
    if bad:
        raise SystemExit(f"modeled FLOPs off by >5% vs compiled: {bad}")
    print(f"# ledger/off tok/s={record['ledger_over_off_tok_per_s']}  "
          f"null_spread={record['null_spread']}  "
          f"overhead_share={record['ledger_overhead_share']}  "
          f"token_identical={record['token_identical']}  "
          f"validated={len(record['modeled_vs_measured'])} points")


if __name__ == "__main__":
    main()
