# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure regenerated, plus kernel
micro-benchmarks and the TPU roofline summary.

  python -m benchmarks.run            # all benches, CSV on stdout
  python -m benchmarks.run fig6       # one bench
"""

import sys


def main() -> None:
    from benchmarks import (
        attn_bench,
        chaos_bench,
        costs_bench,
        engine_model,
        fig4_scaling,
        fig6_latency,
        kernel_bench,
        load_bench,
        obs_bench,
        prefix_bench,
        roofline_summary,
        serve_bench,
        table1_fmax,
        table3_tile,
        table5_freq,
    )

    benches = {
        "table1": table1_fmax.run,
        "table3": table3_tile.run,
        "fig4": fig4_scaling.run,
        "table5": table5_freq.run,
        "fig6": fig6_latency.run,
        "kernels": kernel_bench.run,
        "engine": engine_model.run,
        "roofline": roofline_summary.run,
        "serve": serve_bench.run,
        "attn": attn_bench.run,
        "prefix": prefix_bench.run,
        "load": load_bench.run,
        "obs": obs_bench.run,
        "chaos": chaos_bench.run,
        "costs": costs_bench.run,
    }
    from benchmarks.common import bench_env

    env = bench_env()
    print(f"# device_kind={env['device_kind']}  "
          f"interpret_mode={env['interpret_mode']}")
    picked = sys.argv[1:] or list(benches)
    print("name,us_per_call,derived")
    for name in picked:
        if name not in benches:
            raise SystemExit(f"unknown bench {name!r}; have {sorted(benches)}")
        for row in benches[name]():
            print(",".join(str(v) for v in row))


if __name__ == "__main__":
    main()
