#!/usr/bin/env python
"""Serving benchmark: paged continuous batching vs the fixed-slot engine.

For each batch size (= decode lanes), a stream of prompts is served through

  * ``slots`` — the legacy fixed-slot engine (per-token prompt prefill,
    fixed ``n_slots × max_len`` cache rectangle);
  * ``paged`` — the paged-KV engine (batched chunked prefill through
    ``prefill_chunk``, block-table decode, capacity-based admission);
  * ``paged_kv8`` — paged with ``EngineConfig.kv_bits=8`` int8 KV pages.

and the run reports generated tokens/sec, time-to-first-token, and the KV
memory each mode holds.  Results land in ``BENCH_serve.json`` (the serving
entry of the bench trajectory) plus the repo-standard CSV rows on stdout.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke    # CI: batch 4
"""

import argparse
import json

try:
    from benchmarks.common import (build_model, make_engine, tree_bytes,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import (build_model, make_engine, tree_bytes, wall_timer,
                        write_bench)


def _workload(cfg, batch: int, n_reqs: int, prompt_len: int,
              max_new: int):
    prompts = [
        [(7 * i + j) % cfg.vocab_size for j in range(prompt_len + i % 4)]
        for i in range(n_reqs)
    ]
    return prompts, max_new


def _serve(cfg, params, mode: str, batch: int, prompts, max_new: int,
           max_len: int, kv_bits: int = 0, page_size: int = 8,
           prefill_chunk: int = 16, n_pages: int = 0):
    # warm=True: fresh closures per engine would otherwise bill
    # compilation to the first mode measured
    eng = make_engine(cfg, params, n_slots=batch, max_len=max_len,
                      mode=mode, max_new=max_new, kv_bits=kv_bits,
                      page_size=page_size, prefill_chunk=prefill_chunk,
                      n_pages=n_pages)

    for p in prompts:
        eng.submit(p)
    with wall_timer(f"serve_{mode}_b{batch}") as w:
        done = eng.run()
    wall = w.wall

    gen = sum(len(r.output) for r in done)
    pre = sum(len(r.prompt) for r in done)
    ttfts = [r.ttft for r in done if r.ttft is not None]
    kv_bytes = (eng.pages.nbytes() if mode == "paged"
                else tree_bytes(eng.cache))
    outputs = {r.rid: r.output for r in done}
    return {
        "mode": mode + (f"_kv{kv_bits}" if kv_bits else ""),
        "batch": batch,
        "kv_bits": kv_bits,
        "requests": len(done),
        "prompt_tokens": pre,
        "gen_tokens": gen,
        "wall_s": round(wall, 4),
        "tok_per_s": round(gen / wall, 2) if wall > 0 else 0.0,
        "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4) if ttfts else None,
        "kv_bytes": int(kv_bytes),
        "preemptions": eng.metrics()["preemptions"],
    }, outputs


def run(batches=(1, 2, 4), arch: str = "qwen2.5-3b", n_reqs_per_lane: int = 2,
        prompt_len: int = 8, max_new: int = 8, max_len: int = 64,
        with_kv8: bool = True, out: str = "BENCH_serve.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns the
    repo-standard (name, us_per_call, derived) CSV rows."""
    cfg, params = build_model(arch)
    results, rows = [], []
    identical = True
    for batch in batches:
        prompts, _ = _workload(cfg, batch, n_reqs_per_lane * batch,
                               prompt_len, max_new)
        slot_res, slot_out = _serve(cfg, params, "slots", batch, prompts,
                                    max_new, max_len)
        paged_res, paged_out = _serve(cfg, params, "paged", batch, prompts,
                                      max_new, max_len)
        identical &= slot_out == paged_out
        pair = [slot_res, paged_res]
        if with_kv8:
            kv8_res, _ = _serve(cfg, params, "paged", batch, prompts,
                                max_new, max_len, kv_bits=8)
            pair.append(kv8_res)
        results.extend(pair)
        for r in pair:
            us = 1e6 * r["wall_s"] / max(r["gen_tokens"], 1)
            rows.append((f"serve_{r['mode']}_b{batch}", round(us, 1),
                         f"tok/s={r['tok_per_s']}"))

    speedup = {
        str(b): round(
            next(r["tok_per_s"] for r in results
                 if r["batch"] == b and r["mode"] == "paged")
            / max(next(r["tok_per_s"] for r in results
                       if r["batch"] == b and r["mode"] == "slots"), 1e-9),
            3)
        for b in batches
    }
    record = {
        "bench": "serve",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs_per_lane": n_reqs_per_lane,
                     "prompt_len": prompt_len, "max_new": max_new,
                     "max_len": max_len, "batches": list(batches)},
        "results": results,
        "paged_over_slots_tok_per_s": speedup,
        "token_identical": bool(identical),
        "paged_ge_slots_at_batch4plus": all(
            v >= 1.0 for b, v in speedup.items() if int(b) >= 4),
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: batch 4 only, short generations")
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(batches=tuple(args.batches or (4,)), max_new=6,
                   n_reqs_per_lane=2, out=args.out)
    else:
        rows = run(batches=tuple(args.batches or (1, 2, 4)), out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["token_identical"]:
        raise SystemExit("paged outputs diverged from fixed-slot outputs")
    if args.smoke and not record["paged_ge_slots_at_batch4plus"]:
        raise SystemExit("paged throughput fell below fixed-slot at b>=4")
    print(f"# paged/slots tok/s: {record['paged_over_slots_tok_per_s']}  "
          f"token_identical={record['token_identical']}")


if __name__ == "__main__":
    main()
