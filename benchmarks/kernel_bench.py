"""Kernel micro-benchmarks (CPU interpret mode for wall time; the derived
column reports the roofline-relevant quantities: bytes/weight, digit passes,
arithmetic intensity on the TPU target — and, for the paged-attention
family, modeled bytes per decode token)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EnginePlan, pack_linear
from repro.kernels.bitplane_gemv.ref import bitplane_gemv_ref
from repro.kernels.int8_matvec.ops import int8_matvec
from repro.kernels.paged_attention.ops import synthetic_paged_case
from repro.models.attention import attend_paged_decode
from repro.obs.costs import decode_attn_bytes

try:
    from benchmarks.common import time_call
except ImportError:  # executed as a loose script
    from common import time_call


def _time(fn, *args, reps=3, **kw):
    """Mean microseconds per call (the shared rep-loop timer)."""
    return time_call(fn, *args, reps=reps, **kw) * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    b, kdim, n = 8, 1024, 1024
    w = jnp.asarray(rng.standard_normal((kdim, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, kdim)).astype(np.float32))

    for bits in (8, 4, 2):
        ql = pack_linear(w, bits)
        for radix in (1, 2):
            if bits % radix:
                continue
            # one resolved plan per (bits, radix) sweep point — the same
            # dispatch object the serving path threads through
            plan = EnginePlan(backend="pallas_interpret", bits=bits,
                              radix=radix)
            us = _time(plan.apply, ql, x)
            passes = bits // radix
            bytes_per_weight = bits / 8
            macs = b * kdim * n
            # TPU-target arithmetic intensity: digit-pass flops over packed
            # weight bytes (weight-stationary, batch amortized)
            ai = 2 * macs * passes / (kdim * n * bytes_per_weight)
            rows.append((
                f"kernels.bitplane_gemv.b{bits}.r{radix}", round(us, 1),
                f"passes={passes} bytes/w={bytes_per_weight}"
                f" tpu_arith_intensity={ai:.1f}flop/B"))
        # oracle comparison cost (jnp ref)
        us_ref = _time(bitplane_gemv_ref, ql.packed, ql.scale, x, bits=bits)
        rows.append((f"kernels.bitplane_ref.b{bits}", round(us_ref, 1), ""))

    ql8 = pack_linear(w, 8)
    us = _time(int8_matvec, ql8.packed, ql8.scale, x)
    rows.append(("kernels.int8_matvec.baseline", round(us, 1),
                 "bit-parallel comparison point"))

    # paged-attention family: fused in-place read vs the gather reference,
    # derived column = modeled HBM bytes per decode token.  Same synthetic
    # inputs as benchmarks/attn_bench.py via the shared fixture.
    batch, hkv, group, dh, page, nblk = 4, 4, 2, 64, 8, 16
    hq = hkv * group
    for kv_bits in (0, 8):
        case = synthetic_paged_case(rng, batch=batch, nblk=nblk, page=page,
                                    hkv=hkv, group=group, dh=dh,
                                    kv_bits=kv_bits)
        q, kp, vp = case["q"], case["k_pages"], case["v_pages"]
        ks, vs, bt = case["k_scale"], case["v_scale"], case["block_tables"]
        pos = jnp.full((batch,), nblk * page - 2, jnp.int32)
        for backend in ("gather", "pallas_interpret"):
            fn = jax.jit(lambda q, kp, vp, bt, pos, _b=backend:
                         attend_paged_decode(q, kp, vp, bt, pos, 0,
                                             k_scale=ks, v_scale=vs,
                                             attn_backend=_b))
            us = _time(fn, q, kp, vp, bt, pos)
            bpt = decode_attn_bytes(
                backend, batch=batch, context=nblk * page, n_kv_heads=hkv,
                head_dim=dh, n_q_heads=hq, page_size=page,
                kv_bits=kv_bits) // batch
            tag = "fused" if backend.startswith("pallas") else "gather"
            rows.append((f"kernels.paged_attention.{tag}.kv{kv_bits}",
                         round(us, 1), f"bytes/tok={bpt}"))
    return rows
