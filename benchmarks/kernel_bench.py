"""Kernel micro-benchmarks (CPU interpret mode for wall time; the derived
column reports the roofline-relevant quantities: bytes/weight, digit passes,
arithmetic intensity on the TPU target)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EnginePlan, pack_linear
from repro.kernels.bitplane_gemv.ref import bitplane_gemv_ref
from repro.kernels.int8_matvec.ops import int8_matvec


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    b, kdim, n = 8, 1024, 1024
    w = jnp.asarray(rng.standard_normal((kdim, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, kdim)).astype(np.float32))

    for bits in (8, 4, 2):
        ql = pack_linear(w, bits)
        for radix in (1, 2):
            if bits % radix:
                continue
            # one resolved plan per (bits, radix) sweep point — the same
            # dispatch object the serving path threads through
            plan = EnginePlan(backend="pallas_interpret", bits=bits,
                              radix=radix)
            us = _time(plan.apply, ql, x)
            passes = bits // radix
            bytes_per_weight = bits / 8
            macs = b * kdim * n
            # TPU-target arithmetic intensity: digit-pass flops over packed
            # weight bytes (weight-stationary, batch amortized)
            ai = 2 * macs * passes / (kdim * n * bytes_per_weight)
            rows.append((
                f"kernels.bitplane_gemv.b{bits}.r{radix}", round(us, 1),
                f"passes={passes} bytes/w={bytes_per_weight}"
                f" tpu_arith_intensity={ai:.1f}flop/B"))
        # oracle comparison cost (jnp ref)
        us_ref = _time(bitplane_gemv_ref, ql.packed, ql.scale, x, bits=bits)
        rows.append((f"kernels.bitplane_ref.b{bits}", round(us_ref, 1), ""))

    ql8 = pack_linear(w, 8)
    us = _time(int8_matvec, ql8.packed, ql8.scale, x)
    rows.append(("kernels.int8_matvec.baseline", round(us, 1),
                 "bit-parallel comparison point"))
    return rows
