"""Paper Table V — system frequency + utilization of PIM GEMV/GEMM engines,
and the clock-speedup claim derived from it."""

from repro.core.latency_model import (
    IMAGINE_FSYS_MHZ,
    TABLE_V,
    clock_speedup_range,
    peak_tops,
)


def run():
    rows = []
    for name, (lut, ff, dsp, bram, f_sys) in TABLE_V.items():
        rel = round(f_sys / 1000.0 if name.startswith("RIMA") else f_sys / 737.0
                    if "SPAR" in name or "IMAGine" in name else f_sys / 730.0, 3)
        rows.append((f"table5.{name}", "",
                     f"lut%={lut} dsp%={dsp} bram%={bram} fsys={f_sys}MHz"
                     f" rel_fbram={rel}"))
    lo, hi = clock_speedup_range()
    rows.append(("table5.speedup_range", "",
                 f"{lo:.2f}x-{hi:.2f}x (paper: 2.65x-3.2x)"))
    rows.append(("table5.peak_tops_8bit", "",
                 f"{peak_tops(8):.3f} (paper: 0.33)"))
    rows.append(("table5.fsys", "", f"{IMAGINE_FSYS_MHZ}MHz @ 100% BRAM"))
    return rows
