"""Shared model/engine setup for the serving benchmarks.

``serve_bench``, ``prefix_bench`` and ``load_bench`` all start from the
same place: a reduced float32 model with seeded params, and a
``ServeEngine`` sized for the workload.  Keeping that here means a
change to the reduced configs or engine signature touches one file,
and every bench bills identical one-time costs (imports, param init).

Import pattern (the benches run both as scripts and via
``python -m benchmarks.run``)::

    try:
        from benchmarks.common import build_model, make_engine, tree_bytes
    except ImportError:          # executed as a loose script
        from common import build_model, make_engine, tree_bytes

Timing goes through :func:`wall_timer` / :func:`time_call` — one
implementation of the start/stop-and-subtract block every bench used to
hand-roll, reading the one serve-path timebase (``repro.obs.clock``; CI
greps benchmarks/ for hand-rolled wall-clock reads) and feeding the
walls into the ``repro.obs`` global registry when observability is
enabled.
"""

import contextlib
import dataclasses

from repro.obs import clock


class _WallBox:
    """Result box yielded by :func:`wall_timer`; ``.wall`` (seconds) is
    set when the block exits."""

    __slots__ = ("wall",)

    def __init__(self):
        self.wall = None


@contextlib.contextmanager
def wall_timer(name=None):
    """Time a block of work::

        with wall_timer("serve_b4") as w:
            eng.run()
        tok_per_s = gen / w.wall

    When ``repro.obs`` is enabled the elapsed wall also lands in the
    process-global metrics registry (histogram ``bench_wall_s`` labeled
    by ``name``), so a traced bench run carries its own timing metrics.
    """
    box = _WallBox()
    t0 = clock.now()
    try:
        yield box
    finally:
        box.wall = clock.now() - t0
        if name is not None:
            import repro.obs as obs
            if obs.enabled:
                obs.global_registry().histogram(
                    "bench_wall_s", name=name).observe(box.wall)


def time_call(fn, *args, reps=5, name=None, **kw):
    """Mean per-call seconds for a jitted callable: one warm call
    (compile), then ``reps`` timed calls bracketed by
    ``block_until_ready`` — the rep-loop pattern the kernel benches
    used to hand-roll."""
    fn(*args, **kw).block_until_ready()  # compile + warm
    with wall_timer(name) as w:
        for _ in range(reps):
            out = fn(*args, **kw)
        out.block_until_ready()
    return w.wall / reps


def build_model(arch: str):
    """Reduced ``arch`` config forced to float32 + seeded params."""
    import jax

    from repro.config import get_reduced
    from repro.models import init_params

    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def make_engine(cfg, params, *, n_slots, max_len, mode="paged",
                max_new=8, kv_bits=0, page_size=8, prefill_chunk=16,
                n_pages=0, prefix_cache=False, sched="fcfs",
                step_tokens=0, max_queue=0, warm=True, telemetry=None,
                attn_backend=None, audit=0, chaos=None,
                max_request_retries=1):
    """A ``ServeEngine`` with the bench-standard knobs, optionally with
    the jits warmed on a tiny throwaway request (so compilation is never
    billed to the first mode measured).  ``telemetry``: an explicit
    ``repro.obs`` Telemetry/NullTelemetry for this engine (None defers
    to the process-wide switch).  ``attn_backend``: pin the paged
    attention read path (None defers to the plan's ``auto``).
    ``audit`` / ``chaos`` / ``max_request_retries``: the robustness
    knobs (invariant auditor level, a ``repro.ft.ChaosInjector``, and
    the per-request retry budget) for the chaos bench."""
    from repro.config.base import EngineConfig, ServeConfig
    from repro.serve import ServeEngine

    scfg = ServeConfig(
        max_new_tokens=max_new,
        engine=EngineConfig(kv_bits=kv_bits, backend="reference"),
        page_size=page_size, prefill_chunk=prefill_chunk, n_pages=n_pages,
        sched=sched, step_tokens=step_tokens, max_queue=max_queue,
        audit=audit, max_request_retries=max_request_retries)
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode=mode, prefix_cache=prefix_cache,
                      telemetry=telemetry, attn_backend=attn_backend,
                      chaos=chaos)
    if warm:
        eng.submit([cfg.vocab_size - 1] * 4, max_new_tokens=2)
        eng.run()
    return eng


def bench_env():
    """Where did this bench run?  ``device_kind`` is the JAX device
    (``cpu`` / ``TPU v4`` / ...); ``interpret_mode`` says whether Pallas
    kernel bodies interpret (every non-TPU host) — a BENCH_*.json with
    ``interpret_mode: true`` measures dispatch overhead and byte models,
    never kernel speed, and must not be compared against hardware runs."""
    import jax

    from repro.engine.backends import default_interpret

    return {
        "device_kind": jax.devices()[0].device_kind,
        "interpret_mode": default_interpret(),
    }


def write_bench(out, record):
    """Write a BENCH_*.json record, stamping :func:`bench_env` into it —
    every bench goes through here so no result file ships without its
    device/interpret provenance — and append its comparable metrics to
    ``BENCH_history.jsonl`` next to it (``benchmarks.history``; the
    perf-regression gate compares future runs against this line).
    No-op when ``out`` is falsy."""
    import json

    if not out:
        return
    record = {**bench_env(), **record}
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out}")
    try:
        from benchmarks.history import append_record
    except ImportError:  # executed as a loose script
        from history import append_record
    hpath = append_record(out, record)
    if hpath:
        print(f"# history -> {hpath}")


def tree_bytes(t):
    """Total bytes held by the array leaves of a pytree."""
    import jax

    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t)
               if hasattr(l, "dtype"))


def percentile(xs, q):
    """Linear-interpolation percentile of a non-empty list (q in 0..100)."""
    ys = sorted(xs)
    if not ys:
        return None
    pos = (len(ys) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (pos - lo)
