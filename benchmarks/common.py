"""Shared model/engine setup for the serving benchmarks.

``serve_bench``, ``prefix_bench`` and ``load_bench`` all start from the
same place: a reduced float32 model with seeded params, and a
``ServeEngine`` sized for the workload.  Keeping that here means a
change to the reduced configs or engine signature touches one file,
and every bench bills identical one-time costs (imports, param init).

Import pattern (the benches run both as scripts and via
``python -m benchmarks.run``)::

    try:
        from benchmarks.common import build_model, make_engine, tree_bytes
    except ImportError:          # executed as a loose script
        from common import build_model, make_engine, tree_bytes
"""

import dataclasses


def build_model(arch: str):
    """Reduced ``arch`` config forced to float32 + seeded params."""
    import jax

    from repro.config import get_reduced
    from repro.models import init_params

    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def make_engine(cfg, params, *, n_slots, max_len, mode="paged",
                max_new=8, kv_bits=0, page_size=8, prefill_chunk=16,
                n_pages=0, prefix_cache=False, sched="fcfs",
                step_tokens=0, max_queue=0, warm=True):
    """A ``ServeEngine`` with the bench-standard knobs, optionally with
    the jits warmed on a tiny throwaway request (so compilation is never
    billed to the first mode measured)."""
    from repro.config.base import EngineConfig, ServeConfig
    from repro.serve import ServeEngine

    scfg = ServeConfig(
        max_new_tokens=max_new,
        engine=EngineConfig(kv_bits=kv_bits, backend="reference"),
        page_size=page_size, prefill_chunk=prefill_chunk, n_pages=n_pages,
        sched=sched, step_tokens=step_tokens, max_queue=max_queue)
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode=mode, prefix_cache=prefix_cache)
    if warm:
        eng.submit([cfg.vocab_size - 1] * 4, max_new_tokens=2)
        eng.run()
    return eng


def tree_bytes(t):
    """Total bytes held by the array leaves of a pytree."""
    import jax

    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t)
               if hasattr(l, "dtype"))


def percentile(xs, q):
    """Linear-interpolation percentile of a non-empty list (q in 0..100)."""
    ys = sorted(xs)
    if not ys:
        return None
    pos = (len(ys) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (pos - lo)
