#!/usr/bin/env python
"""Observability overhead benchmark: telemetry-on vs telemetry-off.

The tentpole claim of the obs subsystem is that instrumentation is
cheap when on and *free* when off.  This bench serves the identical
closed-loop paged workload through two engines:

  * ``off`` — the default ``NULL_TELEMETRY`` path (module switch
    disabled, every hook a no-op);
  * ``on``  — a full ``Telemetry`` with metrics enabled and tracing
    off (the steady-state production configuration; tracing is a debug
    mode, priced separately below).

Reps are interleaved (off/on/off/on…) so drift in the host's thermal /
noisy-neighbor state hits both arms equally, and the best-of-reps wall
per arm is compared — the gate is about instruction overhead, not
scheduler jitter.

Gates (enforced under ``--smoke``, recorded always):

  * **token identity** — greedy tokens identical with telemetry on/off
    (observability observes; it never perturbs);
  * **overhead** — metrics-on tok/s within 3% of metrics-off
    (``tok_on >= 0.97 * tok_off``).

A third traced arm (metrics + Chrome tracing) is measured and recorded
for reference, and its exported trace is schema-validated — but traced
throughput is not gated (tracing buys debuggability with a small cost).

Results land in ``BENCH_obs.json`` plus the repo-standard CSV rows.

  PYTHONPATH=src python benchmarks/obs_bench.py            # full run
  PYTHONPATH=src python benchmarks/obs_bench.py --smoke    # CI-sized
"""

import argparse
import json

try:
    from benchmarks.common import (build_model, make_engine,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import build_model, make_engine, wall_timer, write_bench

OVERHEAD_BUDGET = 0.03  # metrics-on may cost at most 3% tok/s


def _workload(cfg, n_reqs: int, prompt_len: int):
    return [
        [(7 * i + j) % cfg.vocab_size for j in range(prompt_len + i % 4)]
        for i in range(n_reqs)
    ]


def _serve_once(cfg, params, prompts, telemetry, tag, *, n_slots, max_len,
                max_new):
    eng = make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                      max_new=max_new, telemetry=telemetry)
    for p in prompts:
        eng.submit(list(p))
    with wall_timer(None) as w:
        done = eng.run()
    gen = sum(len(r.output) for r in done)
    outs = {r.rid: r.output for r in done}
    return {
        "arm": tag,
        "gen_tokens": gen,
        "wall_s": round(w.wall, 5),
        "tok_per_s": round(gen / w.wall, 2) if w.wall > 0 else 0.0,
    }, outs, eng


def run(arch: str = "qwen2.5-3b", n_reqs: int = 16, n_slots: int = 4,
        prompt_len: int = 12, max_new: int = 8, max_len: int = 64,
        reps: int = 6, out: str = "BENCH_obs.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns
    the repo-standard (name, us_per_call, derived) CSV rows."""
    from repro.obs import Telemetry
    from repro.obs.telemetry import NULL_TELEMETRY
    from repro.obs.trace import validate_trace

    cfg, params = build_model(arch)
    prompts = _workload(cfg, n_reqs, prompt_len)
    kw = dict(n_slots=n_slots, max_len=max_len, max_new=max_new)

    arms = {
        "off": lambda: NULL_TELEMETRY,
        "on": lambda: Telemetry(trace=False),
        "traced": lambda: Telemetry(trace=True),
    }
    # one throwaway pass warms process-global jit state for everyone
    _serve_once(cfg, params, prompts[:2], NULL_TELEMETRY, "warm", **kw)

    best = {}
    outs = {}
    snapshot = None
    trace_tracks = None
    for _ in range(reps):
        for tag, mk in arms.items():  # interleaved off/on/traced
            tel = mk()
            res, o, eng = _serve_once(cfg, params, prompts, tel, tag, **kw)
            outs.setdefault(tag, o)
            assert o == outs[tag], f"{tag} arm tokens drifted across reps"
            if tag not in best or res["wall_s"] < best[tag]["wall_s"]:
                best[tag] = res
            if tag == "on":
                snapshot = tel.snapshot()
            elif tag == "traced":
                trace_tracks = validate_trace(tel.tracer.export())

    identical = outs["off"] == outs["on"] == outs["traced"]
    tok_off, tok_on = best["off"]["tok_per_s"], best["on"]["tok_per_s"]
    overhead_ok = tok_on >= (1.0 - OVERHEAD_BUDGET) * tok_off
    m = (snapshot or {}).get("metrics", {})
    counters = dict(m.get("counters", {}))

    rows = [
        (f"obs_{tag}", round(1e6 * r["wall_s"] / max(r["gen_tokens"], 1), 1),
         f"tok/s={r['tok_per_s']}")
        for tag, r in best.items()
    ]
    record = {
        "bench": "obs",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs": n_reqs, "n_slots": n_slots,
                     "prompt_len": prompt_len, "max_new": max_new,
                     "max_len": max_len, "reps": reps},
        "results": list(best.values()),
        "on_over_off_tok_per_s": round(tok_on / max(tok_off, 1e-9), 4),
        "traced_over_off_tok_per_s": round(
            best["traced"]["tok_per_s"] / max(tok_off, 1e-9), 4),
        "overhead_budget": OVERHEAD_BUDGET,
        "overhead_within_budget": bool(overhead_ok),
        "token_identical": bool(identical),
        "metrics_counters": counters,
        "trace_tracks": trace_tracks,
    }
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, short generations")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(n_reqs=8, max_new=5, reps=6, out=args.out)
    else:
        rows = run(out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["token_identical"]:
        raise SystemExit("telemetry changed the generated tokens")
    if not record["overhead_within_budget"]:
        raise SystemExit(
            f"metrics-on throughput "
            f"{record['on_over_off_tok_per_s']:.4f}x off exceeds the "
            f"{record['overhead_budget']:.0%} overhead budget")
    print(f"# on/off tok/s={record['on_over_off_tok_per_s']}  "
          f"traced/off={record['traced_over_off_tok_per_s']}  "
          f"token_identical={record['token_identical']}")


if __name__ == "__main__":
    main()
