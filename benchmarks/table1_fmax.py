"""Paper Table I — maximum frequencies of FPGA-PIM designs.

Emits each design's f_PIM/f_BRAM and f_sys/f_BRAM ratios; the paper's point
is that every prior design clocks well under BRAM Fmax except PiCaSO (and
IMAGine, Table V)."""

from repro.core.latency_model import TABLE_I


def run():
    rows = []
    for name, (kind, device, f_bram, f_pim, f_sys) in TABLE_I.items():
        rel_pim = round(f_pim / f_bram, 3)
        rel_sys = round(f_sys / f_bram, 3) if f_sys else ""
        rows.append((f"table1.{name}", "", f"fbram={f_bram}MHz"
                     f" fpim={f_pim}MHz rel_pim={rel_pim} rel_sys={rel_sys}"))
    return rows
