#!/usr/bin/env python
"""Latency-under-load benchmark: open-loop Poisson arrivals through the
streaming front-end, FCFS vs the SLA-aware budget scheduler.

A seeded mixed workload (short interactive / medium default / long batch
prompts) arrives open-loop — arrival times are drawn once from a Poisson
process and do **not** wait for the system, so an overloaded engine
falls behind exactly as a production deployment would.  Each offered
load point is served twice:

  * ``fcfs``   — arrival-order admission, unbounded queue (the PR-3
    baseline): under overload the queue grows without bound and tail
    TTFT grows with it;
  * ``budget`` — WFQ admission + per-step token budget + bounded
    admission queue: excess load is shed at submit with a reason, and
    the requests that are admitted keep bounded queueing delay.

Per run the harness reports TTFT and TPOT percentiles, goodput (tokens
from requests whose TTFT met their priority-class SLO, per second), the
shed fraction, and a decode-stall bound (the max number of engine steps
any decoding stream went without producing a token — the chunked-prefill
interleaving claim says this is 0 for the budget scheduler).

Gates (enforced under ``--smoke``, recorded always):

  * **token identity** — streamed tokens ≡ the synchronous batch engine
    on the same seeded workload, for both schedulers;
  * **tail latency** — budget p99 TTFT strictly below FCFS p99 TTFT at
    the highest offered load;
  * **no decode stalls** — budget-scheduler decode lanes advance every
    step (``decode_stall_max_steps == 0``).

Results land in ``BENCH_load.json`` plus repo-standard CSV rows.

  PYTHONPATH=src python benchmarks/load_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/load_bench.py --smoke    # CI-sized
"""

import argparse
import json
import random

try:
    from benchmarks.common import (build_model, make_engine, percentile,
                                   wall_timer, write_bench)
except ImportError:  # executed as a loose script
    from common import (build_model, make_engine, percentile, wall_timer,
                        write_bench)

from repro.obs import clock
from repro.obs.clock import now as _now

# priority-class mix: (priority, tenant, prompt_len_range, weight)
CLASSES = [
    ("interactive", "t-app", (4, 12), 5),
    ("default", "t-web", (16, 40), 3),
    ("batch", "t-etl", (48, 88), 2),
]
# TTFT SLO per class, in units of the calibrated per-request service
# time (interactive wants near-immediate first tokens; batch is lax)
SLO_SVC_MULT = {"interactive": 4.0, "default": 8.0, "batch": 40.0}


def _workload(cfg, n_reqs: int, seed: int):
    """Seeded mixed workload: (prompt, priority, tenant) triples plus
    unit-rate exponential inter-arrival gaps.  The gaps are drawn once
    and scaled by the offered rate later, so every load point sees the
    same request sequence in the same order."""
    rng = random.Random(seed)
    pool = [c for c in CLASSES for _ in range(c[3])]
    work = []
    for i in range(n_reqs):
        prio, tenant, (lo, hi), _ = rng.choice(pool)
        n = rng.randint(lo, hi)
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(n)]
        work.append((prompt, prio, tenant))
    gaps = [rng.expovariate(1.0) for _ in range(n_reqs)]
    return work, gaps


def _drive(eng, work, arrivals, max_new: int):
    """Open-loop driver: submit each request at its scheduled arrival
    time (never waiting for the system), step the engine in between,
    and track the per-stream decode-stall bound."""
    from repro.serve import ServeFrontend

    fe = ServeFrontend(eng)
    streams = []
    stall_now = {}  # stream -> consecutive stall steps
    stall_max = 0
    t0 = _now()
    i = 0
    while True:
        now = _now() - t0
        while i < len(work) and arrivals[i] <= now:
            prompt, prio, tenant = work[i]
            streams.append(fe.submit(list(prompt), max_new_tokens=max_new,
                                     priority=prio, tenant=tenant))
            i += 1
        if fe.has_live():
            decoding = [(s, len(s.tokens)) for s in streams
                        if s.state == "decoding"]
            fe.step()
            for s, had in decoding:
                if len(s.tokens) == had and not s.finished:
                    stall_now[s] = stall_now.get(s, 0) + 1
                    stall_max = max(stall_max, stall_now[s])
                else:
                    stall_now.pop(s, None)
        elif i < len(work):  # idle until the next scheduled arrival
            clock.sleep(min(max(arrivals[i] - now, 0.0), 0.002))
        else:
            break
    wall = _now() - t0
    return fe, streams, wall, stall_max


def _measure(streams, wall, stall_max, svc_s: float, offered_rps: float,
             sched: str):
    ttfts = [s.ttft() for s in streams if s.ttft() is not None]
    tpots = [s.tpot() for s in streams if s.tpot() is not None]
    shed = sum(1 for s in streams if s.state == "shed")
    done = [s for s in streams if s.state == "done"]
    good_tok = sum(
        len(s.tokens) for s in done
        if s.ttft() is not None
        and s.ttft() <= SLO_SVC_MULT[s.req.priority] * svc_s)
    pct = lambda xs, q: (round(percentile(xs, q), 5) if xs else None)
    return {
        "sched": sched,
        "offered_rps": round(offered_rps, 3),
        "offered": len(streams),
        "completed": len(done),
        "shed": shed,
        "shed_frac": round(shed / max(len(streams), 1), 4),
        "wall_s": round(wall, 4),
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p95_s": pct(ttfts, 95),
        "ttft_p99_s": pct(ttfts, 99),
        "tpot_p50_s": pct(tpots, 50),
        "tpot_p95_s": pct(tpots, 95),
        "goodput_tok_per_s": round(good_tok / wall, 2) if wall else 0.0,
        "goodput_frac": round(
            good_tok / max(sum(len(s.tokens) for s in streams), 1), 4),
        "decode_stall_max_steps": int(stall_max),
    }


def _engine_for(cfg, params, sched: str, n_slots: int, max_len: int,
                max_new: int, max_queue: int):
    return make_engine(
        cfg, params, n_slots=n_slots, max_len=max_len, max_new=max_new,
        sched=sched, max_queue=max_queue if sched == "budget" else 0)


def _identity_gate(cfg, params, work, n_slots, max_len, max_new):
    """Streamed tokens must equal the synchronous batch engine's on the
    same seeded workload — for both schedulers (same greedy argmax, so
    scheduling may reorder work but never change tokens)."""
    ref = None
    for sched in ("fcfs", "budget"):
        eng = make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                          max_new=max_new, sched=sched)
        reqs = [eng.submit(list(p), max_new_tokens=max_new,
                           priority=prio, tenant=ten)
                for p, prio, ten in work]
        eng.run()
        sync_out = [r.output for r in reqs]
        if ref is None:
            ref = sync_out
        elif sync_out != ref:
            return False

        from repro.serve import ServeFrontend
        eng = make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                          max_new=max_new, sched=sched)
        fe = ServeFrontend(eng)
        streams = [fe.submit(list(p), max_new_tokens=max_new,
                             priority=prio, tenant=ten)
                   for p, prio, ten in work]
        # consume round-robin one token at a time — the pull-driven path
        exhausted = [False] * len(streams)
        while not all(exhausted):
            for k, s in enumerate(streams):
                if exhausted[k]:
                    continue
                try:
                    next(s)
                except StopIteration:
                    exhausted[k] = True
        if [s.tokens for s in streams] != ref:
            return False
    return True


def _traced_run(cfg, params, n_slots, max_len, max_new, trace_path):
    """Serve a shared-prefix workload through a fully-traced engine and
    export + validate the Chrome trace (the observability CI gate rides
    this): the trace must parse and carry per-lane prefill/decode spans
    plus scheduler and prefix-cache events.  The engine runs the *fused*
    attention backend (interpreted off-TPU), so the per-step prefill
    spans cover the in-kernel chunked-prefill path — the span timeline
    must not go dark when prefill stops being a Python-level gather."""
    import repro.obs as obs
    from repro.obs.trace import (CACHE_TID, SCHED_TID, validate_trace)

    tel = obs.Telemetry(trace=True)
    eng = make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                      max_new=max_new, sched="budget", prefix_cache=True,
                      telemetry=tel, attn_backend="pallas_interpret")
    # shared prefix (page-aligned at the default page_size=8) so the
    # radix tree produces hit/insert events, not just misses
    prefix = [(3 * j + 1) % cfg.vocab_size for j in range(16)]
    eng.submit(prefix + [2], max_new_tokens=1)
    eng.run()  # primes the tree
    for i in range(2 * n_slots):
        eng.submit(prefix + [(5 * i + 7) % cfg.vocab_size, 3])
    eng.run()
    tel.export_chrome_trace(trace_path)
    track_counts = validate_trace(tel.tracer.export())
    seen = {(e["tid"], e["name"]) for e in tel.tracer.events}
    lane_prefill = any(t == 1 + s and n == "prefill"
                       for t, n in seen for s in range(n_slots))
    lane_decode = any(t == 1 + s and n == "decode"
                      for t, n in seen for s in range(n_slots))
    sched_events = any(t == SCHED_TID for t, _ in seen)
    cache_events = any(t == CACHE_TID for t, _ in seen)
    return {
        "trace_file": trace_path,
        "attn_backend": eng.attn_backend,
        "trace_events": len(tel.tracer.events),
        "trace_tracks": track_counts,
        "trace_valid": True,  # validate_trace raised otherwise
        "has_lane_prefill_spans": bool(lane_prefill),
        "has_lane_decode_spans": bool(lane_decode),
        "has_scheduler_events": bool(sched_events),
        "has_prefix_cache_events": bool(cache_events),
        "prefix_cache": eng.metrics().get("prefix"),
    }


def run(rate_mults=(0.5, 1.0, 4.0), arch: str = "qwen2.5-3b",
        n_reqs: int = 32, n_slots: int = 4, max_new: int = 6,
        max_len: int = 128, seed: int = 0, n_identity: int = 8,
        trace: str = None, out: str = "BENCH_load.json"):
    """Bench entry point (also registered in benchmarks.run).  Returns
    the repo-standard (name, us_per_call, derived) CSV rows."""
    cfg, params = build_model(arch)
    work, gaps = _workload(cfg, n_reqs, seed)
    # bounded admission: roughly one queue wave behind the resident set —
    # deep enough to ride out bursts at capacity, shallow enough that a
    # genuine overload sheds instead of queueing unboundedly
    max_queue = n_slots

    # calibrate capacity: everything submitted at t=0, budget scheduler,
    # closed-loop — the sustainable request rate of this engine on this
    # host.  Offered loads are multiples of it, so the top point is a
    # genuine overload on any machine.
    eng = _engine_for(cfg, params, "budget", n_slots, max_len, max_new, 0)
    _, _, cal_wall, _ = _drive(eng, work, [0.0] * len(work), max_new)
    capacity_rps = len(work) / cal_wall
    svc_s = cal_wall / len(work)

    identical = _identity_gate(cfg, params, work[:n_identity], n_slots,
                               max_len, max_new)

    results, rows = [], []
    for mult in rate_mults:
        rate = capacity_rps * mult
        arrivals, t = [], 0.0
        for g in gaps:
            t += g / rate
            arrivals.append(t)
        for sched in ("fcfs", "budget"):
            eng = _engine_for(cfg, params, sched, n_slots, max_len,
                              max_new, max_queue)
            fe, streams, wall, stall = _drive(eng, work, arrivals, max_new)
            res = _measure(streams, wall, stall, svc_s, rate, sched)
            res["load_mult"] = mult
            results.append(res)
            rows.append((
                f"load_{sched}_x{mult}",
                round(1e6 * (res["ttft_p99_s"] or 0.0), 1),
                f"ttft_p50={res['ttft_p50_s']}"
                f";shed={res['shed']}"
                f";goodput={res['goodput_tok_per_s']}"))

    peak = max(rate_mults)
    at_peak = {r["sched"]: r for r in results if r["load_mult"] == peak}
    tail_ok = (at_peak["budget"]["ttft_p99_s"]
               < at_peak["fcfs"]["ttft_p99_s"])
    stall_ok = all(r["decode_stall_max_steps"] == 0
                   for r in results if r["sched"] == "budget")
    record = {
        "bench": "load",
        "arch": arch,
        "reduced": True,
        "dtype": "float32",
        "workload": {"n_reqs": n_reqs, "seed": seed, "max_new": max_new,
                     "n_slots": n_slots, "max_len": max_len,
                     "max_queue": max_queue,
                     "rate_mults": list(rate_mults),
                     "classes": [c[:3] for c in CLASSES]},
        "capacity_rps": round(capacity_rps, 3),
        "results": results,
        "token_identical": bool(identical),
        "budget_p99_ttft_below_fcfs_at_peak": bool(tail_ok),
        "decode_stall_bounded": bool(stall_ok),
    }
    if trace:
        record["trace"] = _traced_run(cfg, params, n_slots, max_len,
                                      max_new, trace)
        print(f"# wrote {trace} ({record['trace']['trace_events']} events)")
    write_bench(out, record)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, short generations")
    ap.add_argument("--n-reqs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", nargs="?", const="trace_load.json",
                    default=None,
                    help="also export + validate a Chrome trace of a "
                         "traced serve run (Perfetto-loadable JSON)")
    ap.add_argument("--out", default="BENCH_load.json")
    args = ap.parse_args()

    if args.smoke:
        rows = run(n_reqs=args.n_reqs or 24, max_new=5, n_identity=6,
                   seed=args.seed, trace=args.trace, out=args.out)
    else:
        rows = run(n_reqs=args.n_reqs or 48, seed=args.seed,
                   trace=args.trace, out=args.out)
    print("name,us_per_call,derived")
    for row in rows:
        print(",".join(str(v) for v in row))

    with open(args.out) as f:
        record = json.load(f)
    if not record["token_identical"]:
        raise SystemExit("streamed tokens diverged from batch outputs")
    if not record["decode_stall_bounded"]:
        raise SystemExit("a budget-scheduler decode lane stalled")
    if args.smoke and not record["budget_p99_ttft_below_fcfs_at_peak"]:
        raise SystemExit(
            "budget scheduler p99 TTFT not below FCFS at peak load")
    if args.trace:
        tr = record["trace"]
        missing = [k for k in ("has_lane_prefill_spans",
                               "has_lane_decode_spans",
                               "has_scheduler_events",
                               "has_prefix_cache_events") if not tr[k]]
        if missing:
            raise SystemExit(f"exported trace is incomplete: {missing}")
    peak = record["workload"]["rate_mults"][-1]
    at = {r["sched"]: r for r in record["results"]
          if r["load_mult"] == peak}
    print(f"# capacity={record['capacity_rps']} req/s  "
          f"p99 TTFT at x{peak}: fcfs={at['fcfs']['ttft_p99_s']}s "
          f"budget={at['budget']['ttft_p99_s']}s  "
          f"token_identical={record['token_identical']}")


if __name__ == "__main__":
    main()
