"""TPU roofline summary: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits the per-cell three-term roofline table, plus
the serve-path per-op cost rows priced through ``repro.obs.costs`` — the
single analytic FLOPs/bytes model the serve engine's ledger, the
attention benches and this table now share (no local bytes arithmetic
here: one bytes model per op)."""

import glob
import json
import os

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "dryrun")

# serve-path roofline points: reduced arch, one decode + one prefill
# shape matching the serve bench geometry
SERVE_ARCH = "qwen2.5-3b"
SERVE_SHAPE = {"batch": 4, "context": 256, "page_size": 8, "chunk": 16}


def serve_cost_rows(arch: str = SERVE_ARCH):
    """Per-op modeled cost rows for one paged decode step and one chunked
    prefill — ``repro.obs.costs`` tables, the same ones the engine's
    ledger charges, so the roofline table and the live metrics can never
    disagree on what a step costs."""
    from repro.config import get_reduced
    from repro.obs import costs

    dims = costs.model_dims(get_reduced(arch))
    sh = SERVE_SHAPE
    rows = []
    for phase, backend in (("decode", "gather"), ("decode", "pallas_tpu"),
                           ("prefill", "gather"), ("prefill", "pallas_tpu")):
        if phase == "decode":
            table = costs.decode_step_costs(
                dims, batch=sh["batch"], context=sh["context"],
                page_size=sh["page_size"], attn_backend=backend)
            toks = sh["batch"]
        else:
            table = costs.prefill_chunk_costs(
                dims, batch=sh["batch"], chunk=sh["chunk"],
                context=sh["context"], page_size=sh["page_size"],
                attn_backend=backend)
            toks = sh["batch"] * sh["chunk"]
        tot = costs.total_cost(table)
        tag = "fused" if backend.startswith("pallas") else "gather"
        rows.append((
            f"roofline.serve.{phase}.{tag}", "",
            f"arch={arch} flops/tok={tot.flops / toks:.3e}"
            f" bytes/tok={tot.bytes / toks:.3e}"
            f" arith_intensity={tot.flops / max(tot.bytes, 1):.2f}flop/B"
            f" ops={len(table)}"))
    return rows


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        name = os.path.basename(path)[:-5]
        if name.startswith("_"):
            continue
        try:
            r = json.load(open(path))
        except Exception:
            continue
        if "compute_s" not in r:
            continue
        rows.append((
            f"roofline.{name}", "",
            f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
            f" collective={r['collective_s']:.3e}s dom={r['dominant']}"
            f" frac={r.get('roofline_fraction', 0):.4f}"
            f" flops/dev={r['flops_per_device']:.3e}"))
    if not rows:
        rows.append(("roofline.missing", "",
                     "run experiments/run_dryruns.py first"))
    rows.extend(serve_cost_rows())
    return rows
