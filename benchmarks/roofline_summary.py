"""TPU roofline summary: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits the per-cell three-term roofline table."""

import glob
import json
import os

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "dryrun")


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        name = os.path.basename(path)[:-5]
        if name.startswith("_"):
            continue
        try:
            r = json.load(open(path))
        except Exception:
            continue
        if "compute_s" not in r:
            continue
        rows.append((
            f"roofline.{name}", "",
            f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
            f" collective={r['collective_s']:.3e}s dom={r['dominant']}"
            f" frac={r.get('roofline_fraction', 0):.4f}"
            f" flops/dev={r['flops_per_device']:.3e}"))
    if not rows:
        rows.append(("roofline.missing", "",
                     "run experiments/run_dryruns.py first"))
    return rows
