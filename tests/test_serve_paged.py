"""Paged-KV serving subsystem: greedy equivalence with the fixed-slot
engine (the pinning sweep: pages only move bytes, never change tokens),
allocator/free-list behaviour, chunked-prefill numerics, preemption, and
int8 KV pages through the ``EnginePlan.kv_bits`` knob."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.engine import resolve_plan
from repro.models import init_cache, init_params, prefill, prefill_chunk
from repro.serve import PageAllocator, ServeEngine, init_kv_pages, pages_for

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


def _gen(cfg, params, prompts, mode, *, max_new=5, n_slots=2, max_len=32,
         engine=None, **kw):
    scfg = ServeConfig(max_new_tokens=max_new,
                       engine=engine or EngineConfig())
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode=mode, **kw)
    for p in prompts:
        eng.submit(p)
    return eng, sorted(eng.run(), key=lambda r: r.rid)


# ---------------------------------------------------------------- sweep
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma3-27b",
                                  "qwen3-moe-235b-a22b", "musicgen-medium"])
def test_paged_matches_slots(arch, rng):
    """kv_bits=0: paged greedy decode is token-identical to fixed slots
    across dense / sliding-window / moe / audio families."""
    cfg = reduced_f32(arch, capacity_factor=8.0)
    params = init_params(cfg, rng)
    _, slots = _gen(cfg, params, PROMPTS, "slots")
    _, paged = _gen(cfg, params, PROMPTS, "paged", page_size=4,
                    prefill_chunk=3)
    assert len(slots) == len(paged) == len(PROMPTS)
    for a, b in zip(slots, paged):
        assert a.output == b.output, (arch, a.rid, a.output, b.output)
        assert b.done


def test_paged_matches_slots_across_slot_counts(rng):
    """Slot-reuse waves (more requests than lanes) and odd chunk/page
    geometry keep token identity."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    _, ref = _gen(cfg, params, PROMPTS, "slots", n_slots=1, max_new=6)
    for n_slots in (1, 2, 3):
        for chunk in (1, 2, 5):
            _, paged = _gen(cfg, params, PROMPTS, "paged", n_slots=n_slots,
                            max_new=6, page_size=4, prefill_chunk=chunk)
            for a, b in zip(ref, paged):
                assert a.output == b.output, (n_slots, chunk, a.rid)


def test_preemption_token_identical(rng):
    """A page pool too small for all residents forces preemption of the
    longest-running request; recompute-resume keeps greedy tokens exact."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    _, ref = _gen(cfg, params, PROMPTS, "slots", n_slots=3, max_len=48,
                  max_new=16)
    eng, paged = _gen(cfg, params, PROMPTS, "paged", n_slots=3, max_len=48,
                      max_new=16, page_size=4, n_pages=14, prefill_chunk=4)
    assert eng.preemptions > 0
    assert any(r.preemptions > 0 for r in paged)
    for a, b in zip(ref, paged):
        assert a.output == b.output, (a.rid, a.output, b.output)


# ------------------------------------------------------------- kv_bits
def test_kv_bits_resolves_to_plan():
    """kv_bits alone enables the engine: the plan carries bits=0 (dense
    weights) and kv_bits=8 — the previously-dead field is live."""
    plan = resolve_plan(EngineConfig(kv_bits=8, backend="reference"))
    assert plan is not None
    assert plan.bits == 0 and plan.kv_bits == 8
    assert resolve_plan(EngineConfig()) is None  # fully-off still disables


def test_kv8_pages_close_to_slots(rng):
    """kv_bits=8: int8 KV pages track the full-precision engine within
    tolerance (first token exact, large majority of the stream agrees)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    _, ref = _gen(cfg, params, PROMPTS, "slots", max_new=8)
    eng, kv8 = _gen(cfg, params, PROMPTS, "paged", max_new=8, page_size=4,
                    prefill_chunk=3,
                    engine=EngineConfig(kv_bits=8, backend="reference"))
    assert eng.pages.quantized and eng.pages.k.dtype == jnp.int8
    assert all(a.output[0] == b.output[0] for a, b in zip(ref, kv8))
    agree = sum(t1 == t2 for a, b in zip(ref, kv8)
                for t1, t2 in zip(a.output, b.output))
    total = sum(len(a.output) for a in ref)
    assert agree / total > 0.7, (agree, total)


def test_full_imagine_paged_mode(rng):
    """weights int8 bit-plane + int8 KV pages through one plan."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    _, ref = _gen(cfg, params, PROMPTS[:2], "slots", max_new=6)
    eng, quant = _gen(
        cfg, params, PROMPTS[:2], "paged", max_new=6, page_size=4,
        prefill_chunk=3,
        engine=EngineConfig(weight_bits=8, kv_bits=8, backend="reference"))
    assert eng.plan.bits == 8 and eng.plan.kv_bits == 8
    agree = sum(t1 == t2 for a, b in zip(ref, quant)
                for t1, t2 in zip(a.output, b.output))
    total = sum(len(a.output) for a in ref)
    assert agree / total > 0.6, (agree, total)


# ------------------------------------------------- chunked prefill math
def test_prefill_chunk_matches_prefill(rng):
    """Running prefill_chunk to completion (chunk < prompt) reproduces the
    one-shot batched ``prefill`` last-token logits."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    b, s, page = 2, 11, 4
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)

    cache = init_cache(cfg, b, max_len=16)
    ref_logits, _ = prefill(params, {"tokens": tokens}, cfg, cache)

    n_blocks = pages_for(16, page)
    pages = init_kv_pages(cfg, b * n_blocks + 1, page)
    alloc = PageAllocator(b * n_blocks + 1, page, b, 16)
    for lane in range(b):
        assert alloc.ensure(lane, s)
    bt, _ = alloc.device_tables()
    for chunk in (3,):
        got = None
        for c0 in range(0, s, chunk):
            n = min(chunk, s - c0)
            tk = jnp.pad(tokens[:, c0:c0 + n], ((0, 0), (0, chunk - n)))
            pos0 = jnp.full((b,), c0, jnp.int32)
            seq = jnp.full((b,), c0 + n, jnp.int32)
            got, pages = prefill_chunk(params, pages, bt, tk, pos0, seq, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- allocator
def test_page_allocator_unit():
    alloc = PageAllocator(n_pages=9, page_size=4, n_slots=3, max_len=16)
    assert alloc.free_pages == 8  # page 0 reserved as null
    assert alloc.can_admit(16 - 1)
    assert not alloc.can_admit(4 * 8)  # beyond pool capacity

    assert alloc.ensure(0, 9)  # 3 pages
    assert alloc.used_pages == 3
    assert (alloc.block_tables[0, :3] > 0).all()
    assert (alloc.block_tables[0, 3:] == 0).all()
    assert alloc.ensure(0, 9)  # idempotent
    assert alloc.used_pages == 3

    assert alloc.ensure(1, 16)  # 4 pages -> 7 of 8 used
    assert alloc.free_pages == 1
    assert not alloc.ensure(2, 8)  # needs 2 pages: dry
    assert alloc.used_pages == 7  # failed ensure allocates nothing
    assert (alloc.block_tables[2] == 0).all()
    assert alloc.ensure(0, 13)  # the last page
    assert alloc.free_pages == 0

    alloc.free_slot(1)
    assert alloc.free_pages == 4
    assert (alloc.block_tables[1] == 0).all() and alloc.pos[1] == 0
    assert alloc.ensure(2, 8)

    with pytest.raises(ValueError):
        alloc.ensure(0, 17)  # > max_len capacity
    with pytest.raises(ValueError):
        PageAllocator(n_pages=3, page_size=4, n_slots=1, max_len=16)


def test_grant_never_leaks_onto_empty_slot():
    """Regression: after one lane's grant preempts another lane's request,
    a grant for the now-empty slot must refuse (not allocate a page onto a
    slot with no resident request — with minimum-size pools the leaked
    page blocked admission forever)."""
    from repro.serve.engine import Request
    from repro.serve.scheduler import PagedScheduler

    alloc = PageAllocator(n_pages=5, page_size=4, n_slots=2, max_len=16)
    sched = PagedScheduler(alloc, chunk=4)
    for rid in range(2):
        req = Request(rid, [1, 2, 3], 8)
        req.prefill_tokens = list(req.prompt)
        sched.submit(req)
    sched.admit()
    assert all(r is not None for r in sched.slot_req)
    # drain the pool: both lanes at a page boundary, free list dry
    assert alloc.ensure(0, 8) and alloc.ensure(1, 8)
    alloc.pos[:] = 8
    assert alloc.free_pages == 0
    # lane 0's grant preempts lane 1 (the earliest other resident)
    assert sched.grant_decode_page(0)
    assert sched.slot_req[1] is None and sched.preemptions == 1
    free_before = alloc.free_pages
    assert not sched.grant_decode_page(1)  # empty slot: refuse, no alloc
    assert alloc.free_pages == free_before
    assert alloc.block_tables[1].sum() == 0


def test_capacity_admission_queues(rng):
    """With a pool smaller than total demand every request still completes
    (admission waits for pages instead of over-committing)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng, done = _gen(cfg, params, PROMPTS + [[11, 12, 13]], "paged",
                     n_slots=4, max_len=32, max_new=6, page_size=4,
                     n_pages=9, prefill_chunk=3)
    assert len(done) == len(PROMPTS) + 1
    assert all(r.done and len(r.output) == 6 for r in done)
    assert eng.alloc.used_pages == 0  # everything reclaimed
    assert eng.alloc.free_pages == 8
