"""Mamba2 SSD invariants: chunked == naive recurrence, chunk-size
independence, decode-step == one-step chunked, state carry-over."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def _naive_recurrence(xh, dt, a, b_in, c_in, h0=None):
    """Exact per-step SSD recurrence: h = exp(dt·a)h + dt·x⊗B; y = C·h."""
    bsz, s, nh, p = xh.shape
    n = b_in.shape[-1]
    h = np.zeros((bsz, nh, p, n)) if h0 is None else np.asarray(h0).copy()
    ys = []
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a)[None])  # (B,H)
        xdt = np.asarray(xh[:, t]) * np.asarray(dt[:, t])[..., None]
        h = h * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xdt, np.asarray(b_in[:, t]))
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(c_in[:, t]), h))
    return np.stack(ys, 1), h


def _inputs(bsz=2, s=32, nh=8, p=4, n=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (bsz, s, nh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, nh)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    b_in = jax.random.normal(ks[3], (bsz, s, n))
    c_in = jax.random.normal(ks[4], (bsz, s, n))
    return xh, dt, a, b_in, c_in


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_equals_recurrence(chunk):
    xh, dt, a, b_in, c_in = _inputs()
    y, h = ssd_chunked(xh, dt, a, b_in, c_in, chunk)
    y_ref, h_ref = _naive_recurrence(xh, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_chunk_size_invariance():
    xh, dt, a, b_in, c_in = _inputs(s=64)
    y1, h1 = ssd_chunked(xh, dt, a, b_in, c_in, 8)
    y2, h2 = ssd_chunked(xh, dt, a, b_in, c_in, 64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_initial_state_carry():
    """Splitting a sequence across two calls with h0 carried == one call."""
    xh, dt, a, b_in, c_in = _inputs(s=32)
    y_full, h_full = ssd_chunked(xh, dt, a, b_in, c_in, 8)
    y1, h1 = ssd_chunked(xh[:, :16], dt[:, :16], a, b_in[:, :16],
                         c_in[:, :16], 8)
    y2, h2 = ssd_chunked(xh[:, 16:], dt[:, 16:], a, b_in[:, 16:],
                         c_in[:, 16:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_decay_bounds():
    """With a<0 and dt>0 the state decays: ||h|| bounded by input energy."""
    xh, dt, a, b_in, c_in = _inputs(s=128, seed=3)
    _, h = ssd_chunked(xh, dt, a, b_in, c_in, 16)
    assert np.all(np.isfinite(np.asarray(h)))
