"""ISA encode/decode + controller FSM: exact GEMV and cycle accounting."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.controller import CycleModel, GemvTileController, run_gemv
from repro.core.isa import (
    Instr,
    Op,
    SINGLE_CYCLE,
    assemble_gemv,
    decode,
    roundtrip,
)


@given(
    op=st.sampled_from(list(Op)),
    rd=st.integers(0, 63),
    rs1=st.integers(0, 63),
    rs2=st.integers(0, 63),
    imm=st.integers(0, 127),
)
@settings(max_examples=200, deadline=None)
def test_encode_decode_roundtrip(op, rd, rs1, rs2, imm):
    i = Instr(op, rd, rs1, rs2, imm)
    w = i.encode()
    assert 0 <= w < (1 << 30)
    assert decode(w) == i


def test_encode_rejects_out_of_range():
    with pytest.raises(ValueError):
        Instr(Op.ADD, rd=64).encode()
    with pytest.raises(ValueError):
        Instr(Op.ADD, imm=128).encode()
    with pytest.raises(ValueError):
        decode(1 << 30)


def test_program_roundtrip():
    prog = assemble_gemv(n_elems=5, n_folds=2, out_rows=4)
    words, decoded = roundtrip(prog)
    assert decoded == prog
    assert all(0 <= w < 2**30 for w in words)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_controller_gemv_exact(m, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-127, 128, size=(m, k))
    x = rng.integers(-127, 128, size=(k,))
    res = run_gemv(w, x, rows=16, cols=8)
    np.testing.assert_array_equal(res.y, w @ x)
    assert res.cycles > 0


def test_cycle_accounting_matches_model():
    """Controller cycle count == analytic instruction-cost sum."""
    rng = np.random.default_rng(0)
    w = rng.integers(-8, 8, size=(8, 16))
    x = rng.integers(-8, 8, size=(16,))
    res = run_gemv(w, x, rows=8, cols=4)
    cm = CycleModel()
    expect = 0
    for op_, count in res.ctrl.instr_count.items():
        cost = cm.for_instr(Instr(op_), n_cols=4)
        expect += cost * count
    # plus the data-load cycles charged by load_weights/load_activations
    elems = 4
    expect += elems  # activations
    expect += elems  # weights (1 fold)
    assert res.cycles == expect


def test_single_vs_multicycle_drivers():
    cm = CycleModel(precision=8)
    for op_ in SINGLE_CYCLE:
        assert cm.for_instr(Instr(op_), 4) == 1
    assert cm.for_instr(Instr(Op.MULT), 4) > 8
    assert cm.for_instr(Instr(Op.MAC), 4) > cm.for_instr(Instr(Op.MULT), 4)


def test_radix4_halves_mult_passes():
    """The slice4 variant (radix-4 Booth) halves multiply latency."""
    r2 = CycleModel(precision=8, radix_bits=1)
    r4 = CycleModel(precision=8, radix_bits=2)
    assert r4.mult() - r4.issue == (r2.mult() - r2.issue) // 2


def test_halt_stops_execution():
    ctrl = GemvTileController(2, 2)
    ctrl.execute([Instr(Op.HALT)])
    with pytest.raises(RuntimeError):
        ctrl.execute([Instr(Op.NOP)])
