"""Pallas kernel validation: shape/dtype/bits/radix sweeps against the
pure-jnp oracles (interpret mode), plus hypothesis property checks."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.gemv_engine import (
    gemv_bit_serial_reference,
    gemv_reference,
    quantize_linear,
)
from repro.kernels.bitplane_gemv.ops import bitplane_gemv
from repro.kernels.bitplane_gemv.ref import bitplane_gemv_ref
from repro.kernels.int8_matvec.ops import int8_matvec
from repro.kernels.int8_matvec.ref import int8_matvec_ref

SHAPES = [(1, 64, 48), (3, 300, 130), (8, 1024, 512), (128, 256, 128)]
BITS_RADIX = [(8, 1), (8, 2), (8, 4), (4, 1), (4, 2), (2, 1), (2, 2)]


def _data(b, k, n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(dtype))
    x = jnp.asarray(rng.standard_normal((b, k)).astype(dtype))
    return w, x


@pytest.mark.parametrize("b,k,n", SHAPES)
@pytest.mark.parametrize("bits,radix", BITS_RADIX)
def test_bitplane_kernel_vs_ref(b, k, n, bits, radix):
    w, x = _data(b, k, n)
    ql = quantize_linear(w, bits)
    y_k = bitplane_gemv(ql.packed, ql.scale, x, bits=bits, radix=radix,
                        interpret=True)
    y_r = bitplane_gemv_ref(ql.packed, ql.scale, x, bits=bits, radix=radix)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_bitplane_kernel_dtypes(dtype):
    w, x = _data(4, 256, 128)
    x = x.astype(dtype)
    ql = quantize_linear(w, 8)
    y_k = bitplane_gemv(ql.packed, ql.scale, x, bits=8, radix=1,
                        interpret=True, out_dtype=jnp.float32)
    y_r = bitplane_gemv_ref(ql.packed, ql.scale, x.astype(jnp.float32),
                            bits=8, radix=1)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-2, atol=2e-1)


def test_bitplane_kernel_1d_input():
    w, _ = _data(1, 128, 64)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(128,).astype(np.float32))
    ql = quantize_linear(w, 8)
    y = bitplane_gemv(ql.packed, ql.scale, x, bits=8, interpret=True)
    assert y.shape == (64,)


def test_radix_variants_agree():
    """radix-2 (paper baseline), radix-4 ("slice4") and nibble passes are
    numerically identical — the paper's latency knob, not a numerics knob."""
    w, x = _data(2, 512, 64, seed=3)
    ql = quantize_linear(w, 8)
    outs = [
        bitplane_gemv(ql.packed, ql.scale, x, bits=8, radix=r, interpret=True)
        for r in (1, 2, 4)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("b,k,n", SHAPES[:3])
def test_int8_matvec_vs_ref(b, k, n):
    w, x = _data(b, k, n, seed=7)
    ql = quantize_linear(w, 8)
    y_k = int8_matvec(ql.packed, ql.scale, x, interpret=True)
    y_r = int8_matvec_ref(ql.packed, ql.scale, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-4)


def test_bitparallel_equals_bitserial():
    """int8 bit-parallel baseline == bit-serial engine on 8-bit weights."""
    w, x = _data(4, 192, 96, seed=11)
    ql = quantize_linear(w, 8)
    y_bp = int8_matvec(ql.packed, ql.scale, x, interpret=True)
    y_bs = bitplane_gemv(ql.packed, ql.scale, x, bits=8, radix=1,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(y_bp), np.asarray(y_bs),
                               rtol=1e-5, atol=1e-4)


@given(
    b=st.integers(1, 8),
    k=st.integers(8, 96),
    n=st.integers(1, 48),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_bitplane_kernel_property(b, k, n, bits, seed):
    k = k * (8 // bits)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32))
    ql = quantize_linear(w, bits)
    y_k = bitplane_gemv(ql.packed, ql.scale, x, bits=bits, interpret=True)
    y_ref = gemv_reference(ql, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_engine_reference_vs_bit_serial_oracle():
    w, x = _data(3, 128, 64, seed=13)
    for bits in (2, 4, 8):
        ql = quantize_linear(w, bits)
        y0 = gemv_reference(ql, x)
        for radix in (r for r in (1, 2, 4) if bits % r == 0):
            y1 = gemv_bit_serial_reference(ql, x, radix=radix)
            np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                       rtol=1e-5, atol=1e-4)
