"""End-to-end behaviour tests: training converges, checkpoint/restart drill
reproduces the uninterrupted run, the serving engine generates coherently
with and without the IMAGine engine, quantization degrades gracefully."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.config.base import EngineConfig, ServeConfig, TrainConfig
from repro.data import DataPipeline
from repro.ft import FailureInjector, StragglerMonitor
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    quantize_params,
)
from repro.serve import ServeEngine
from repro.train import Trainer

from conftest import reduced_f32


def _mk(arch="qwen2.5-3b", seed=0, **kw):
    cfg = reduced_f32(arch, **kw)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


class TestTraining:
    def test_loss_decreases(self):
        cfg, params = _mk()
        tcfg = TrainConfig(lr=1e-3, total_steps=30, warmup_steps=5)
        pipe = DataPipeline(cfg, batch=4, seq_len=32, seed=1)
        tr = Trainer(cfg, tcfg, params, pipe)
        hist = tr.run(15)["loss"]
        assert hist[-1] < hist[0]
        assert all(np.isfinite(hist))

    def test_microbatched_equals_full_batch(self):
        """Gradient accumulation must not change the loss value."""
        from repro.optim import make_optimizer
        from repro.train.trainer import make_train_step

        cfg, params = _mk(seed=3)
        pipe = DataPipeline(cfg, batch=4, seq_len=16, seed=2)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        losses = {}
        for mb in (1, 2, 4):
            tcfg = TrainConfig(microbatches=mb)
            step = make_train_step(cfg, tcfg, donate=False)
            init_fn, _ = make_optimizer("adamw")
            _, _, _, m = step(params, init_fn(params), {}, batch)
            losses[mb] = float(m["loss"])
        assert abs(losses[1] - losses[2]) < 5e-3
        assert abs(losses[1] - losses[4]) < 5e-3

    def test_restart_drill_matches_uninterrupted(self):
        """Failure at step 12 + restore from the step-10 checkpoint must end
        at the same final loss as a run that never failed (deterministic
        data + complete checkpoints)."""
        cfg, params = _mk(seed=5)
        tcfg = TrainConfig(lr=5e-4, total_steps=40, warmup_steps=2)

        def run(inject):
            pipe = DataPipeline(cfg, batch=4, seq_len=16, seed=9)
            with tempfile.TemporaryDirectory() as d:
                tr = Trainer(
                    cfg, tcfg, params, pipe,
                    ckpt_manager=CheckpointManager(d, async_save=False),
                    ckpt_every=5,
                    failure_injector=FailureInjector(
                        schedule={12: 0} if inject else {}),
                )
                tr.run(16)
                return tr

        clean = run(False)
        failed = run(True)
        assert failed.restarts == 1
        assert abs(clean.history[-1] - failed.history[-1]) < 1e-5

    def test_grad_compression_trains(self):
        """int8 error-feedback compression must track the uncompressed
        trajectory step-for-step (the EF buffer keeps the accumulated
        update unbiased), not just end finite."""
        cfg, params = _mk(seed=7)

        def run(bits):
            tcfg = TrainConfig(lr=1e-3, grad_compress_bits=bits,
                               total_steps=20, warmup_steps=2)
            pipe = DataPipeline(cfg, batch=2, seq_len=16, seed=3)
            tr = Trainer(cfg, tcfg, params, pipe,
                         straggler_monitor=StragglerMonitor())
            return tr.run(10)["loss"]

        comp, plain = run(8), run(0)
        assert all(np.isfinite(comp))
        assert max(abs(a - b) for a, b in zip(comp, plain)) < 0.05
        assert abs(comp[-1] - plain[-1]) < 0.02


class TestServing:
    def test_continuous_batching_completes_all(self):
        cfg, params = _mk(seed=1)
        eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=4),
                          n_slots=2, max_len=32)
        eng.submit([1, 2, 3])
        eng.submit([4])
        eng.submit([5, 6])
        done = eng.run()
        assert len(done) == 3
        assert all(len(r.output) == 4 for r in done)

    def test_greedy_deterministic(self):
        cfg, params = _mk(seed=2)
        outs = []
        for _ in range(2):
            eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=6),
                              n_slots=1, max_len=32)
            eng.submit([7, 8, 9])
            outs.append(eng.run()[0].output)
        assert outs[0] == outs[1]

    def test_engine_quantized_matches_dense_mostly(self):
        """int8 IMAGine serving: greedy tokens match the dense path for
        most steps (quantization noise may flip late tokens)."""
        cfg, params = _mk(seed=3)
        prompts = [[1, 2, 3], [9, 8]]

        def gen(engine_cfg):
            eng = ServeEngine(
                cfg, params,
                ServeConfig(max_new_tokens=4, engine=engine_cfg),
                n_slots=2, max_len=32)
            for p in prompts:
                eng.submit(p)
            return sorted(eng.run(), key=lambda r: r.rid)

        dense = gen(EngineConfig())
        quant = gen(EngineConfig(weight_bits=8, backend="reference"))
        # free-running generation compounds: once quantization noise flips
        # one low-margin token the suffix legitimately diverges.  Assert
        # the pre-divergence behaviour: every request opens on the dense
        # token, at least one request agrees end-to-end, and half of all
        # tokens match.  (Step-wise argmax agreement under teacher forcing
        # is pinned separately in test_engine_serving_modes.)
        assert all(a.output[0] == b.output[0] for a, b in zip(dense, quant))
        assert any(a.output == b.output for a, b in zip(dense, quant))
        matches = sum(
            t1 == t2
            for a, b in zip(dense, quant)
            for t1, t2 in zip(a.output, b.output))
        assert matches >= 4  # of 8 tokens


class TestQuantizedParams:
    @pytest.mark.parametrize("bits", [4, 8])
    def test_quantized_forward_close(self, bits):
        cfg, params = _mk(seed=4)
        qparams = quantize_params(params, cfg, bits)
        toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0,
                                  cfg.vocab_size)
        eng = EngineConfig(weight_bits=bits, backend="reference")
        lg_d, _ = forward(params, {"tokens": toks}, cfg, remat="none")
        lg_q, _ = forward(qparams, {"tokens": toks}, cfg, eng, remat="none")
        agree = float(jnp.mean(
            (jnp.argmax(lg_d, -1) == jnp.argmax(lg_q, -1))
            .astype(jnp.float32)))
        assert agree > (0.9 if bits == 8 else 0.5), agree

    def test_quantized_storage_shrinks(self):
        cfg, params = _mk(seed=4)

        def nbytes(t):
            return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t)
                       if hasattr(l, "dtype"))

        q8 = quantize_params(params, cfg, 8)
        q4 = quantize_params(params, cfg, 4)
        q2 = quantize_params(params, cfg, 2)
        assert nbytes(q8) < nbytes(params)
        assert nbytes(q4) < nbytes(q8)
        assert nbytes(q2) < nbytes(q4)

    def test_quantized_decode_runs_all_archs(self):
        for arch in ("gemma3-27b", "mamba2-130m", "zamba2-7b",
                     "qwen3-moe-235b-a22b", "musicgen-medium"):
            cfg, params = _mk(arch, seed=6, capacity_factor=8.0)
            qparams = quantize_params(params, cfg, 8)
            eng = EngineConfig(weight_bits=8, backend="reference")
            cache = init_cache(cfg, 2, max_len=8)
            shape = ((2, 1, cfg.n_codebooks) if cfg.family == "audio"
                     else (2, 1))
            tok = jnp.zeros(shape, jnp.int32)
            lg, _ = decode_step(qparams, cache, tok, cfg, eng)
            assert np.all(np.isfinite(np.asarray(lg, dtype=np.float32))), arch
