"""Fused paged-attention kernel: token-identity against the ``gather``
reference backend.

The kernel (``repro.kernels.paged_attention``) reads K/V pages in place
through the block table; these tests pin that the read path is a pure
relocation of bytes — page size × GQA group × sliding window × kv_bits
sweeps, a ragged last block, block tables reshuffled as preemption
free/re-alloc would leave them, and end-to-end greedy serving (including
under real preemption, reusing the ``test_serve_paged`` geometry).  The
in-kernel chunked-prefill grid gets the same treatment: ragged last
pages, mid-page ``pos0`` (a prefix-cache match ending inside a page),
int8 pools and sliding-window layers, each pinned against the gather
prefill path that materializes the KV view."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.engine import ATTN_BACKENDS, EnginePlan, resolve_attn_backend
from repro.kernels.paged_attention.ops import (
    decode_attn_bytes,
    prefill_attn_bytes,
    synthetic_prefill_case,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_prefill_ref,
)
from repro.models import init_params
from repro.models.attention import attend_paged_decode, attend_paged_prefill
from repro.serve import ServeEngine

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


def _pool(rng, n_pages, page, hkv, dh, kv_bits):
    if kv_bits:
        kp = rng.integers(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        vp = rng.integers(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        ks = rng.uniform(0.004, 0.02, (n_pages, page, hkv))
        vs = rng.uniform(0.004, 0.02, (n_pages, page, hkv))
        return (jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(ks, jnp.bfloat16), jnp.asarray(vs, jnp.bfloat16))
    kp = rng.standard_normal((n_pages, page, hkv, dh)).astype(np.float32)
    vp = rng.standard_normal((n_pages, page, hkv, dh)).astype(np.float32)
    return jnp.asarray(kp), jnp.asarray(vp), None, None


def _both(q, kp, vp, bt, pos, win, ks, vs):
    a = attend_paged_decode(q, kp, vp, bt, pos, win, k_scale=ks, v_scale=vs,
                            attn_backend="gather")
    b = attend_paged_decode(q, kp, vp, bt, pos, win, k_scale=ks, v_scale=vs,
                            attn_backend="pallas_interpret")
    return np.asarray(a), np.asarray(b)


# ------------------------------------------------------------- the sweep
@pytest.mark.parametrize(
    "page,group,window,kv_bits",
    [(p, g, w, kb)
     for p, g in itertools.product((2, 4), (1, 3))
     for w, kb in (((0, 0)), ((5, 0)), ((0, 8)), ((5, 8)))],
)
def test_fused_matches_gather(page, group, window, kv_bits):
    """Fused kernel output == gather output across page size × GQA group
    × sliding window × kv_bits, at ragged positions (last block partly
    unwritten) and distinct per-lane contexts."""
    rng = np.random.default_rng(7)
    b, hkv, dh, nblk = 3, 2, 8, 4
    hq = hkv * group
    n_pages = b * nblk + 1
    kp, vp, ks, vs = _pool(rng, n_pages, page, hkv, dh, kv_bits)
    bt = jnp.asarray(
        1 + rng.permutation(b * nblk).reshape(b, nblk), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hq, dh)), jnp.float32)
    # ragged everywhere: no lane sits on a page boundary, lane 2 has a
    # nearly-empty last block
    pos = jnp.asarray([page * nblk - 2, page + 1, 0], jnp.int32)
    a, f = _both(q, kp, vp, bt, pos, window, ks, vs)
    tol = 1e-2 if kv_bits else 1e-5
    np.testing.assert_allclose(a, f, rtol=tol, atol=tol)


def test_fused_close_on_bf16_pools():
    """bf16 pools (the default model dtype): the kernel mirrors the gather
    path's storage-dtype casts (q → pool dtype, p → V dtype), but online
    softmax normalizes *after* the bf16 rounding of p where the gather
    path normalizes before — agreement is within a bf16 ulp, not
    bitwise.  Exact token identity is pinned on f32 and int8 pools."""
    rng = np.random.default_rng(5)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    kp, vp, _, _ = _pool(rng, b * nblk + 1, page, hkv, dh, 0)
    kp, vp = kp.astype(jnp.bfloat16), vp.astype(jnp.bfloat16)
    bt = jnp.asarray(1 + rng.permutation(b * nblk).reshape(b, nblk),
                     jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.bfloat16)
    pos = jnp.asarray([9, 4], jnp.int32)
    a, f = _both(q, kp, vp, bt, pos, 0, None, None)
    np.testing.assert_allclose(a.astype(np.float32), f.astype(np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_matches_standalone_ref():
    """The kernel package's own gather reference (no repro.models import)
    agrees too — kernel tests and benches can diff against it directly."""
    rng = np.random.default_rng(3)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    kp, vp, _, _ = _pool(rng, b * nblk + 1, page, hkv, dh, 0)
    bt = jnp.asarray(1 + rng.permutation(b * nblk).reshape(b, nblk),
                     jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.float32)
    pos = jnp.asarray([9, 4], jnp.int32)
    ref = paged_attention_ref(q, kp, vp, bt, pos, 0)
    _, fused = _both(q, kp, vp, bt, pos, 0, None, None)
    np.testing.assert_allclose(np.asarray(ref), fused, rtol=1e-5, atol=1e-5)


def test_fused_invariant_under_page_reshuffle():
    """Preemption re-allocs hand a resumed request *different* physical
    pages; the same logical content through a permuted block table must
    produce bit-identical attention output."""
    rng = np.random.default_rng(11)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    n_pages = b * nblk + 1
    kp, vp, _, _ = _pool(rng, n_pages, page, hkv, dh, 0)
    bt = jnp.asarray(1 + np.arange(b * nblk).reshape(b, nblk), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.float32)
    pos = jnp.asarray([10, 7], jnp.int32)

    perm = np.concatenate([[0], 1 + rng.permutation(n_pages - 1)])
    inv = np.argsort(perm)
    kp2 = kp[jnp.asarray(perm)]            # physical page p moves to inv[p]
    vp2 = vp[jnp.asarray(perm)]
    bt2 = jnp.asarray(inv[np.asarray(bt)], jnp.int32)

    _, f1 = _both(q, kp, vp, bt, pos, 0, None, None)
    _, f2 = _both(q, kp2, vp2, bt2, pos, 0, None, None)
    np.testing.assert_array_equal(f1, f2)


# ------------------------------------------------- in-kernel prefill grid
def _both_prefill(case, window=0):
    """(gather, fused) outputs of ``attend_paged_prefill`` on one case."""
    b, c = case["q"].shape[:2]
    positions = case["pos0"][:, None] + jnp.arange(c, dtype=jnp.int32)[None]
    args = (case["q"], case["k_pages"], case["v_pages"],
            case["block_tables"], positions, case["pos0"], case["seq_lens"],
            window, case["k_scale"], case["v_scale"])
    a = attend_paged_prefill(*args, attn_backend="gather")
    f = attend_paged_prefill(*args, attn_backend="pallas_interpret")
    return np.asarray(a), np.asarray(f)


@pytest.mark.parametrize("window,kv_bits",
                         [(0, 0), (6, 0), (0, 8), (6, 8)])
def test_prefill_fused_matches_gather(window, kv_bits):
    """The prefill grid == the gather prefill path across sliding window ×
    kv_bits, on the standard synthetic case: every lane's ``pos0`` lands
    mid-page (a prefix-cache match offset, not page-aligned) and the last
    lane's chunk is ragged (``seq_lens < pos0 + chunk``)."""
    rng = np.random.default_rng(17)
    case = synthetic_prefill_case(rng, batch=3, nblk=5, page=4, hkv=2,
                                  group=2, dh=16, chunk=6, kv_bits=kv_bits)
    a, f = _both_prefill(case, window)
    tol = 1e-2 if kv_bits else 1e-5
    np.testing.assert_allclose(a, f, rtol=tol, atol=tol)


def test_prefill_fused_ragged_last_page():
    """A chunk whose final KV page is mostly unwritten: the in-kernel
    ``kv_pos < limit`` mask must drop exactly the unwritten tail — one
    valid token on the last page, the rest garbage the gather path never
    materializes."""
    rng = np.random.default_rng(23)
    page, chunk = 4, 9            # pos0=0 → last page holds 1 of 4 slots
    case = synthetic_prefill_case(rng, batch=1, nblk=4, page=page, hkv=2,
                                  group=1, dh=8, chunk=chunk, kv_bits=0)
    case["pos0"] = jnp.zeros_like(case["pos0"])
    case["seq_lens"] = jnp.full_like(case["seq_lens"], chunk)
    a, f = _both_prefill(case)
    np.testing.assert_allclose(a, f, rtol=1e-5, atol=1e-5)


def test_prefill_fused_midpage_pos0():
    """Suffix-only prefill after a prefix-cache hit that ends *inside* a
    page: ``pos0`` is not page-aligned, so the first query row attends a
    partially-filled page and the causal mask starts mid-page."""
    rng = np.random.default_rng(29)
    page = 4
    case = synthetic_prefill_case(rng, batch=2, nblk=5, page=page, hkv=2,
                                  group=2, dh=8, chunk=5, kv_bits=0)
    pos0 = jnp.asarray([page + 2, 2 * page + 3], jnp.int32)  # both mid-page
    case["pos0"] = pos0
    case["seq_lens"] = pos0 + 5
    a, f = _both_prefill(case)
    np.testing.assert_allclose(a, f, rtol=1e-5, atol=1e-5)


def test_prefill_fused_matches_standalone_ref():
    """The kernel package's own prefill gather reference (no repro.models
    import) agrees — benches can diff against it directly."""
    rng = np.random.default_rng(31)
    case = synthetic_prefill_case(rng, batch=2, nblk=4, page=4, hkv=2,
                                  group=2, dh=8, chunk=6, kv_bits=0)
    ref = paged_prefill_ref(case["q"], case["k_pages"], case["v_pages"],
                            case["block_tables"], case["pos0"],
                            case["seq_lens"], 0, None, None)
    _, f = _both_prefill(case)
    b, c = case["q"].shape[:2]
    valid = np.asarray(case["seq_lens"] - case["pos0"])  # per-lane real rows
    for lane in range(b):
        np.testing.assert_allclose(np.asarray(ref)[lane, :valid[lane]],
                                   f[lane, :valid[lane]],
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------- end-to-end serving
def _serve(cfg, params, abk, *, engine=None, max_new=5, n_slots=2,
           max_len=32, **kw):
    scfg = ServeConfig(max_new_tokens=max_new, engine=engine or EngineConfig())
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode="paged", attn_backend=abk, **kw)
    for p in PROMPTS:
        eng.submit(p)
    return eng, [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_serve_token_identity(rng, kv_bits):
    """Greedy serving through the fused kernel emits exactly the gather
    backend's tokens — kv_bits ∈ {0, 8} through one dispatch."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    engine = (EngineConfig(kv_bits=kv_bits, backend="reference")
              if kv_bits else None)
    _, ref = _serve(cfg, params, "gather", engine=engine,
                    page_size=4, prefill_chunk=3)
    _, fused = _serve(cfg, params, "pallas_interpret", engine=engine,
                      page_size=4, prefill_chunk=3)
    assert ref == fused


def test_serve_token_identity_sliding_window(rng):
    """gemma3-family local/global stack: the traced per-layer window rides
    into the kernel as a runtime scalar under the layer scan."""
    cfg = reduced_f32("gemma3-27b")
    params = init_params(cfg, rng)
    _, ref = _serve(cfg, params, "gather", page_size=4, prefill_chunk=3)
    _, fused = _serve(cfg, params, "pallas_interpret", page_size=4,
                      prefill_chunk=3)
    assert ref == fused


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_serve_token_identity_with_prefix_cache(rng, kv_bits):
    """Prefix-cache hits feed the in-kernel prefill grid a mid-page
    ``pos0`` (the B prompt's match ends 2 tokens into a page): cache-hit
    suffix-only prefill through the fused kernel matches the gather
    backend token for token, and the hit path really ran."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    a = list(range(1, 13))
    prompts = [a, list(range(1, 11)) + [99, 100], list(a), [71, 72, 73]]
    engine = (EngineConfig(kv_bits=kv_bits, backend="reference")
              if kv_bits else None)

    def gen(abk):
        scfg = ServeConfig(max_new_tokens=5, engine=engine or EngineConfig())
        # n_slots=1 serializes admission so B and C find A's pages
        # committed (their matches end mid-page: 10 and 11 tokens)
        eng = ServeEngine(cfg, params, scfg, n_slots=1, max_len=32,
                          mode="paged", attn_backend=abk, page_size=4,
                          prefill_chunk=3, prefix_cache=True)
        for p in prompts:
            eng.submit(list(p))
        return eng, [r.output for r in sorted(eng.run(),
                                              key=lambda r: r.rid)]

    ref_eng, ref = gen("gather")
    fused_eng, fused = gen("pallas_interpret")
    assert fused_eng.prefix_stats()["hits"] >= 2
    assert ref_eng.prefix_stats() == fused_eng.prefix_stats()
    assert ref == fused


def test_serve_token_identity_under_preemption(rng):
    """The test_serve_paged preemption geometry (pool too small for all
    residents), decoded through the fused kernel: recompute-resume with
    reshuffled block tables keeps greedy tokens exact."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    ref_eng, ref = _serve(cfg, params, "gather", max_new=16, n_slots=3,
                          max_len=48, page_size=4, n_pages=14,
                          prefill_chunk=4)
    fused_eng, fused = _serve(cfg, params, "pallas_interpret", max_new=16,
                              n_slots=3, max_len=48, page_size=4, n_pages=14,
                              prefill_chunk=4)
    assert ref_eng.preemptions > 0 and fused_eng.preemptions > 0
    assert ref == fused


# ------------------------------------------------------- plan threading
def test_plan_resolves_attn_backend():
    plan = EnginePlan(backend="reference", bits=8)
    assert plan.attn_backend in ("gather", "pallas_tpu")  # never "auto"
    if jax.default_backend() != "tpu":
        assert plan.attn_backend == "gather"
    pinned = EnginePlan(backend="reference", bits=8,
                        attn_backend="pallas_interpret")
    assert pinned.attn_backend == "pallas_interpret"
    with pytest.raises(KeyError):
        EnginePlan(backend="reference", bits=8, attn_backend="nope")
    assert resolve_attn_backend("gather") == "gather"
    assert resolve_attn_backend(None) in ATTN_BACKENDS


def test_auto_no_longer_downgrades_on_mesh():
    """'auto' resolves identically with and without a mesh: the fused
    kernel shard_maps over the pool's model axis now, so a mesh-carrying
    TPU plan runs fused by default (the old downgrade of auto-on-mesh to
    gather is gone).  On this host that means both resolve to the same
    host default; an explicit pallas name is still honored anywhere."""
    from repro.dist import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    assert (resolve_attn_backend("auto", mesh=mesh)
            == resolve_attn_backend("auto"))
    plan = EnginePlan(backend="reference", bits=8, mesh=mesh)
    flat = EnginePlan(backend="reference", bits=8)
    assert plan.attn_backend == flat.attn_backend  # mesh changes nothing
    pinned = EnginePlan(backend="reference", bits=8, mesh=mesh,
                        attn_backend="pallas_interpret")
    assert pinned.attn_backend == "pallas_interpret"


def test_serve_engine_honors_config_attn_backend(rng):
    """EngineConfig.attn_backend reaches the engine even when the engine
    is otherwise disabled (plan resolves to None)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    scfg = ServeConfig(engine=EngineConfig(attn_backend="pallas_interpret"))
    eng = ServeEngine(cfg, params, scfg, n_slots=1, max_len=16, mode="paged")
    assert eng.plan is None
    assert eng.attn_backend == "pallas_interpret"
    # explicit kwarg wins over the config
    eng2 = ServeEngine(cfg, params, scfg, n_slots=1, max_len=16,
                       mode="paged", attn_backend="gather")
    assert eng2.attn_backend == "gather"


# ------------------------------------------------------ bytes-moved model
def test_bytes_model_fused_below_gather():
    """The modeled read-path traffic of the fused kernel is strictly below
    gather at every context length >= one page, both precisions.  (A
    self-consistency check of the analytic model — it guards edits to
    ``decode_attn_bytes``; the kernel's real traffic is a TPU item.)"""
    for kv_bits in (0, 8):
        for context in (4, 16, 64, 512, 4096):
            kw = dict(batch=4, context=context, n_kv_heads=4, head_dim=64,
                      n_q_heads=8, page_size=4, kv_bits=kv_bits)
            gather = decode_attn_bytes("gather", **kw)
            fused = decode_attn_bytes("pallas_interpret", **kw)
            assert fused < gather, (kv_bits, context, fused, gather)
            # the win is the dropped view write + re-read: ~3x on the
            # KV term, diluted only by the shared Q/O traffic
            assert gather - fused > gather / 3


def test_prefill_bytes_model_fused_below_gather():
    """Same self-consistency guard for the chunked-prefill traffic model:
    in-kernel prefill never materializes the gathered (B, T, Hkv, Dh)
    view, so its modeled bytes sit below gather at every context — and
    once the context dwarfs the chunk (the KV view term dominating the
    shared Q/O traffic) the dropped write + re-read is most of the
    total, same ~3x-on-the-view win as decode."""
    for kv_bits in (0, 8):
        for context in (16, 64, 512, 4096):
            kw = dict(batch=4, chunk=16, context=context, n_kv_heads=4,
                      head_dim=64, n_q_heads=8, page_size=4,
                      kv_bits=kv_bits)
            gather = prefill_attn_bytes("gather", **kw)
            fused = prefill_attn_bytes("pallas_interpret", **kw)
            assert fused < gather, (kv_bits, context, fused, gather)
            if context >= 32 * kw["chunk"]:  # view-dominated regime
                assert gather - fused > gather / 3
