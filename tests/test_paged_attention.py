"""Fused paged-attention kernel: token-identity against the ``gather``
reference backend.

The kernel (``repro.kernels.paged_attention``) reads K/V pages in place
through the block table; these tests pin that the read path is a pure
relocation of bytes — page size × GQA group × sliding window × kv_bits
sweeps, a ragged last block, block tables reshuffled as preemption
free/re-alloc would leave them, and end-to-end greedy serving (including
under real preemption, reusing the ``test_serve_paged`` geometry)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.engine import ATTN_BACKENDS, EnginePlan, resolve_attn_backend
from repro.kernels.paged_attention.ops import decode_attn_bytes
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models import init_params
from repro.models.attention import attend_paged_decode
from repro.serve import ServeEngine

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


def _pool(rng, n_pages, page, hkv, dh, kv_bits):
    if kv_bits:
        kp = rng.integers(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        vp = rng.integers(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        ks = rng.uniform(0.004, 0.02, (n_pages, page, hkv))
        vs = rng.uniform(0.004, 0.02, (n_pages, page, hkv))
        return (jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(ks, jnp.bfloat16), jnp.asarray(vs, jnp.bfloat16))
    kp = rng.standard_normal((n_pages, page, hkv, dh)).astype(np.float32)
    vp = rng.standard_normal((n_pages, page, hkv, dh)).astype(np.float32)
    return jnp.asarray(kp), jnp.asarray(vp), None, None


def _both(q, kp, vp, bt, pos, win, ks, vs):
    a = attend_paged_decode(q, kp, vp, bt, pos, win, k_scale=ks, v_scale=vs,
                            attn_backend="gather")
    b = attend_paged_decode(q, kp, vp, bt, pos, win, k_scale=ks, v_scale=vs,
                            attn_backend="pallas_interpret")
    return np.asarray(a), np.asarray(b)


# ------------------------------------------------------------- the sweep
@pytest.mark.parametrize(
    "page,group,window,kv_bits",
    [(p, g, w, kb)
     for p, g in itertools.product((2, 4), (1, 3))
     for w, kb in (((0, 0)), ((5, 0)), ((0, 8)), ((5, 8)))],
)
def test_fused_matches_gather(page, group, window, kv_bits):
    """Fused kernel output == gather output across page size × GQA group
    × sliding window × kv_bits, at ragged positions (last block partly
    unwritten) and distinct per-lane contexts."""
    rng = np.random.default_rng(7)
    b, hkv, dh, nblk = 3, 2, 8, 4
    hq = hkv * group
    n_pages = b * nblk + 1
    kp, vp, ks, vs = _pool(rng, n_pages, page, hkv, dh, kv_bits)
    bt = jnp.asarray(
        1 + rng.permutation(b * nblk).reshape(b, nblk), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hq, dh)), jnp.float32)
    # ragged everywhere: no lane sits on a page boundary, lane 2 has a
    # nearly-empty last block
    pos = jnp.asarray([page * nblk - 2, page + 1, 0], jnp.int32)
    a, f = _both(q, kp, vp, bt, pos, window, ks, vs)
    tol = 1e-2 if kv_bits else 1e-5
    np.testing.assert_allclose(a, f, rtol=tol, atol=tol)


def test_fused_close_on_bf16_pools():
    """bf16 pools (the default model dtype): the kernel mirrors the gather
    path's storage-dtype casts (q → pool dtype, p → V dtype), but online
    softmax normalizes *after* the bf16 rounding of p where the gather
    path normalizes before — agreement is within a bf16 ulp, not
    bitwise.  Exact token identity is pinned on f32 and int8 pools."""
    rng = np.random.default_rng(5)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    kp, vp, _, _ = _pool(rng, b * nblk + 1, page, hkv, dh, 0)
    kp, vp = kp.astype(jnp.bfloat16), vp.astype(jnp.bfloat16)
    bt = jnp.asarray(1 + rng.permutation(b * nblk).reshape(b, nblk),
                     jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.bfloat16)
    pos = jnp.asarray([9, 4], jnp.int32)
    a, f = _both(q, kp, vp, bt, pos, 0, None, None)
    np.testing.assert_allclose(a.astype(np.float32), f.astype(np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_matches_standalone_ref():
    """The kernel package's own gather reference (no repro.models import)
    agrees too — kernel tests and benches can diff against it directly."""
    rng = np.random.default_rng(3)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    kp, vp, _, _ = _pool(rng, b * nblk + 1, page, hkv, dh, 0)
    bt = jnp.asarray(1 + rng.permutation(b * nblk).reshape(b, nblk),
                     jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.float32)
    pos = jnp.asarray([9, 4], jnp.int32)
    ref = paged_attention_ref(q, kp, vp, bt, pos, 0)
    _, fused = _both(q, kp, vp, bt, pos, 0, None, None)
    np.testing.assert_allclose(np.asarray(ref), fused, rtol=1e-5, atol=1e-5)


def test_fused_invariant_under_page_reshuffle():
    """Preemption re-allocs hand a resumed request *different* physical
    pages; the same logical content through a permuted block table must
    produce bit-identical attention output."""
    rng = np.random.default_rng(11)
    b, hkv, g, dh, page, nblk = 2, 2, 2, 8, 4, 3
    n_pages = b * nblk + 1
    kp, vp, _, _ = _pool(rng, n_pages, page, hkv, dh, 0)
    bt = jnp.asarray(1 + np.arange(b * nblk).reshape(b, nblk), jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, dh)), jnp.float32)
    pos = jnp.asarray([10, 7], jnp.int32)

    perm = np.concatenate([[0], 1 + rng.permutation(n_pages - 1)])
    inv = np.argsort(perm)
    kp2 = kp[jnp.asarray(perm)]            # physical page p moves to inv[p]
    vp2 = vp[jnp.asarray(perm)]
    bt2 = jnp.asarray(inv[np.asarray(bt)], jnp.int32)

    _, f1 = _both(q, kp, vp, bt, pos, 0, None, None)
    _, f2 = _both(q, kp2, vp2, bt2, pos, 0, None, None)
    np.testing.assert_array_equal(f1, f2)


# --------------------------------------------------- end-to-end serving
def _serve(cfg, params, abk, *, engine=None, max_new=5, n_slots=2,
           max_len=32, **kw):
    scfg = ServeConfig(max_new_tokens=max_new, engine=engine or EngineConfig())
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode="paged", attn_backend=abk, **kw)
    for p in PROMPTS:
        eng.submit(p)
    return eng, [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_serve_token_identity(rng, kv_bits):
    """Greedy serving through the fused kernel emits exactly the gather
    backend's tokens — kv_bits ∈ {0, 8} through one dispatch."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    engine = (EngineConfig(kv_bits=kv_bits, backend="reference")
              if kv_bits else None)
    _, ref = _serve(cfg, params, "gather", engine=engine,
                    page_size=4, prefill_chunk=3)
    _, fused = _serve(cfg, params, "pallas_interpret", engine=engine,
                      page_size=4, prefill_chunk=3)
    assert ref == fused


def test_serve_token_identity_sliding_window(rng):
    """gemma3-family local/global stack: the traced per-layer window rides
    into the kernel as a runtime scalar under the layer scan."""
    cfg = reduced_f32("gemma3-27b")
    params = init_params(cfg, rng)
    _, ref = _serve(cfg, params, "gather", page_size=4, prefill_chunk=3)
    _, fused = _serve(cfg, params, "pallas_interpret", page_size=4,
                      prefill_chunk=3)
    assert ref == fused


def test_serve_token_identity_under_preemption(rng):
    """The test_serve_paged preemption geometry (pool too small for all
    residents), decoded through the fused kernel: recompute-resume with
    reshuffled block tables keeps greedy tokens exact."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    ref_eng, ref = _serve(cfg, params, "gather", max_new=16, n_slots=3,
                          max_len=48, page_size=4, n_pages=14,
                          prefill_chunk=4)
    fused_eng, fused = _serve(cfg, params, "pallas_interpret", max_new=16,
                              n_slots=3, max_len=48, page_size=4, n_pages=14,
                              prefill_chunk=4)
    assert ref_eng.preemptions > 0 and fused_eng.preemptions > 0
    assert ref == fused


# ------------------------------------------------------- plan threading
def test_plan_resolves_attn_backend():
    plan = EnginePlan(backend="reference", bits=8)
    assert plan.attn_backend in ("gather", "pallas_tpu")  # never "auto"
    if jax.default_backend() != "tpu":
        assert plan.attn_backend == "gather"
    pinned = EnginePlan(backend="reference", bits=8,
                        attn_backend="pallas_interpret")
    assert pinned.attn_backend == "pallas_interpret"
    with pytest.raises(KeyError):
        EnginePlan(backend="reference", bits=8, attn_backend="nope")
    assert resolve_attn_backend("gather") == "gather"
    assert resolve_attn_backend(None) in ATTN_BACKENDS


def test_auto_resolves_to_gather_on_mesh():
    """'auto' on a mesh-carrying plan stays on the gather path (the fused
    kernel is not shard_mapped over the sharded pool yet); an explicit
    pallas name is honored as the caller's opt-in."""
    from repro.dist import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    plan = EnginePlan(backend="reference", bits=8, mesh=mesh)
    assert plan.attn_backend == "gather"
    pinned = EnginePlan(backend="reference", bits=8, mesh=mesh,
                        attn_backend="pallas_interpret")
    assert pinned.attn_backend == "pallas_interpret"
    assert resolve_attn_backend("auto", mesh=mesh) == "gather"


def test_serve_engine_honors_config_attn_backend(rng):
    """EngineConfig.attn_backend reaches the engine even when the engine
    is otherwise disabled (plan resolves to None)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    scfg = ServeConfig(engine=EngineConfig(attn_backend="pallas_interpret"))
    eng = ServeEngine(cfg, params, scfg, n_slots=1, max_len=16, mode="paged")
    assert eng.plan is None
    assert eng.attn_backend == "pallas_interpret"
    # explicit kwarg wins over the config
    eng2 = ServeEngine(cfg, params, scfg, n_slots=1, max_len=16,
                       mode="paged", attn_backend="gather")
    assert eng2.attn_backend == "gather"


# ------------------------------------------------------ bytes-moved model
def test_bytes_model_fused_below_gather():
    """The modeled read-path traffic of the fused kernel is strictly below
    gather at every context length >= one page, both precisions.  (A
    self-consistency check of the analytic model — it guards edits to
    ``decode_attn_bytes``; the kernel's real traffic is a TPU item.)"""
    for kv_bits in (0, 8):
        for context in (4, 16, 64, 512, 4096):
            kw = dict(batch=4, context=context, n_kv_heads=4, head_dim=64,
                      n_q_heads=8, page_size=4, kv_bits=kv_bits)
            gather = decode_attn_bytes("gather", **kw)
            fused = decode_attn_bytes("pallas_interpret", **kw)
            assert fused < gather, (kv_bits, context, fused, gather)
            # the win is the dropped view write + re-read: ~3x on the
            # KV term, diluted only by the shared Q/O traffic
            assert gather - fused > gather / 3
