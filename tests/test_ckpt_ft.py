"""Checkpointing + fault tolerance: roundtrip, integrity, rotation, async,
restart drills, elastic shrink plans, straggler detection."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import latest_step
from repro.config.base import MeshConfig
from repro.ft import (
    ElasticMeshManager,
    FailureInjector,
    RestartPolicy,
    StragglerMonitor,
)
from repro.ft.failures import SimulatedNodeFailure, run_with_restarts


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


class TestCheckpoint:
    def test_roundtrip(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 5, tree, extra={"data_step": 5})
            out, extra = load_checkpoint(d, jax.tree.map(jnp.zeros_like, tree))
            assert extra["data_step"] == 5
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_multi_host_roundtrip(self):
        tree = _tree(1)
        with tempfile.TemporaryDirectory() as d:
            # hosts write their leaf shards; host 0 last to finalize
            for h in (1, 2, 0):
                save_checkpoint(d, 3, tree, host_id=h, n_hosts=3)
            out, _ = load_checkpoint(d, jax.tree.map(jnp.zeros_like, tree))
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            path = save_checkpoint(d, 1, tree)
            shard = os.path.join(path, "shard_0.bin")
            blob = bytearray(open(shard, "rb").read())
            blob[len(blob) // 2] ^= 0xFF
            open(shard, "wb").write(bytes(blob))
            with pytest.raises(Exception):
                load_checkpoint(d, tree)

    def test_uncommitted_invisible(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            path = save_checkpoint(d, 2, tree)
            os.remove(os.path.join(path, "COMMITTED"))
            assert latest_step(d) is None

    def test_manager_rotation_and_resume(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False)
            for s in (10, 20, 30):
                mgr.save(s, tree)
            steps = sorted(int(n.split("_")[1])
                           for n in os.listdir(d) if n.startswith("step_"))
            assert steps == [20, 30]
            step, out, _ = mgr.restore_latest(tree)
            assert step == 30

    def test_async_save(self):
        tree = _tree()
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3, async_save=True)
            mgr.save(1, tree)
            mgr.wait()
            assert latest_step(d) == 1


class TestFailureRecovery:
    def test_injector_raises_once(self):
        inj = FailureInjector(schedule={3: 7})
        inj.check(2)
        with pytest.raises(SimulatedNodeFailure):
            inj.check(3)
        inj.check(3)  # consumed

    def test_restart_policy_budget(self):
        pol = RestartPolicy(max_restarts=2, backoff_s=0.0)
        pol.on_failure(RuntimeError("x"), 1)
        pol.on_failure(RuntimeError("x"), 2)
        with pytest.raises(RuntimeError):
            pol.on_failure(RuntimeError("x"), 3)

    def test_restart_policy_resets_after_healthy_period(self):
        """reset_after_steps: the budget bounds failure *density* — a
        long healthy stretch earns the counter back."""
        pol = RestartPolicy(max_restarts=2, backoff_s=0.0,
                            reset_after_steps=10)
        pol.on_failure(RuntimeError("x"), 1)
        pol.on_failure(RuntimeError("x"), 5)
        assert pol.restarts == 2
        # 10+ healthy steps since the last failure: counter resets first
        pol.on_failure(RuntimeError("x"), 20)
        assert pol.restarts == 1
        pol.on_failure(RuntimeError("x"), 21)
        with pytest.raises(RuntimeError):
            pol.on_failure(RuntimeError("x"), 22)

    def test_restart_policy_no_reset_within_window(self):
        """Failures closer together than the window still exhaust."""
        pol = RestartPolicy(max_restarts=2, backoff_s=0.0,
                            reset_after_steps=10)
        pol.on_failure(RuntimeError("x"), 1)
        pol.on_failure(RuntimeError("x"), 9)
        with pytest.raises(RuntimeError):
            pol.on_failure(RuntimeError("x"), 15)  # only 6 steps healthy

    def test_restart_policy_zero_window_never_resets(self):
        """reset_after_steps=0 keeps the original accumulate-forever
        semantics (the training loop's behavior, unchanged)."""
        pol = RestartPolicy(max_restarts=2, backoff_s=0.0)
        pol.on_failure(RuntimeError("x"), 0)
        pol.on_failure(RuntimeError("x"), 10_000)
        with pytest.raises(RuntimeError):
            pol.on_failure(RuntimeError("x"), 1_000_000)

    def test_run_with_restarts_recovers(self):
        executed = []
        ckpt = {"step": 0}

        def step_fn(s):
            executed.append(s)
            if (s + 1) % 4 == 0:
                ckpt["step"] = s + 1

        inj = FailureInjector(schedule={6: 1, 9: 2})
        restarts = run_with_restarts(
            step_fn, start_step=0, total_steps=12,
            restore_fn=lambda: ckpt["step"],
            policy=RestartPolicy(backoff_s=0.0),
            injector=inj)
        assert restarts == 2
        assert max(executed) == 11
        # every step eventually executed
        assert set(range(12)) <= set(executed)


class TestElastic:
    def test_shrink_pod_loss(self):
        mgr = ElasticMeshManager(MeshConfig(multi_pod=True))
        plan = mgr.plan_shrink(lost_nodes=64, chips_per_node=4)  # lose a pod
        assert plan.new_shape[-1] == 16          # model axis intact
        total_old = 512
        total_new = 1
        for s in plan.new_shape:
            total_new *= s
        assert total_new == 256
        assert plan.grad_accum_factor == 2       # keep global batch

    def test_shrink_partial(self):
        mgr = ElasticMeshManager(MeshConfig(multi_pod=False))
        plan = mgr.plan_shrink(lost_nodes=8, chips_per_node=4)  # 256->224
        total = 1
        for s in plan.new_shape:
            total *= s
        assert total <= 224 and plan.new_shape[-1] == 16

    def test_shrink_too_much(self):
        mgr = ElasticMeshManager(MeshConfig(multi_pod=False))
        with pytest.raises(ValueError):
            mgr.plan_shrink(lost_nodes=64, chips_per_node=4)


class TestStraggler:
    def test_detects_persistent_straggler(self):
        mon = StragglerMonitor(threshold=1.5, patience=3, mitigation="skip")
        events = []
        for step in range(6):
            times = {h: 1.0 for h in range(8)}
            times[3] = 3.0  # host 3 is chronically slow
            events += mon.observe(step, times)
        assert events and all(e.host == 3 for e in events)
        assert events[0].action == "skip"
        assert 3 in mon.chronic_hosts()

    def test_tolerates_transient_blip(self):
        mon = StragglerMonitor(threshold=1.5, patience=3)
        events = []
        for step in range(8):
            times = {h: 1.0 for h in range(4)}
            if step == 2:
                times[1] = 5.0  # one-off blip
            events += mon.observe(step, times)
        assert not events
