"""Mesh-native engine: plan resolution with mesh/partition fields, the
``sharded`` backend's bit-for-bit equivalence with its wrapped
single-device backend across the bits × radix sweep, and paged serving on
a (data, model) mesh (token-identical to the unsharded paged engine,
including under preemption).

Multi-device pieces run in a subprocess with 8 forced host devices (the
test_dist pattern), so this process's single-device view is untouched.

The equivalence sweep uses *integer-grid* data (integer activations,
weights that quantize to integers times a power-of-two scale): every fp32
product and partial sum is then exact, so column-parallel reassembly AND
row-parallel ``psum`` reduction are bit-identical to the single-device
accumulation — "bit-for-bit (fp32 accumulate)" is literal, not a
tolerance.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig
from repro.engine import (
    EnginePlan,
    pack_linear,
    partition_kind,
    resolve_plan,
)


def _run_sub(code: str):
    pre = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        sys.path.insert(0, "tests")
        import jax, jax.numpy as jnp
        import numpy as np

        def grid_data(b, k, n, bits, seed=0):
            '''Integer-grid (w, x): quantizes exactly, scale = 2^-3.'''
            qmax = 2 ** (bits - 1) - 1
            rng = np.random.default_rng(seed)
            q = rng.integers(-qmax, qmax + 1, (k, n)).astype(np.float32)
            q[0, :] = qmax   # pin per-column absmax -> scale exactly 2^-3
            w = jnp.asarray(q * 2.0 ** -3)
            x = jnp.asarray(rng.integers(-8, 9, (b, k)).astype(np.float32))
            return w, x
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", pre + textwrap.dedent(code)],
                         capture_output=True, text=True, cwd=repo,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# plan resolution (single device — no mesh required)
# ---------------------------------------------------------------------------


def test_sharded_plan_from_config():
    """EngineConfig.sharded wraps the named backend: the plan's backend is
    'sharded' and the config's backend becomes the inner backend."""
    plan = resolve_plan(EngineConfig(weight_bits=8, backend="reference",
                                     sharded=True, psum_bits=8))
    assert plan.backend == "sharded"
    assert plan.inner_backend == "reference"
    assert plan.psum_bits == 8
    assert plan.mesh is None  # resolution without a mesh is legal
    # memoized on (cfg, backend, mesh)
    again = resolve_plan(EngineConfig(weight_bits=8, backend="reference",
                                      sharded=True, psum_bits=8))
    assert plan is again


def test_sharded_plan_validation():
    with pytest.raises(KeyError):
        EnginePlan(backend="sharded", bits=8, inner_backend="no_such")
    with pytest.raises(ValueError):
        EnginePlan(backend="sharded", bits=8, inner_backend="sharded")
    with pytest.raises(ValueError):
        EnginePlan(backend="reference", bits=8, psum_bits=5)
    with pytest.raises(ValueError):
        EngineConfig(psum_bits=3)


def test_sharded_degrades_without_mesh():
    """No mesh on the plan -> the wrapped backend runs unsharded,
    bit-identically (degrade-to-replication, never an error)."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    lin = pack_linear(w, 8)
    y_ref = EnginePlan(backend="reference", bits=8).apply(
        lin, x, out_dtype=jnp.float32)
    y_sh = EnginePlan(backend="sharded", bits=8,
                      inner_backend="reference").apply(
        lin, x, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_sh))


def test_partition_kind_rules():
    rng = np.random.default_rng(1)

    def lin(k, n, bits=8):
        return pack_linear(
            jnp.asarray(rng.standard_normal((k, n)).astype(np.float32)),
            bits)

    assert partition_kind(lin(128, 64), 8) == "col"
    assert partition_kind(lin(128, 20), 8) == "row"   # N not divisible
    assert partition_kind(lin(100, 20), 8) == "replicate"  # neither
    assert partition_kind(lin(128, 64), 1) == "replicate"  # trivial mesh
    # stacked experts stay replicated at this layer (expert-parallelism
    # is the param-spec layer's job)
    stacked = pack_linear(jnp.asarray(
        rng.standard_normal((4, 64, 64)).astype(np.float32)), 8)
    assert partition_kind(stacked, 8) == "replicate"


def test_partition_preference_follows_weight_name():
    """quantize_params stamps the dist.sharding placement into the weight:
    wo/w_down prefer row-parallel even when both axes divide (a weight
    placed P('model', None) must not be re-gathered column-parallel inside
    every decode step), wq/w_up prefer col; the preference yields when its
    axis does not divide."""
    import jax

    from conftest import reduced_f32
    from repro.models import init_params, quantize_params

    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
    assert partition_kind(pack_linear(w, 8, partition="row"), 8) == "row"
    assert partition_kind(pack_linear(w, 8, partition="col"), 8) == "col"
    assert partition_kind(pack_linear(w, 8), 8) == "col"   # auto
    # preference yields when non-divisible: (100, 64) cannot row-split
    w2 = jnp.asarray(rng.standard_normal((100, 64)).astype(np.float32))
    assert partition_kind(pack_linear(w2, 8, partition="row"), 8) == "col"

    cfg = reduced_f32("qwen2.5-3b")
    q = quantize_params(init_params(cfg, jax.random.PRNGKey(0)), cfg, 8)
    attn = q["layers"]["attn"]
    assert attn["wq"].partition == "col"
    assert attn["wo"].partition == "row"
    assert q["layers"]["mlp"]["w_down"].partition == "row"
    # the preference is static metadata: survives tree ops and scan slices
    sliced = jax.tree.map(lambda a: a[0], attn["wo"])
    assert sliced.partition == "row"


# ---------------------------------------------------------------------------
# the equivalence sweep (8 forced host devices)
# ---------------------------------------------------------------------------


def test_sharded_backend_bit_for_bit_sweep():
    """bits × radix × {col, row} × inner backend on an 8-way model axis:
    the sharded backend's output is bit-identical to the wrapped
    single-device backend (fp32 accumulate, integer-grid data)."""
    _run_sub("""
    from repro.dist import make_mesh
    from repro.engine import EnginePlan, pack_linear, partition_kind

    mesh = make_mesh((1, 8), ("data", "model"))
    n_cases = 0
    for bits in (2, 4, 8):
        for radix in (1, 2, 4):
            if bits % radix:
                continue
            for inner in ("reference", "bit_serial"):
                for kind, (k, n) in (("col", (128, 64)), ("row", (128, 20))):
                    w, x = grid_data(3, k, n, bits, seed=17 * bits + radix)
                    lin = pack_linear(w, bits)
                    assert partition_kind(lin, 8) == kind, (kind, bits)
                    ref = EnginePlan(backend=inner, bits=bits, radix=radix
                                     ).apply(lin, x, out_dtype=jnp.float32)
                    sh = EnginePlan(backend="sharded", bits=bits,
                                    radix=radix, mesh=mesh,
                                    inner_backend=inner
                                    ).apply(lin, x, out_dtype=jnp.float32)
                    np.testing.assert_array_equal(
                        np.asarray(ref), np.asarray(sh),
                        err_msg=f"{inner}/{kind} bits={bits} radix={radix}")
                    n_cases += 1
    assert n_cases == 32, n_cases  # 8 (bits, radix) pairs x 2 inner x 2
    print("bit-for-bit sweep OK:", n_cases, "cases")
    """)


def test_sharded_backend_pallas_inner_and_ranks():
    """The Pallas-interpret kernel as the wrapped backend, plus 1D and
    batched-3D activations through the sharded dispatch."""
    _run_sub("""
    from repro.dist import make_mesh
    from repro.engine import EnginePlan, pack_linear

    mesh = make_mesh((1, 8), ("data", "model"))
    w, x = grid_data(3, 128, 64, 8, seed=5)
    lin = pack_linear(w, 8)
    for xx in (x, x[0], jnp.stack([x, 2.0 * x])):   # 2D, 1D, batched 3D
        ref = EnginePlan(backend="pallas_interpret", bits=8).apply(
            lin, xx, out_dtype=jnp.float32)
        sh = EnginePlan(backend="sharded", bits=8, mesh=mesh,
                        inner_backend="pallas_interpret").apply(
            lin, xx, out_dtype=jnp.float32)
        assert sh.shape == ref.shape
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(sh))
    print("pallas inner + rank sweep OK")
    """)


def test_sharded_backend_compressed_psum():
    """psum_bits=8 row-parallel reduction: within the compressed-psum
    bound (n_dev * scale/2 per element) of the exact reduction."""
    _run_sub("""
    from repro.dist import make_mesh
    from repro.engine import EnginePlan, pack_linear

    mesh = make_mesh((1, 8), ("data", "model"))
    w, x = grid_data(4, 128, 20, 8, seed=9)
    lin = pack_linear(w, 8)
    exact = EnginePlan(backend="sharded", bits=8, mesh=mesh,
                       inner_backend="reference").apply(
        lin, x, out_dtype=jnp.float32)
    comp = EnginePlan(backend="sharded", bits=8, mesh=mesh,
                      inner_backend="reference", psum_bits=8).apply(
        lin, x, out_dtype=jnp.float32)
    # the compressed wire scale is absmax over the *partials* (pmax'd) /
    # qmax; reconstruct the partials exactly from the dequantized weight
    wq, xs = np.asarray(lin.dequantize(), np.float64), np.asarray(x)
    parts = [xs[:, i * 16:(i + 1) * 16] @ wq[i * 16:(i + 1) * 16]
             for i in range(8)]
    absmax = max(np.abs(p).max() for p in parts)
    bound = 8.0 * (absmax / 127.0) / 2.0   # n_dev roundings of scale/2
    err = float(jnp.max(jnp.abs(exact - comp)))
    assert err <= bound * 1.0001, (err, bound)
    print("compressed psum err", err, "<= bound", bound)
    """)


# ---------------------------------------------------------------------------
# mesh-native paged serving
# ---------------------------------------------------------------------------


def test_paged_serving_on_mesh_token_identical():
    """Paged greedy decode on a (data=4, model=2) mesh — lanes and pages
    over data, KV heads over model — is token-identical to the unsharded
    paged engine, including under preemption (page pool too small for all
    residents)."""
    _run_sub("""
    from conftest import reduced_f32
    from repro.config.base import EngineConfig, ServeConfig
    from repro.dist import make_mesh
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]

    def gen(mesh=None, n_pages=None, max_new=6):
        scfg = ServeConfig(max_new_tokens=max_new, engine=EngineConfig())
        eng = ServeEngine(cfg, params, scfg, n_slots=4, max_len=32,
                          mode="paged", page_size=4, n_pages=n_pages,
                          prefill_chunk=3, mesh=mesh)
        for p in PROMPTS:
            eng.submit(p)
        return eng, sorted(eng.run(), key=lambda r: r.rid)

    mesh = make_mesh((4, 2), ("data", "model"))
    _, ref = gen()
    eng, shard = gen(mesh=mesh)
    # the pool really is sharded: pages over data, heads over model
    kspec = eng.pages.k.sharding.spec
    assert "model" in str(kspec) and "data" in str(kspec), kspec
    for a, b in zip(ref, shard):
        assert a.output == b.output, (a.rid, a.output, b.output)
    print("mesh == unsharded:", [r.output for r in shard])

    # preemption: 12 pages (divisible by data=4) cannot hold 4 residents
    _, ref_p = gen(n_pages=12, max_new=16)
    e2, shard_p = gen(mesh=mesh, n_pages=12, max_new=16)
    assert e2.preemptions > 0
    for a, b in zip(ref_p, shard_p):
        assert a.output == b.output, (a.rid, a.output, b.output)
    print("preemption token-identity OK:", e2.preemptions, "preemptions")
    """)


def test_fused_attn_on_mesh_token_identical():
    """The fused paged-attention kernel shard_mapped over the (4, 2) mesh
    (KV heads over model, lanes over data) is token-identical to the
    gather backend on the same mesh — decode and in-kernel chunked
    prefill, kv_bits 0/8, under preemption, and through prefix-cache
    hits whose suffix-only prefill starts mid-page."""
    _run_sub("""
    from conftest import reduced_f32
    from repro.config.base import EngineConfig, ServeConfig
    from repro.dist import make_mesh
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]
    mesh = make_mesh((4, 2), ("data", "model"))

    def gen(abk, prompts=PROMPTS, kv_bits=0, n_slots=4, n_pages=None,
            max_new=6, prefix_cache=False):
        engine = (EngineConfig(kv_bits=kv_bits, backend="reference")
                  if kv_bits else EngineConfig())
        scfg = ServeConfig(max_new_tokens=max_new, engine=engine)
        eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=32,
                          mode="paged", page_size=4, n_pages=n_pages,
                          prefill_chunk=3, mesh=mesh, attn_backend=abk,
                          prefix_cache=prefix_cache)
        for p in prompts:
            eng.submit(list(p))
        return eng, [r.output for r in sorted(eng.run(),
                                              key=lambda r: r.rid)]

    for kv_bits in (0, 8):
        eng, ref = gen("gather", kv_bits=kv_bits)
        _, fused = gen("pallas_interpret", kv_bits=kv_bits)
        kspec = eng.pages.k.sharding.spec
        assert "model" in str(kspec), kspec  # pool really head-sharded
        assert ref == fused, (kv_bits, ref, fused)
        print("fused==gather on mesh, kv_bits", kv_bits)

    # preemption: 12 pages cannot hold 4 residents at max_new=16
    e_ref, ref_p = gen("gather", n_pages=12, max_new=16)
    e_fus, fused_p = gen("pallas_interpret", n_pages=12, max_new=16)
    assert e_ref.preemptions > 0 and e_fus.preemptions > 0
    assert ref_p == fused_p
    print("preemption OK:", e_fus.preemptions, "preemptions")

    # prefix-cache: serialized admission so the repeats hit; the matches
    # end mid-page, so fused suffix-only prefill starts at a non-aligned
    # pos0 inside the shard_mapped grid
    a = list(range(1, 13))
    pc_prompts = [a, list(range(1, 11)) + [99, 100], list(a)]
    e_ref, ref_c = gen("gather", prompts=pc_prompts, n_slots=1,
                       prefix_cache=True)
    e_fus, fused_c = gen("pallas_interpret", prompts=pc_prompts,
                         n_slots=1, prefix_cache=True)
    assert e_fus.prefix_stats()["hits"] >= 2, e_fus.prefix_stats()
    assert e_ref.prefix_stats() == e_fus.prefix_stats()
    assert ref_c == fused_c
    print("prefix-cache on mesh OK:", e_fus.prefix_stats()["hits"], "hits")
    """)


def test_paged_serving_sharded_weights_on_mesh():
    """Full mesh-native stack: int8 bit-planed weights through the
    ``sharded`` backend + the sharded page pool, vs the same quantized
    engine on one device.  Greedy tokens match (integer-exact weight
    GEMV partials keep the stream stable on this seed)."""
    _run_sub("""
    from conftest import reduced_f32
    from repro.config.base import EngineConfig, ServeConfig
    from repro.dist import make_mesh
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]

    def gen(mesh=None, engine=None):
        scfg = ServeConfig(max_new_tokens=6, engine=engine)
        eng = ServeEngine(cfg, params, scfg, n_slots=4, max_len=32,
                          mode="paged", page_size=4, prefill_chunk=3,
                          mesh=mesh)
        for p in PROMPTS:
            eng.submit(p)
        return eng, sorted(eng.run(), key=lambda r: r.rid)

    mesh = make_mesh((4, 2), ("data", "model"))
    _, ref = gen(engine=EngineConfig(weight_bits=8, backend="reference"))
    e, shard = gen(mesh=mesh, engine=EngineConfig(
        weight_bits=8, backend="reference", sharded=True))
    assert e.plan.backend == "sharded" and e.plan.mesh is mesh
    for a, b in zip(ref, shard):
        assert a.output == b.output, (a.rid, a.output, b.output)
    print("sharded-weights serving token-identical")
    """)
