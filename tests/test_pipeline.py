"""Pipeline parallelism (GPipe over the pod axis): forward/gradient
exactness vs the unpipelined stack, and collective-permute lowering.
Runs on 8 forced host devices in a subprocess."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.dist.pipeline import microbatch, stack_stages


def test_microbatch_roundtrip():
    x = jnp.arange(24).reshape(8, 3)
    m = microbatch(x, 4)
    assert m.shape == (4, 2, 3)
    np.testing.assert_array_equal(np.asarray(m.reshape(8, 3)), np.asarray(x))


def test_stack_stages_shapes():
    import jax

    tree = {"w": jnp.zeros((8, 4, 4)), "b": jnp.zeros((8, 4))}
    staged = stack_stages(tree, 2)
    assert staged["w"].shape == (2, 4, 4, 4)
    assert staged["b"].shape == (2, 4, 4)
    del jax


def test_pipeline_matches_sequential_multi_device():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.dist import make_mesh, use_mesh
        from repro.dist.pipeline import pipeline_apply, microbatch, stack_stages
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        L, D, B, M, S = 8, 16, 8, 4, 2
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (L, D, D)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))

        def stage_fn(local_w, h):
            h, _ = jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), h, local_w)
            return h

        ref = stage_fn(Ws, x)
        micros = microbatch(x, M)
        staged = stack_stages(Ws, S)
        with use_mesh(mesh):
            staged_s = jax.device_put(staged, NamedSharding(mesh, P("pod")))
            out = jax.jit(lambda w, m: pipeline_apply(
                w, m, stage_fn, n_stages=S))(staged_s, micros)
            g1 = jax.jit(jax.grad(lambda w: jnp.sum(pipeline_apply(
                w, micros, stage_fn, n_stages=S) ** 2)))(staged_s)
            txt = jax.jit(lambda w, m: pipeline_apply(
                w, m, stage_fn, n_stages=S)).lower(
                staged_s, micros).compile().as_text()
        err = float(jnp.max(jnp.abs(out.reshape(B, D) - ref)))
        assert err < 1e-5, err
        g2 = jax.grad(lambda w: jnp.sum(stage_fn(w, x) ** 2))(Ws)
        gerr = float(jnp.max(jnp.abs(
            jax.device_get(g1).reshape(L, D, D) - g2)))
        assert gerr < 1e-4, gerr
        assert "collective-permute" in txt
        print("PIPELINE_TEST_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_TEST_OK" in out.stdout
