"""SLA-aware budget scheduler (``repro.serve.scheduler.BudgetScheduler``).

The three scheduling claims this file pins:

* the per-step token budget (prefill + decode) is a hard invariant —
  chunked-prefill interleaving never exceeds ``step_tokens``;
* decode lanes advance **every** step while a long prompt prefills (the
  budget funds decode first, prefill gets the remainder);
* weighted fair share bounds priority inversion — a ``batch``-class
  request completes within a bounded number of steps no matter how much
  ``interactive`` traffic keeps arriving.

Plus token identity: the budget scheduler reorders *work*, never tokens
(greedy output matches FCFS exactly), and host-side WFQ unit tests.
"""

import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.models import init_params
from repro.serve import (
    BudgetScheduler,
    PageAllocator,
    Request,
    ServeEngine,
)

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


def _engine(cfg, params, *, sched="budget", step_tokens=0, n_slots=2,
            max_len=96, max_new=5, prefill_chunk=4, **kw):
    scfg = ServeConfig(max_new_tokens=max_new, sched=sched,
                       step_tokens=step_tokens,
                       engine=EngineConfig(backend="reference"), **kw)
    return ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                       mode="paged", page_size=4,
                       prefill_chunk=prefill_chunk)


# -------------------------------------------------------------- identity
def test_budget_output_identical_to_fcfs(rng):
    """Scheduling policy changes latency, never tokens: greedy output
    under the budget scheduler (with priorities mixed in) matches FCFS
    per request."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)

    def gen(sched):
        eng = _engine(cfg, params, sched=sched, step_tokens=6, max_new=6)
        prios = ["batch", "interactive", "default", "interactive"]
        for p, pr in zip(PROMPTS, prios):
            eng.submit(list(p), priority=pr if sched == "budget"
                       else "default")
        return sorted(eng.run(), key=lambda r: r.rid)

    fcfs, budget = gen("fcfs"), gen("budget")
    assert len(fcfs) == len(budget) == len(PROMPTS)
    for a, b in zip(fcfs, budget):
        assert a.output == b.output, (a.rid, a.output, b.output)
        assert b.done and b.finish_reason == "length"


# ---------------------------------------------------------------- budget
def test_per_step_token_budget_never_exceeded(rng):
    """Hard invariant: prefill tokens + decode tokens per engine step
    never exceed ``step_tokens``, across admission waves, long prompts
    and lanes completing prefill mid-step (the +1 completion reserve)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    step_tokens = 7
    eng = _engine(cfg, params, step_tokens=step_tokens, n_slots=3,
                  max_new=4, prefill_chunk=5)
    reqs = [eng.submit(list(range(1, 1 + n)), max_new_tokens=4)
            for n in (29, 3, 17, 1, 40, 6)]
    prev_prefill, prev_out = 0, 0
    steps = 0
    while eng.has_work():
        eng.step()
        steps += 1
        assert steps < 500, "scheduler stopped making progress"
        prefill_now = eng.prefill_computed
        out_now = sum(len(r.output) for r in reqs)
        spent = (prefill_now - prev_prefill) + (out_now - prev_out)
        assert spent <= step_tokens, \
            f"step {steps} spent {spent} > budget {step_tokens}"
        prev_prefill, prev_out = prefill_now, out_now
    assert all(r.done for r in reqs)


def test_small_budget_still_makes_progress(rng):
    """step_tokens=2 (the legal minimum) drains a prompt one token per
    step without deferring the tail forever."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, step_tokens=2, n_slots=1, max_new=2)
    req = eng.submit(list(range(1, 10)), max_new_tokens=2)
    out = eng.run()
    assert req.done and len(req.output) == 2
    assert len(out) == 1


def test_step_tokens_validation():
    with pytest.raises(ValueError, match="step_tokens"):
        BudgetScheduler(PageAllocator(9, 4, 1, 16), chunk=4, step_tokens=1)
    with pytest.raises(ValueError, match="step_tokens"):
        ServeConfig(step_tokens=-1)
    with pytest.raises(ValueError, match="sched"):
        ServeConfig(sched="wfq")


# ------------------------------------------------- decode never stalls
def test_decode_advances_every_step_during_long_prefill(rng):
    """A lane decoding while a 60-token prompt prefills advances by one
    token on *every* step until it finishes — chunked prefill is sliced
    into the budget's remainder and can never stall active decode."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, step_tokens=6, n_slots=2, max_new=12,
                  prefill_chunk=8)
    short = eng.submit([1, 2, 3], max_new_tokens=12)
    # let the short request reach decode
    while short.last_logits is None:
        eng.step()
    long = eng.submit(list(range(100, 160)), max_new_tokens=2)
    overlap_steps = 0
    while long.prefill_pos < len(long.prefill_tokens) and not short.done:
        before = len(short.output)
        eng.step()
        assert len(short.output) == before + 1, \
            "decode lane stalled while the long prompt prefilled"
        overlap_steps += 1
    # the budget (6/step minus 1 decode) genuinely sliced the 60-token
    # prompt across many steps — the claim above wasn't vacuous
    assert overlap_steps >= 8, overlap_steps
    eng.run()
    assert short.done and long.done


# ----------------------------------------------------- fair share bound
def test_low_priority_not_starved_by_interactive_flood(rng):
    """Priority inversion bound: with a sustained interactive flood (a
    fresh arrival whenever the queue drains), a batch-class request
    still completes within a bounded number of steps — WFQ serves an
    active weight-1 key 1/(1+8) of the time, it never zeroes it."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, step_tokens=6, n_slots=2, max_new=3,
                  prefill_chunk=4)
    batch_req = eng.submit(list(range(1, 9)), max_new_tokens=3,
                           priority="batch", tenant="t-batch")
    flood_done = 0
    steps = 0
    while not batch_req.done:
        # keep interactive pressure up: never let the queue go empty
        while len(eng.sched.queue) < 2:
            eng.submit([100 + steps % 50, 101, 102], max_new_tokens=3,
                       priority="interactive", tenant="t-inter")
        done = eng.step()
        flood_done += len(done)
        steps += 1
        assert steps < 400, \
            f"batch request starved ({flood_done} interactive done)"
    assert batch_req.done and len(batch_req.output) == 3
    # the flood really was alive the whole time (queue never drained) and
    # interactive requests keep completing once the batch lane is done
    assert flood_done >= 1, flood_done
    for _ in range(30):
        if not eng.has_work():
            break
        flood_done += len(eng.step())
    assert flood_done >= 5, flood_done


# ------------------------------------------------------- WFQ unit tests
class _FakeReq:
    def __init__(self, rid, priority="default", tenant="default"):
        self.rid = rid
        self.priority = priority
        self.tenant = tenant
        self.prefill_tokens = [1]
        self.prefill_pos = 0
        self.output = []
        self.max_new_tokens = 1


def _sched(**kw):
    return BudgetScheduler(PageAllocator(33, 4, 2, 32), chunk=4,
                           step_tokens=kw.pop("step_tokens", 8), **kw)


def test_wfq_charge_and_order():
    s = _sched()
    inter = _FakeReq(0, "interactive")
    batch = _FakeReq(1, "batch")
    # equal service advances the batch key 8x faster in virtual time
    s._charge(inter, 8)
    s._charge(batch, 8)
    assert s._vtime[("default", "interactive")] == pytest.approx(1.0)
    assert s._vtime[("default", "batch")] == pytest.approx(8.0)
    assert [r.rid for r in s._service_order([batch, inter])] == [0, 1]
    # fresh keys (unseen tenant) start at the active floor, heavier
    # class wins the tie
    fresh_i = _FakeReq(2, "interactive", "t2")
    fresh_b = _FakeReq(3, "batch", "t2")
    order = s._service_order([fresh_b, fresh_i])
    assert [r.rid for r in order][:2] == [2, 3]


def test_wfq_idle_key_gets_no_banked_credit():
    """A key that sleeps while others are served re-enters at the floor,
    not at its stale (tiny) virtual time — sleeping earns nothing."""
    s = _sched()
    a, b = _FakeReq(0, "default", "a"), _FakeReq(1, "default", "b")
    s._charge(a, 1)          # a barely served, then goes idle
    for _ in range(100):
        s._charge(b, 4)      # b consumes heavily meanwhile
    # keep b active so the floor tracks its virtual time
    s.queue.append(b)
    s._charge(a, 4)          # a returns
    va, vb = s._vtime[("a", "default")], s._vtime[("b", "default")]
    assert va >= vb, (va, vb)  # floor-bumped: no century of banked credit


def test_budget_admission_skips_blocked_head():
    """A queued request that cannot fit does not head-of-line block the
    budget scheduler: later requests that fit are admitted around it
    (FCFS, by contrast, preserves arrival order strictly)."""
    # pool: 8 usable pages, page_size 4 -> a 24-token prompt (7 pages
    # incl. decode token) fits alone but not beside a resident request
    alloc = PageAllocator(9, 4, 2, 32)
    s = BudgetScheduler(alloc, chunk=4, step_tokens=8)
    big = _FakeReq(0)
    big.prefill_tokens = list(range(24))
    small = _FakeReq(1)
    small.prefill_tokens = [1, 2, 3]
    resident = _FakeReq(2)
    resident.prefill_tokens = list(range(8))
    s.queue.extend([big, small])
    # occupy capacity so big (7 pages) cannot fit: resident takes 3 pages
    assert s._try_admit(0, resident)
    s.admit()
    assert s.slot_req[1] is small, "small should be admitted around big"
    assert big in list(s.queue)
