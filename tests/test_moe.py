"""MoE routing invariants: mass conservation, capacity drops, aux loss,
dispatch/combine correctness against a dense loop reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import swiglu
from repro.models.moe import _capacity, init_moe, moe_block

from conftest import reduced_f32


def _setup(arch="qwen3-moe-235b-a22b", t=32, capacity_factor=8.0, seed=0):
    cfg = reduced_f32(arch, capacity_factor=capacity_factor)
    key = jax.random.PRNGKey(seed)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (t, cfg.d_model))
    return cfg, params, x


def _dense_reference(params, x, cfg):
    """Route every token to its true top-k experts with no capacity limit."""
    logits = x @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for t in range(x.shape[0]):
        for j in range(cfg.top_k):
            e = int(top_i[t, j])
            h = x[t] @ params["w_gate"][e]
            u = x[t] @ params["w_up"][e]
            o = (jax.nn.silu(h) * u) @ params["w_down"][e]
            y = y.at[t].add(top_p[t, j] * o)
    if "shared" in params:
        y = y + swiglu(params["shared"], x)
    return y


def test_moe_matches_dense_reference():
    cfg, params, x = _setup(t=16)
    y, aux = moe_block(params, x, cfg)
    y_ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_shared_expert_llama4():
    cfg, params, x = _setup(arch="llama4-scout-17b-a16e", t=16)
    assert "shared" in params
    y, _ = moe_block(params, x, cfg)
    y_ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """With tiny capacity some tokens get no expert output (dropped)."""
    cfg, params, x = _setup(t=64, capacity_factor=0.05)
    y, _ = moe_block(params, x, cfg)
    y_ref = _dense_reference(params, x, cfg)
    # some rows dropped (zero or partial), but nothing is NaN and capacity
    # is respected: at most C tokens per expert contributed
    assert np.all(np.isfinite(np.asarray(y)))
    assert not np.allclose(np.asarray(y), np.asarray(y_ref))


def test_capacity_formula():
    cfg = reduced_f32("qwen3-moe-235b-a22b", capacity_factor=1.25)
    c = _capacity(1024, cfg)
    expect = int(-(-1024 * cfg.top_k * 1.25 // cfg.n_experts))
    assert c >= expect and c % 8 == 0


def test_router_gates_normalized():
    """Per-token combined gate weights sum to ~1 for surviving tokens."""
    cfg, params, x = _setup(t=8)
    logits = x @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, _ = jax.lax.top_k(probs, cfg.top_k)
    norm = top_p / top_p.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(norm.sum(-1)), 1.0, rtol=1e-5)


def test_aux_loss_balanced_vs_skewed():
    """Aux loss is minimal for uniform routing, larger for skewed."""
    cfg, params, x = _setup(t=256)
    _, aux_random = moe_block(params, x, cfg)
    # force skew: make router always pick expert 0
    skew = dataclasses.replace(cfg)
    p2 = dict(params)
    p2["router"] = {"w": params["router"]["w"].at[:, 0].set(100.0)}
    _, aux_skew = moe_block(p2, x, skew)
    assert float(aux_skew) > float(aux_random)
