"""The trip-count-aware HLO cost analyzer: exactness on known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    model_bytes_for_cell,
    model_flops_for_cell,
    roofline_report,
)
from repro.roofline.hlo_cost import analyze_hlo_text
from repro.config import SHAPES, get_arch


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


class TestAnalyzer:
    def test_single_dot_exact(self):
        c = _compile(lambda a, b: a @ b,
                     jnp.ones((128, 64)), jnp.ones((64, 32)))
        r = analyze_hlo_text(c.as_text())
        assert r["flops"] == pytest.approx(2 * 128 * 64 * 32, rel=0.01)

    @pytest.mark.parametrize("length", [2, 5, 13])
    def test_scan_trip_count(self, length):
        x = jnp.ones((64, 64))
        c = _compile(
            lambda x: jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                   length=length)[0], x)
        r = analyze_hlo_text(c.as_text())
        assert r["flops"] == pytest.approx(length * 2 * 64**3, rel=0.01)

    def test_nested_scan(self):
        x = jnp.ones((32, 32))

        def nested(x):
            def outer(c, _):
                d, _ = jax.lax.scan(lambda d, _: (d @ d, None), c, None,
                                    length=3)
                return d, None
            return jax.lax.scan(outer, x, None, length=5)[0]

        r = analyze_hlo_text(_compile(nested, x).as_text())
        assert r["flops"] == pytest.approx(15 * 2 * 32**3, rel=0.01)

    def test_xla_cost_analysis_undercounts_scans(self):
        """Documents WHY the custom analyzer exists."""
        x = jnp.ones((64, 64))
        c = _compile(
            lambda x: jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                   length=10)[0], x)
        cost = c.cost_analysis()
        if not isinstance(cost, dict):  # older jax returns [dict]
            cost = cost[0]
        xla = cost["flops"]
        ours = analyze_hlo_text(c.as_text())["flops"]
        assert ours > 5 * xla  # XLA counts the body once

    def test_bytes_scale_with_trips(self):
        x = jnp.ones((64, 64))
        rs = []
        for length in (2, 8):
            c = _compile(
                lambda x, n=length: jax.lax.scan(
                    lambda c, _: (c @ c + 1.0, None), x, None, length=n)[0], x)
            rs.append(analyze_hlo_text(c.as_text())["bytes"])
        assert rs[1] > 2.5 * rs[0]

    def test_region_attribution(self):
        """Instructions carry op_name metadata; attention dots must be
        attributed to the 'attention' region."""
        from repro.models.attention import attend_flash
        q = jnp.ones((1, 128, 4, 16))
        k = jnp.ones((1, 128, 2, 16))
        pos = jnp.broadcast_to(jnp.arange(128), (1, 128))
        c = _compile(lambda q, k, v: attend_flash(q, k, v, pos, 0,
                                                  block_q=64, block_kv=64),
                     q, k, k)
        r = analyze_hlo_text(c.as_text())
        assert "attention" in r["regions"]
        assert r["regions"]["attention"]["flops"] > 0
        # most of the program's flops are attention here
        assert r["regions"]["attention"]["flops"] > 0.5 * r["flops"]


class TestCollectiveParse:
    def test_parses_families(self):
        text = """
HloModule m
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%a), replica_groups={}
  %ar = f32[16,16]{1,0} all-reduce(%a), to_apply=%add
  %rs = f32[4,16]{1,0} reduce-scatter(%a), to_apply=%add
  %aa = f32[16,16]{1,0} all-to-all(%a)
  ROOT %cp = f32[16,16]{1,0} collective-permute(%a)
}
"""
        r = collective_bytes_from_hlo(text)
        assert r["all-gather"] == 64 * 16 * 4
        assert r["all-reduce"] == 16 * 16 * 4
        assert r["reduce-scatter"] == 4 * 16 * 4
        assert r["all-to-all"] == 16 * 16 * 4
        assert r["collective-permute"] == 16 * 16 * 4
        assert r["total"] == sum(
            r[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))


class TestRooflineReport:
    def test_report_fields(self):
        c = _compile(lambda a, b: a @ b,
                     jnp.ones((256, 256)), jnp.ones((256, 256)))
        rep = roofline_report(c, 1, model_flops=2 * 256**3,
                              model_bytes=3 * 256 * 256 * 4)
        for key in ("compute_s", "memory_s", "collective_s", "dominant",
                    "roofline_fraction", "useful_flops_ratio"):
            assert key in rep
        assert rep["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert 0 < rep["roofline_fraction"] <= 1.5

    def test_model_flops_conventions(self):
        cfg = get_arch("mistral-large-123b")
        n = cfg.param_count()
        assert model_flops_for_cell(cfg, SHAPES["train_4k"]) == pytest.approx(
            6 * n * 256 * 4096)
        assert model_flops_for_cell(cfg, SHAPES["decode_32k"]) == pytest.approx(
            2 * n * 128)
        moe = get_arch("qwen3-moe-235b-a22b")
        assert (model_flops_for_cell(moe, SHAPES["decode_32k"])
                < 2 * moe.param_count() * 128 * 0.5)  # active << total

    def test_model_bytes_engine_scaling(self):
        cfg = get_arch("gemma3-27b")
        b16 = model_bytes_for_cell(cfg, SHAPES["decode_32k"], 0)
        b8 = model_bytes_for_cell(cfg, SHAPES["decode_32k"], 8)
        b4 = model_bytes_for_cell(cfg, SHAPES["decode_32k"], 4)
        assert b8 == pytest.approx(b16 / 2, rel=0.01)
        assert b4 == pytest.approx(b16 / 4, rel=0.01)
