"""Crash-consistent ``ServeEngine.snapshot()/restore()``.

The recovery drill: run an engine to step *k*, snapshot, keep the
original running to completion, then restore the snapshot into a
*fresh* engine and drain it — greedy outputs must be token-identical,
in-memory and through the ``repro.ckpt`` disk format, across kv_bits
0/8, with prefix-cache state (radix tree, pins, LRU) and the budget
scheduler's virtual-time lanes intact, and on a (4, 2) device mesh
(the pool re-places under the restoring engine's shardings).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.models import init_params
from repro.serve import ServeEngine

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6, 1, 2, 3, 4, 5], [1, 2, 3, 4, 9]]


@pytest.fixture(scope="module")
def model():
    import jax

    cfg = reduced_f32("qwen2.5-3b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, *, kv_bits=0, prefix_cache=False, sched="fcfs",
            max_new=6, n_pages=0):
    scfg = ServeConfig(max_new_tokens=max_new, sched=sched,
                       n_pages=n_pages,
                       engine=EngineConfig(kv_bits=kv_bits,
                                           backend="reference"))
    return ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                       mode="paged", page_size=4, prefill_chunk=3,
                       prefix_cache=prefix_cache)


def _submit_all(eng):
    for i, p in enumerate(PROMPTS):
        eng.submit(list(p), priority="interactive" if i % 2 else "batch",
                   tenant=f"t{i % 2}")


def _drain(eng):
    return {r.rid: list(r.output) for r in eng.run()}


# ------------------------------------------------------------- identity
@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_kill_at_step_k_restore_identical(model, kv_bits, prefix_cache):
    cfg, params = model
    kw = dict(kv_bits=kv_bits, prefix_cache=prefix_cache)

    engA = _engine(cfg, params, **kw)
    _submit_all(engA)
    for _ in range(3):  # mid-prefill / early-decode crash point
        engA.step()
    snap = engA.snapshot()
    ref = _drain(engA)  # the uninterrupted run

    engB = _engine(cfg, params, **kw)  # fresh process stand-in
    engB.restore(snap)
    engB.audit()
    assert _drain(engB) == ref


def test_budget_scheduler_vtime_restored(model):
    """Fair-share virtual time is engine state: dropping it would
    re-order admissions after restore."""
    cfg, params = model
    engA = _engine(cfg, params, sched="budget", prefix_cache=True)
    _submit_all(engA)
    for _ in range(2):
        engA.step()
    snap = engA.snapshot()
    ref = _drain(engA)

    engB = _engine(cfg, params, sched="budget", prefix_cache=True)
    engB.restore(snap)
    assert engB.sched._vtime == engA.sched._vtime or engB.sched._vtime
    assert _drain(engB) == ref


def test_snapshot_excludes_terminal_requests(model):
    cfg, params = model
    eng = _engine(cfg, params, max_new=2)
    done_req = eng.submit([7, 8])
    eng.run()
    assert done_req.done
    _submit_all(eng)
    eng.step()
    snap = eng.snapshot()
    rids = {r["rid"] for r in snap["host"]["requests"]}
    assert done_req.rid not in rids
    assert len(rids) == len(PROMPTS)


def test_every_pending_state_is_captured(model):
    """Snapshot taken while requests are simultaneously queued,
    mid-chunked-prefill and decoding — each resumes from its exact
    position (prefill_pos, pos, partially generated output)."""
    cfg, params = model
    eng = _engine(cfg, params, prefix_cache=True)
    _submit_all(eng)
    eng.step()  # 2 lanes admitted, 2 queued, prefill chunk 1 done
    snap = eng.snapshot()
    states = {r["rid"]: r for r in snap["host"]["requests"]}
    assert any(r["prefill_pos"] > 0 for r in states.values())
    assert snap["host"]["sched"]["queue"]  # someone still waiting
    ref = _drain(eng)
    engB = _engine(cfg, params, prefix_cache=True)
    engB.restore(snap)
    assert _drain(engB) == ref


# ------------------------------------------------------------------ disk
def test_disk_roundtrip_and_latest(model, tmp_path):
    cfg, params = model
    engA = _engine(cfg, params, prefix_cache=True)
    _submit_all(engA)
    engA.step()
    engA.save_snapshot(str(tmp_path), 1)
    for _ in range(2):
        engA.step()
    engA.save_snapshot(str(tmp_path), 3)
    ref = _drain(engA)

    engB = _engine(cfg, params, prefix_cache=True)
    assert engB.load_snapshot(str(tmp_path)) == 3  # latest committed
    engB.audit()
    assert _drain(engB) == ref

    engC = _engine(cfg, params, prefix_cache=True)
    assert engC.load_snapshot(str(tmp_path), step=1) == 1
    assert _drain(engC) == ref


def test_geometry_mismatch_rejected(model, tmp_path):
    cfg, params = model
    engA = _engine(cfg, params)
    _submit_all(engA)
    engA.step()
    snap = engA.snapshot()

    engB = _engine(cfg, params, kv_bits=8)
    with pytest.raises(ValueError, match="geometry"):
        engB.restore(snap)
    engC = _engine(cfg, params, n_pages=64)
    with pytest.raises(ValueError, match="geometry"):
        engC.restore(snap)

    engA.save_snapshot(str(tmp_path), 0)
    # a non-snapshot checkpoint directory is refused up front
    from repro.ckpt import save_checkpoint

    other = tmp_path / "train"
    save_checkpoint(str(other), 5, {"w": np.zeros((2, 2), np.float32)})
    engD = _engine(cfg, params)
    with pytest.raises(ValueError, match="snapshot"):
        engD.load_snapshot(str(other))


def test_snapshot_is_a_copy_not_a_view(model):
    """Stepping the engine after snapshot() must not mutate the taken
    snapshot (donated buffers!) — the drill depends on it."""
    cfg, params = model
    eng = _engine(cfg, params)
    _submit_all(eng)
    eng.step()
    snap = eng.snapshot()
    k_before = snap["arrays"]["pages/k"].copy()
    eng.run()
    np.testing.assert_array_equal(snap["arrays"]["pages/k"], k_before)


# ------------------------------------------------------------------ mesh
def test_recovery_drill_on_mesh():
    """(4, 2) forced-host mesh: snapshot on-mesh, restore into a fresh
    on-mesh engine (pool re-placed under its shardings) — greedy
    outputs token-identical to the uninterrupted sharded run."""
    pre = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src"); sys.path.insert(0, "tests")
        import jax
        from conftest import reduced_f32
        from repro.config.base import EngineConfig, ServeConfig
        from repro.dist import make_mesh
        from repro.models import init_params
        from repro.serve import ServeEngine

        cfg = reduced_f32("qwen2.5-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = [[1, 2, 3], [4], [5, 6, 1, 2, 3, 4, 5], [1, 2, 3, 4, 9]]
        mesh = make_mesh((4, 2), ("data", "model"))

        def engine(kv_bits):
            scfg = ServeConfig(max_new_tokens=6, engine=EngineConfig(
                kv_bits=kv_bits, backend="reference"))
            return ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                               mode="paged", page_size=4, prefill_chunk=3,
                               prefix_cache=True, mesh=mesh)

        for kv in (0, 8):
            a = engine(kv)
            for p in prompts:
                a.submit(list(p))
            for _ in range(3):
                a.step()
            snap = a.snapshot()
            ref = {r.rid: r.output for r in a.run()}

            b = engine(kv)
            b.restore(snap)
            b.audit()
            kspec = b.pages.k.sharding.spec
            assert "data" in str(kspec) and "model" in str(kspec), kspec
            got = {r.rid: r.output for r in b.run()}
            assert got == ref, (kv, got, ref)
            print("kv", kv, "mesh recovery drill identical")
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", pre], capture_output=True,
                         text=True, cwd=repo, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
