"""Shared test fixtures.

NOTE: no XLA device-count flags here — smoke tests and benches must see the
real single CPU device; only launch/dryrun.py forces 512 host devices.
"""

import dataclasses

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_f32(arch: str, **overrides):
    """Reduced same-family config in float32 for CPU numerics."""
    from repro.config import get_reduced

    return dataclasses.replace(get_reduced(arch), dtype="float32", **overrides)


ALL_ARCHS = [
    "gemma3-27b",
    "mistral-large-123b",
    "starcoder2-15b",
    "qwen2.5-3b",
    "llava-next-mistral-7b",
    "mamba2-130m",
    "zamba2-7b",
    "musicgen-medium",
    "llama4-scout-17b-a16e",
    "qwen3-moe-235b-a22b",
]


def make_batch(cfg, rng, batch=2, seq=16):
    """Family-appropriate batch dict (tokens/labels [+ modality stubs])."""
    import jax

    ks = jax.random.split(rng, 3)
    if cfg.family == "audio":
        toks = jax.random.randint(
            ks[0], (batch, seq + 1, cfg.n_codebooks), 0, cfg.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    else:
        toks = jax.random.randint(ks[0], (batch, seq + 1), 0, cfg.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        out["img_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.img_tokens, cfg.d_model))
    return out
