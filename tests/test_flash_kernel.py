"""Flash-attention Pallas kernel vs oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.attention import attend_dense


def _qkv(b, s, hq, hkv, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_kernel_vs_dense(window, bq, bk):
    q, k, v = _qkv(2, 256, 8, 4, 32)
    out = flash_attention(q, k, v, window=window, block_q=bq, block_kv=bk,
                          interpret=True)
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    ref = attend_dense(q, k, v, pos, pos, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_kernel_padding_path():
    q, k, v = _qkv(1, 200, 4, 4, 16, seed=3)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    assert out.shape == q.shape
    pos = jnp.broadcast_to(jnp.arange(200), (1, 200))
    ref = attend_dense(q, k, v, pos, pos, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(
    b=st.integers(1, 2),
    s_blocks=st.integers(1, 3),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 64]),
    window=st.sampled_from([0, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_flash_kernel_property(b, s_blocks, hkv, group, d, window, seed):
    s = 64 * s_blocks
    q, k, v = _qkv(b, s, hkv * group, hkv, d, seed=seed)
    out = flash_attention(q, k, v, window=window, block_q=64, block_kv=64,
                          interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
