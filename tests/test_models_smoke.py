"""Per-arch smoke tests (assignment requirement): a REDUCED same-family
config runs one forward and one train step on CPU — output shapes correct,
no NaNs.  The FULL configs are exercised only via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, available_archs, get_arch, get_reduced
from repro.config.base import TrainConfig
from repro.models import init_params, forward
from repro.optim import make_optimizer
from repro.train.trainer import make_train_step

from conftest import ALL_ARCHS, make_batch, reduced_f32


def test_registry_complete():
    assert sorted(available_archs()) == sorted(ALL_ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact published dims (spot-check key fields per the assignment)."""
    cfg = get_arch(arch)
    expected = {
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_arch_special_features():
    assert get_arch("gemma3-27b").sliding_window == 1024
    assert get_arch("gemma3-27b").global_every == 6      # 5:1 local:global
    assert get_arch("qwen2.5-3b").qkv_bias
    assert get_arch("mamba2-130m").ssm_state == 128
    assert get_arch("zamba2-7b").ssm_state == 64
    assert get_arch("musicgen-medium").n_codebooks == 4
    assert get_arch("llama4-scout-17b-a16e").n_experts == 16
    assert get_arch("llama4-scout-17b-a16e").top_k == 1
    assert get_arch("qwen3-moe-235b-a22b").n_experts == 128
    assert get_arch("qwen3-moe-235b-a22b").top_k == 8


def test_param_counts_plausible():
    """Total parameter counts should be in the right ballpark of the
    published sizes (our blocks differ in minor ways: +-25%)."""
    targets = {
        "mistral-large-123b": 123e9,
        "starcoder2-15b": 15e9,
        "gemma3-27b": 27e9,
        "mamba2-130m": 130e6,
        "zamba2-7b": 7e9,
        "qwen3-moe-235b-a22b": 235e9,
    }
    for arch, target in targets.items():
        n = get_arch(arch).param_count()
        assert 0.7 * target < n < 1.45 * target, (arch, n, target)
    # MoE active params
    qwen3 = get_arch("qwen3-moe-235b-a22b")
    active = qwen3.active_param_count()
    assert 0.6 * 22e9 < active < 1.6 * 22e9, active


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = reduced_f32(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng, batch=2, seq=16)

    logits, aux = forward(params, batch, cfg, remat="none")
    if cfg.family == "audio":
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))

    tcfg = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    step = make_train_step(cfg, tcfg, donate=False)
    init_fn, _ = make_optimizer(tcfg.optimizer)
    opt = init_fn(params)
    new_params, new_opt, _, metrics = step(params, opt, {}, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(changed)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


def test_shapes_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md table)."""
    runnable = {a for a in ALL_ARCHS if get_arch(a).is_subquadratic}
    assert runnable == {"gemma3-27b", "mamba2-130m", "zamba2-7b"}
