"""Property-style tests for the sharding layer's divisibility discipline:
``_divisible_prefix``, the degrade-to-replication rule of ``param_spec``,
and the hint filter — across awkward (prime, non-divisible, oversized)
mesh shapes.  The invariant under test: non-divisible dimensions must
*never* error, only degrade to replication, and any axis that is placed
must exactly divide its dimension."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.dist.hints import _filter_entry
from repro.dist.sharding import (
    _divisible_prefix,
    param_spec,
    pool_pages_for_mesh,
)


class FakeMesh:
    """Stand-in accepted by the spec functions: axis_names + name->size."""

    def __init__(self, **sizes):
        self.axis_names = tuple(sizes)
        self.shape = dict(sizes)


# ---------------------------------------------------------------------------
# _divisible_prefix
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(dim=st.integers(-4, 4096), pod=st.integers(1, 9),
       data=st.integers(1, 17))
def test_divisible_prefix_invariants(dim, pod, data):
    axes = ("pod", "data")
    sizes = {"pod": pod, "data": data}
    kept = _divisible_prefix(dim, axes, sizes)
    # a prefix, never a reordering or subset-with-gaps
    assert kept == axes[:len(kept)]
    if dim <= 0:
        assert kept == ()
        return
    # whatever was kept divides the dimension exactly
    prod = 1
    for a in kept:
        prod *= sizes[a]
    assert dim % prod == 0
    # and it is maximal: adding the next axis would break divisibility
    if len(kept) < len(axes):
        nxt = prod * sizes[axes[len(kept)]]
        assert dim % nxt != 0


@settings(max_examples=30)
@given(n=st.integers(1, 200), pod=st.integers(1, 7), data=st.integers(1, 7))
def test_pool_padding_minimal_and_divisible(n, pod, data):
    mesh = FakeMesh(pod=pod, data=data, model=3)
    padded = pool_pages_for_mesh(n, mesh)
    assert padded >= n
    assert padded % (pod * data) == 0
    assert padded - n < pod * data  # minimal padding


# ---------------------------------------------------------------------------
# param_spec degrade-to-replication
# ---------------------------------------------------------------------------

_OWNERS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "lm_head",
           "embed", "in_proj", "out_proj", "norm"]


@settings(max_examples=80)
@given(owner=st.sampled_from(_OWNERS),
       d_in=st.integers(1, 96), d_out=st.integers(1, 96),
       model=st.integers(1, 13), data=st.integers(1, 13),
       leafname=st.sampled_from(["w", "packed", "scale", "bias"]))
def test_param_spec_never_errors_and_divides(owner, d_in, d_out, model,
                                             data, leafname):
    mesh = FakeMesh(data=data, model=model)
    shape = (d_out,) if leafname == "bias" else (d_in, d_out)
    leaf = jax.ShapeDtypeStruct(shape, jnp.float32)
    path = (jax.tree_util.DictKey("layers"), jax.tree_util.DictKey(owner),
            jax.tree_util.DictKey(leafname))
    spec = param_spec(path, leaf, mesh)           # must never raise
    assert len(spec) <= leaf.ndim
    for ax, entry in enumerate(spec):
        if entry is None:
            continue
        assert entry == "model"
        assert leaf.shape[ax] % model == 0        # placed => divides


@settings(max_examples=40)
@given(model=st.integers(2, 12), e=st.integers(1, 24),
       d=st.integers(8, 64))
def test_param_spec_stacked_experts_degrade(model, e, d):
    """Stacked (L, E, D, F) expert weights: the expert axis is sharded
    over model iff divisible, otherwise fully replicated — never an
    error, never a half-sharded surprise on another axis."""
    mesh = FakeMesh(data=1, model=model)
    leaf = jax.ShapeDtypeStruct((2, e, d, d), jnp.float32)
    path = (jax.tree_util.DictKey("layers"), jax.tree_util.DictKey("moe"),
            jax.tree_util.DictKey("w_up"))
    spec = param_spec(path, leaf, mesh)
    if e % model == 0:
        assert spec[1] == "model"
    else:
        assert all(s is None for s in spec)


# ---------------------------------------------------------------------------
# hint filtering (with_hint's divisibility filter)
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(dim=st.integers(1, 256), pod=st.integers(1, 9),
       data=st.integers(1, 9), unknown=st.booleans())
def test_filter_entry_degrades(dim, pod, data, unknown):
    axes = {"pod": pod, "data": data}
    entry = ("pod", "nope", "data") if unknown else ("pod", "data")
    kept = _filter_entry(entry, dim, axes)
    if kept is None:
        return
    names = (kept,) if isinstance(kept, str) else tuple(kept)
    assert "nope" not in names
    prod = 1
    for n in names:
        prod *= axes[n]
    assert dim % prod == 0
