"""System invariant: sequential decode_step == full forward (teacher
forcing), prefill+decode == decode-from-scratch, split-local cache ==
uniform cache.  These jointly validate KV caches, RoPE offsets, sliding
windows, SSD chunking vs recurrence, MoE routing and the hybrid shared
block."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import decode_step, forward, init_cache, init_params
from repro.models.transformer import prefill

from conftest import reduced_f32

EQ_ARCHS = ["gemma3-27b", "qwen2.5-3b", "mamba2-130m", "zamba2-7b",
            "qwen3-moe-235b-a22b", "musicgen-medium"]


@pytest.mark.parametrize("arch", EQ_ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = reduced_f32(arch, capacity_factor=8.0)
    params = init_params(cfg, rng)
    b, s = 2, 16
    shape = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    tokens = jax.random.randint(rng, shape, 0, cfg.vocab_size)
    logits_full, _ = forward(params, {"tokens": tokens}, cfg, remat="none")

    cache = init_cache(cfg, b, max_len=s)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    outs = []
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_full - logits_dec))) / scale
    assert err < 5e-4, (arch, err)


@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-7b", "mamba2-130m"])
def test_prefill_matches_sequential_decode(arch, rng):
    cfg = reduced_f32(arch, capacity_factor=8.0)
    params = init_params(cfg, rng)
    b, s, extra = 2, 12, 6
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)

    cache_p = init_cache(cfg, b, max_len=s + extra)
    logits_p, cache_p = prefill(params, {"tokens": tokens}, cfg, cache_p)

    cache_s = init_cache(cfg, b, max_len=s + extra)
    for i in range(s):
        lg, cache_s = decode_step(params, cache_s, tokens[:, i:i + 1], cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_p),
                               rtol=1e-4, atol=1e-4)

    nxt = jnp.argmax(logits_p[:, -1], -1)[:, None]
    l1, _ = decode_step(params, cache_p, nxt, cfg)
    l2, _ = decode_step(params, cache_s, nxt, cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_split_local_cache_equivalence(rng):
    """Gemma3 hillclimb variant: window-capped local ring caches give the
    same logits as the uniform full-length cache."""
    cfg = reduced_f32("gemma3-27b")
    params = init_params(cfg, rng)
    b, s = 2, 24
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    c_uni = init_cache(cfg, b, max_len=s)
    c_spl = init_cache(cfg, b, max_len=s, split_local=True)
    assert "k_local" in c_spl and c_spl["k_local"].shape[2] == cfg.sliding_window
    for i in range(s):
        tok = tokens[:, i:i + 1]
        l1, c_uni = decode_step(params, c_uni, tok, cfg)
        l2, c_spl = decode_step(params, c_spl, tok, cfg)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-4, atol=2e-4)


def test_vlm_prefill_matches_forward(rng):
    cfg = reduced_f32("llava-next-mistral-7b")
    params = init_params(cfg, rng)
    b, s = 2, 12
    batch = {
        "tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
        "img_embeds": jax.random.normal(rng, (b, cfg.img_tokens, cfg.d_model)),
    }
    lf, _ = forward(params, batch, cfg, remat="none")
    cache = init_cache(cfg, b, max_len=s + cfg.img_tokens + 2)
    lp, cache = prefill(params, batch, cfg, cache)
    np.testing.assert_allclose(np.asarray(lf[:, -1:]), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)
    # continue decoding
    nxt = jnp.argmax(lp[:, -1], -1)[:, None]
    lg, _ = decode_step(params, cache, nxt, cfg)
    assert np.all(np.isfinite(np.asarray(lg)))
