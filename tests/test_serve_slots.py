"""Fixed-slot ``ServeEngine`` edge cases (the satellite checklist):
empty-prompt and over-long-prompt rejection, ``Request.last_logits`` as a
real field, ``max_new_tokens=0``, frozen-slot cache bit-identity under
``_merge_cache``, and the slot-reuse / layer-axis regressions found while
building the paged engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ServeConfig
from repro.models import init_params
from repro.serve import Request, ServeEngine

from conftest import reduced_f32


def _mk(arch="qwen2.5-3b", seed=0):
    cfg = reduced_f32(arch)
    return cfg, init_params(cfg, jax.random.PRNGKey(seed))


def _engine(cfg, params, mode, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    scfg = kw.pop("scfg", ServeConfig(max_new_tokens=4))
    return ServeEngine(cfg, params, scfg, mode=mode, **kw)


# ------------------------------------------------------------ submission
@pytest.mark.parametrize("mode", ["slots", "paged"])
def test_empty_prompt_rejected(mode):
    """Defined behaviour for ``prompt == []``: reject at submit (the old
    engine crashed later with an unbound ``logits`` and a stalled slot)."""
    cfg, params = _mk()
    eng = _engine(cfg, params, mode)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    # the engine is still usable afterwards
    eng.submit([1, 2])
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4


@pytest.mark.parametrize("mode", ["slots", "paged"])
def test_out_of_vocab_prompt_rejected(mode):
    """Token ids outside ``[0, vocab_size)`` are a caller bug: they embed
    to an all-zero one-hot and decode to non-finite logits, which the
    fault isolation would misdiagnose as a device fault (retry, then
    quarantine).  Reject them at submit instead."""
    cfg, params = _mk()
    eng = _engine(cfg, params, mode)
    for bad in ([1, cfg.vocab_size, 2], [-1, 1]):
        with pytest.raises(ValueError, match="vocabulary"):
            eng.submit(bad)
    # boundary ids are fine and the engine is still usable
    eng.submit([0, cfg.vocab_size - 1])
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 4


@pytest.mark.parametrize("mode", ["slots", "paged"])
def test_prompt_longer_than_max_len_rejected(mode):
    cfg, params = _mk()
    eng = _engine(cfg, params, mode, max_len=16)
    for n in (16, 15):  # >= max_len - 1: no room to generate
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(list(range(1, n + 1)))
    # max_len - 2 is the longest admissible prompt: exactly one token fits
    req = eng.submit(list(range(1, 15)), max_new_tokens=100)
    done = eng.run()
    assert done == [req] and len(req.output) == 1


# --------------------------------------------------------------- request
def test_last_logits_is_a_real_field():
    names = {f.name for f in dataclasses.fields(Request)}
    assert "last_logits" in names
    req = Request(0, [1], 4)
    assert req.last_logits is None
    req._last_logits = np.zeros((3,))  # deprecated alias still writes it
    assert req.last_logits is not None and req._last_logits is req.last_logits


@pytest.mark.parametrize("mode", ["slots", "paged"])
def test_max_new_tokens_zero(mode):
    """max_new_tokens=0 retires with an empty output (the old loop decoded
    one token before the limit check ran)."""
    cfg, params = _mk()
    eng = _engine(cfg, params, mode)
    r0 = eng.submit([1, 2, 3], max_new_tokens=0)
    r1 = eng.submit([4, 5], max_new_tokens=3)
    done = eng.run()
    assert len(done) == 2
    assert r0.done and r0.output == []
    assert r1.done and len(r1.output) == 3


# ---------------------------------------------------- cache isolation
def _slot_view(cache, slot):
    """Per-slot numpy view of every cache leaf (pos is (B,), stacked
    leaves are (L, B, ...))."""

    def take(path, leaf):
        leaf = np.asarray(leaf)
        top = path[0].key if hasattr(path[0], "key") else None
        unstacked = any(
            isinstance(p, jax.tree_util.SequenceKey) for p in path)
        if top == "pos" or unstacked or leaf.ndim < 2:
            return leaf[slot]
        return leaf[:, slot]

    return jax.tree_util.tree_map_with_path(take, cache)


def test_frozen_slot_cache_bit_identical():
    """While one slot prefills, every other slot's cache (and pos) must be
    bit-identical before/after — ``_merge_cache`` freezes them."""
    cfg, params = _mk()
    eng = _engine(cfg, params, "slots", n_slots=2)
    eng.submit([1, 2, 3])
    eng._admit()                      # request 0 prefilled into slot 0
    before = _slot_view(eng.cache, 0)
    eng.submit([7, 8, 9, 10, 11])
    eng._admit()                      # request 1 prefills into slot 1
    after = _slot_view(eng.cache, 0)
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(b, a)


def test_merge_cache_when_n_slots_equals_n_layers(rng):
    """Regression: with n_slots == n_layers the old shape[0]-based axis
    guess in ``_merge_cache`` merged along the *layer* axis, corrupting
    every slot.  Ground truth is the isolated single-slot engine."""
    cfg, params = _mk(seed=2)
    assert cfg.n_layers == 3  # the collision this test exists for
    prompts = [[1, 2, 3], [4], [5, 6], [7, 8, 9]]
    scfg = ServeConfig(max_new_tokens=6)

    ref = {}
    for i, p in enumerate(prompts):
        eng = _engine(cfg, params, "slots", n_slots=1, scfg=scfg)
        req = eng.submit(p)
        eng.run()
        ref[i] = req.output

    eng = _engine(cfg, params, "slots", n_slots=3, scfg=scfg)
    reqs = [eng.submit(p) for p in prompts]
    eng.run()
    for i, req in enumerate(reqs):
        assert req.output == ref[i], (i, req.output, ref[i])


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-130m"])
def test_slot_reuse_resets_state(arch):
    """Regression: a request admitted into a retired request's slot used to
    inherit its predecessor's cache position (and, for recurrent families,
    conv/h state) and decode with the previous request as context."""
    cfg, params = _mk(arch, seed=3)
    scfg = ServeConfig(max_new_tokens=5)

    solo = _engine(cfg, params, "slots", n_slots=1, scfg=scfg)
    expected = solo.submit([9, 8, 7])
    solo.run()

    eng = _engine(cfg, params, "slots", n_slots=1, scfg=scfg)
    first = eng.submit([1, 2, 3, 4])
    second = eng.submit([9, 8, 7])   # waits, then reuses slot 0
    eng.run()
    assert first.done and second.done
    assert second.output == expected.output, (
        second.output, expected.output)


# --------------------------------------------------- mode="auto" fallback
@pytest.mark.parametrize("arch,family", [("mamba2-130m", "ssm"),
                                         ("zamba2-7b", "hybrid")])
def test_auto_fallback_to_slots_warns_with_family(arch, family, caplog):
    """ssm/hybrid families fall back from mode="auto" to the fixed-slot
    engine — loudly, naming the family, so the capability gap (ROADMAP:
    paged serving for the hybrid family) is visible in server logs
    instead of silently degrading."""
    import logging

    cfg, params = _mk(arch)
    with caplog.at_level(logging.WARNING, logger="repro.serve.engine"):
        eng = _engine(cfg, params, None, scfg=ServeConfig(max_new_tokens=2))
    assert eng.mode == "slots"
    msgs = [r.message for r in caplog.records
            if "falling back to mode='slots'" in r.message]
    assert msgs and repr(family) in msgs[0], caplog.records


def test_auto_paged_family_does_not_warn(caplog):
    """Attention families resolve mode="auto" to paged with no fallback
    warning in the logs."""
    import logging

    cfg, params = _mk("qwen2.5-3b")
    with caplog.at_level(logging.WARNING, logger="repro.serve.engine"):
        eng = _engine(cfg, params, None, scfg=ServeConfig(max_new_tokens=2))
    assert eng.mode == "paged"
    assert not [r for r in caplog.records
                if "falling back" in r.message]
