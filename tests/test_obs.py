"""Serve-path observability (``repro.obs``).

Pins the subsystem's tentpole claims: the disabled path is a true no-op
(no registry, no clocks, identical tokens with telemetry on/off); the
bounded-bucket histogram's percentile estimate tracks the exact
``benchmarks.common.percentile`` within the owning bucket's width; span
timelines cover every lifecycle path including shed / cancel / preempt /
timeout; exported Chrome traces satisfy the trace-event schema contract
(required keys, consistent B/E nesting per track); and TTFT is measured
per request from its own submit time — the regression this PR fixed,
where mid-run submissions inherited the engine's run start as their
zero point.
"""

import json

import pytest

import repro.obs as obs
from benchmarks.common import percentile as exact_percentile
from repro.config.base import EngineConfig, ServeConfig
from repro.models import init_params
from repro.obs import (
    Histogram,
    MetricsRegistry,
    NULL_TELEMETRY,
    RequestTimeline,
    Telemetry,
    validate_trace,
)
from repro.obs import spans
from repro.obs.trace import CACHE_TID, ENGINE_TID, SCHED_TID
from repro.serve import AdmissionRejected, ServeEngine

from conftest import reduced_f32


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _engine(cfg, params, *, n_slots=2, max_len=64, max_new=4,
            prefix_cache=False, sched="fcfs", clock=None, telemetry=None,
            **scfg_kw):
    scfg = ServeConfig(max_new_tokens=max_new, sched=sched,
                       engine=EngineConfig(backend="reference"), **scfg_kw)
    return ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                       mode="paged", page_size=4, prefill_chunk=3,
                       prefix_cache=prefix_cache, clock=clock,
                       telemetry=telemetry)


# ------------------------------------------------------------- registry
def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    # create-or-return: same (name, labels) -> same object
    assert reg.counter("reqs_total") is c
    assert reg.counter("reqs_total", reason="shed") is not c
    g = reg.gauge("depth")
    g.set(3)
    g.inc()
    g.dec(2)
    assert g.value == 2
    # a name is bound to one instrument kind
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")


def test_histogram_percentile_tracks_exact_within_bucket_width():
    """The bounded-bucket estimate vs the exact sorted-sample percentile:
    the error is bounded by the width of the bucket the rank lands in."""
    import random

    rng = random.Random(7)
    h = Histogram("lat", ())
    samples = [rng.uniform(0.0002, 2.0) for _ in range(500)]
    for v in samples:
        h.observe(v)
    for q in (50, 90, 95, 99):
        est = h.percentile(q)
        exact = exact_percentile(samples, q)
        # owning bucket of the exact answer
        import bisect
        i = bisect.bisect_left(h.bounds, exact)
        lo = h.bounds[i - 1] if i > 0 else h.min
        hi = h.bounds[i] if i < len(h.bounds) else h.max
        assert abs(est - exact) <= (hi - lo) + 1e-12, (q, est, exact)
        assert h.min <= est <= h.max


def test_histogram_edges_and_snapshot():
    h = Histogram("lat", (), buckets=(0.1, 1.0))
    for v in (0.05, 0.1, 0.5, 2.0):
        h.observe(v)
    assert h.counts == [2, 1, 1]  # le=0.1 gets both 0.05 and 0.1
    d = h.to_dict()
    assert d["count"] == 4 and d["inf"] == 1
    assert d["min"] == 0.05 and d["max"] == 2.0
    empty = Histogram("none", ())
    assert empty.percentile(50) is None
    assert empty.to_dict()["min"] is None


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("reqs_total", reason="shed").inc(2)
    reg.gauge("pages_free").set(7)
    reg.histogram("lat_s", buckets=(0.1, 1.0)).observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{reason="shed"} 2' in text
    assert "# TYPE pages_free gauge" in text
    assert "# TYPE lat_s histogram" in text
    assert 'lat_s_bucket{le="1.0"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_count 1" in text


# ---------------------------------------------------------------- spans
def test_timeline_lifecycle_and_latency_decomposition():
    tl = RequestTimeline(0, submit_t=1.0)
    tl.transition(spans.ADMITTED, 3.0)
    tl.transition(spans.PREFILLING, 3.0)
    tl.transition(spans.DECODING, 5.0)
    tl.token(5.0)
    tl.token(6.0)
    tl.token(8.0)
    tl.transition(spans.RETIRED, 9.0)
    assert tl.queue_wait == 2.0
    assert tl.ttft == 4.0
    assert tl.tpot == pytest.approx(1.5)  # (8-5)/2
    assert tl.e2e == 8.0
    assert tl.finished and tl.state == spans.RETIRED
    d = tl.to_dict()
    assert d["events"][0] == (spans.SUBMITTED, 1.0)
    assert d["n_tokens"] == 3


def test_timeline_preempt_requeues_and_counts():
    tl = RequestTimeline(1, submit_t=0.0)
    tl.transition(spans.ADMITTED, 1.0)
    tl.transition(spans.PREEMPTED, 2.0)
    assert tl.state == spans.QUEUED  # preemption loops back to queued
    assert tl.n_preemptions == 1
    tl.transition(spans.ADMITTED, 3.0)
    assert tl.queue_wait == 1.0  # first admission wins
    tl.transition(spans.CANCELLED, 4.0)
    assert tl.finished and tl.e2e == 4.0


def test_telemetry_shed_cancel_timeout_paths():
    clk = ManualClock()
    tel = Telemetry(clk, trace=True)
    tel.attach_engine(2, "paged")
    # shed: refused pre-Request — counted by reason, no timeline
    tel.on_shed("queue_full")
    tel.on_shed("deadline")
    assert tel.registry.counter("serve_requests_shed_total",
                                reason="queue_full").value == 1
    assert not tel.timelines
    # cancel vs timeout map to distinct terminal states
    tel.on_submit(0, 4, clk())
    tel.on_submit(1, 4, clk())
    clk.advance(1.0)
    tel.on_cancel(0, "user")
    tel.on_cancel(1, "timed_out")
    assert tel.timelines[0].state == spans.CANCELLED
    assert tel.timelines[1].state == spans.TIMED_OUT
    # preempt path re-queues in the timeline and bumps the counter
    tel.on_submit(2, 4, clk())
    tel.on_admit(2, 0, 0)
    tel.on_preempt(2, 0)
    assert tel.timelines[2].state == spans.QUEUED
    assert tel.registry.counter("serve_preemptions_total").value == 1
    states = tel.snapshot()["request_states"]
    assert states == {spans.CANCELLED: 1, spans.TIMED_OUT: 1,
                      spans.QUEUED: 1}


# ------------------------------------------------------- disabled path
def test_disabled_telemetry_is_noop(rng):
    """With obs off an engine carries the NULL_TELEMETRY singleton: no
    registry, no tracer, no timelines, hooks mutate nothing."""
    assert obs.enabled is False
    assert obs.telemetry() is NULL_TELEMETRY
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params)
    assert eng.obs is NULL_TELEMETRY
    eng.submit([1, 2, 3])
    eng.run()
    assert NULL_TELEMETRY.registry is None
    assert NULL_TELEMETRY.tracer is None
    assert not NULL_TELEMETRY.timelines
    assert NULL_TELEMETRY.snapshot() == {}
    assert NULL_TELEMETRY.export_chrome_trace("/dev/null") is None
    m = eng.metrics()
    assert "obs" not in m and m["submitted"] == 1


def test_tokens_identical_with_telemetry_on_and_off(rng):
    """Observability observes; it never perturbs the greedy tokens."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = [[1, 2, 3], [4], [5, 6, 7, 8], [2, 2]]

    def serve(tel):
        eng = _engine(cfg, params, telemetry=tel)
        reqs = [eng.submit(list(p)) for p in prompts]
        eng.run()
        return [r.output for r in reqs]

    off = serve(None)
    on = serve(Telemetry(trace=True))
    assert off == on


# ------------------------------------------------------- engine wiring
def test_engine_metrics_and_trace_end_to_end(rng):
    """A real serve run through a live Telemetry: counters line up with
    request facts, the trace validates, and every expected track (engine,
    lanes, scheduler, prefix-cache, pages) carries events."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    tel = Telemetry(trace=True)
    eng = _engine(cfg, params, prefix_cache=True, telemetry=tel, max_new=3)
    prefix = list(range(1, 9))
    r0 = eng.submit(prefix + [9])
    eng.run()
    reqs = [eng.submit(prefix + [20 + i]) for i in range(2)]
    eng.run()

    reg = tel.registry
    assert reg.counter("serve_requests_submitted_total").value == 3
    assert reg.counter("serve_tokens_generated_total").value == sum(
        len(r.output) for r in [r0] + reqs)
    assert reg.counter("prefix_cache_hits_total").value >= 1
    snap = eng.metrics()
    assert snap["obs"]["steps"] > 0
    assert snap["obs"]["request_states"] == {spans.RETIRED: 3}
    assert snap["prefix"]["hit_tokens"] >= 8

    counts = validate_trace(tel.tracer.export())
    pid = tel.tracer.pid
    for tid in (ENGINE_TID, 1, SCHED_TID, CACHE_TID):
        assert counts.get(f"{pid}/{tid}", 0) > 0, f"track {tid} empty"
    names = {(e["tid"], e["name"]) for e in tel.tracer.events}
    assert (1, "prefill") in names and (1, "decode") in names
    assert (SCHED_TID, "admit") in names and (SCHED_TID, "retire") in names
    # per-request timelines carry the full latency decomposition
    tl = tel.timelines[reqs[0].rid].to_dict()
    assert tl["state"] == spans.RETIRED
    assert tl["ttft_s"] is not None and tl["e2e_s"] >= tl["ttft_s"]
    assert tl["cached_tokens"] == 8  # two prefix pages matched


def test_trace_validation_rejects_malformed(tmp_path):
    clk = ManualClock()
    tel = Telemetry(clk, trace=True)
    tel.attach_engine(1, "paged")
    t0 = clk()
    tel.step_begin()
    clk.advance(0.001)
    tel.step_end(t0)
    path = str(tmp_path / "trace.json")
    tel.export_chrome_trace(path)
    with open(path) as f:
        trace = json.load(f)
    for ev in trace["traceEvents"]:
        for k in ("ph", "ts", "pid", "tid", "name"):
            assert k in ev
    validate_trace(trace)

    bad = {"traceEvents": [dict(e) for e in trace["traceEvents"]]}
    del bad["traceEvents"][-1]  # drop the E: unclosed B must fail
    with pytest.raises(ValueError, match="open"):
        validate_trace(bad)
    bad2 = {"traceEvents": [{"ph": "B", "ts": 0, "pid": 1, "tid": 0}]}
    with pytest.raises(ValueError, match="name"):
        validate_trace(bad2)
    bad3 = {"traceEvents": [
        {"ph": "B", "ts": 5.0, "pid": 1, "tid": 0, "name": "a"},
        {"ph": "E", "ts": 1.0, "pid": 1, "tid": 0, "name": "a"},
    ]}
    with pytest.raises(ValueError, match="backwards"):
        validate_trace(bad3)


def test_shed_is_counted_by_reason(rng):
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    tel = Telemetry(trace=False)
    eng = _engine(cfg, params, n_slots=1, sched="budget", max_queue=1,
                  telemetry=tel)
    eng.submit([1, 2, 3])   # queued (depth 1 = max_queue)
    with pytest.raises(AdmissionRejected):
        eng.submit([4, 5, 6])  # queue full -> shed
    shed = [c for (name, _), c
            in tel.registry._counters.items()
            if name == "serve_requests_shed_total"]
    assert sum(c.value for c in shed) == 1
    eng.run()


# -------------------------------------------------- the TTFT regression
def test_ttft_is_per_request_not_run_relative(rng):
    """Regression: a request submitted long after the engine started
    running must get a TTFT measured from *its own* submit time, not
    from the engine's run start (the pre-obs bug gave it the full gap)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    clk = ManualClock()
    tel = Telemetry(clk, trace=False)
    eng = _engine(cfg, params, n_slots=2, telemetry=tel, clock=clk)

    r1 = eng.submit([1, 2, 3])
    while not r1.output:  # engine mid-run, r1 decoding
        clk.advance(0.01)
        eng.step()
    gap = 10.0
    clk.advance(gap)  # long idle gap before the late arrival
    r2 = eng.submit([4, 5, 6])
    while r2.ttft is None:
        clk.advance(0.01)
        eng.step()
    eng.run()
    # r2's TTFT covers only its own prefill steps, never the 10s gap
    assert r2.ttft < gap / 2, r2.ttft
    assert r1.ttft is not None and r1.ttft < gap / 2
    # the timelines agree with the Request fields
    assert tel.timelines[r2.rid].ttft == pytest.approx(r2.ttft)
    assert tel.registry.histogram("serve_ttft_s").count == 2
