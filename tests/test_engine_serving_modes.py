"""Decode-path variants: unstacked caches, int8 KV cache, engine bits —
all must agree with the reference stacked/bf16/dense path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig
from repro.models import decode_step, init_cache, init_params, quantize_params

from conftest import reduced_f32


def _roll(cfg, params, caches, engs, steps=10, seed=1):
    toks = jax.random.randint(
        jax.random.PRNGKey(seed),
        (2, steps, cfg.n_codebooks) if cfg.family == "audio" else (2, steps),
        0, cfg.vocab_size)
    outs = [[] for _ in caches]
    for i in range(steps):
        t = toks[:, i:i + 1]
        for j, (p, c, e) in enumerate(zip(params, caches, engs)):
            lg, caches[j] = decode_step(p, caches[j], t, cfg, e)
            outs[j].append(np.asarray(lg))
    return [np.concatenate(o, axis=1) for o in outs]


@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-7b",
                                  "qwen3-moe-235b-a22b"])
def test_unstacked_equals_stacked(arch, rng):
    cfg = reduced_f32(arch, capacity_factor=8.0)
    p = init_params(cfg, rng)
    c1 = init_cache(cfg, 2, max_len=10, stacked=True)
    c2 = init_cache(cfg, 2, max_len=10, stacked=False)
    o1, o2 = _roll(cfg, [p, p], [c1, c2], [None, None])
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_close(rng):
    cfg = reduced_f32("qwen2.5-3b")
    p = init_params(cfg, rng)
    c1 = init_cache(cfg, 2, max_len=10, stacked=False)
    c2 = init_cache(cfg, 2, max_len=10, stacked=False, kv_bits=8)
    assert c2["k"][0].dtype == jnp.int8
    assert "k_scale" in c2
    o1, o2 = _roll(cfg, [p, p], [c1, c2], [None, None])
    rel = np.max(np.abs(o1 - o2)) / np.max(np.abs(o1))
    assert rel < 0.05, rel
    agree = np.mean(np.argmax(o1, -1) == np.argmax(o2, -1))
    assert agree > 0.85, agree


def test_engine_bits_with_unstacked_cache(rng):
    cfg = reduced_f32("qwen2.5-3b")
    p = init_params(cfg, rng)
    q8 = quantize_params(p, cfg, 8)
    eng = EngineConfig(weight_bits=8, backend="reference")
    c1 = init_cache(cfg, 2, max_len=10, stacked=False)
    c2 = init_cache(cfg, 2, max_len=10, stacked=False)
    o1, o2 = _roll(cfg, [p, q8], [c1, c2], [None, eng])
    agree = np.mean(np.argmax(o1, -1) == np.argmax(o2, -1))
    assert agree > 0.85, agree


def test_full_imagine_mode(rng):
    """weights int8 bit-plane + int8 KV cache together (hillclimb-A final)."""
    cfg = reduced_f32("gemma3-27b")
    p = init_params(cfg, rng)
    q8 = quantize_params(p, cfg, 8)
    eng = EngineConfig(weight_bits=8, kv_bits=8, backend="reference")
    c1 = init_cache(cfg, 2, max_len=10, stacked=False)
    c2 = init_cache(cfg, 2, max_len=10, stacked=False, kv_bits=8)
    o1, o2 = _roll(cfg, [p, q8], [c1, c2], [None, eng])
    agree = np.mean(np.argmax(o1, -1) == np.argmax(o2, -1))
    assert agree > 0.8, agree
