"""Trace validity under chaos and snapshot/restore.

A Chrome trace exported from a serve run must stay schema-valid — and
every request timeline must land in a terminal span state — no matter
how the run ended: seeded chaos faults with retries, quarantines that
exhaust the retry budget, or a kill-at-step-k engine whose in-flight
requests were restored into a fresh engine.  Dangling non-terminal
spans are exactly the bug class ``validate_trace`` and
``spans.TERMINAL`` exist to catch: a crashed engine that leaves a
request "decoding" forever renders as an open span across the rest of
the profile.
"""

import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.ft import ChaosInjector
from repro.models import init_params
from repro.obs import Telemetry, spans, validate_trace
from repro.serve import ServeEngine

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


@pytest.fixture(scope="module")
def model():
    import jax

    cfg = reduced_f32("qwen2.5-3b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, tel, *, chaos=None, max_request_retries=1,
            max_new=5):
    scfg = ServeConfig(max_new_tokens=max_new,
                       engine=EngineConfig(backend="reference"),
                       max_request_retries=max_request_retries)
    return ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                       mode="paged", page_size=4, prefill_chunk=3,
                       telemetry=tel, chaos=chaos)


def _assert_all_terminal(tel):
    states = {rid: tl.state for rid, tl in tel.timelines.items()}
    bad = {rid: s for rid, s in states.items() if s not in spans.TERMINAL}
    assert not bad, f"non-terminal timelines after run: {bad}"
    return states


def test_trace_valid_under_chaos_retries(model):
    cfg, params = model
    tel = Telemetry(trace=True)
    chaos = ChaosInjector(seed=3, schedule={"step_fault": {1}})
    eng = _engine(cfg, params, tel, chaos=chaos)
    for p in PROMPTS:
        eng.submit(list(p))
    done = eng.run()
    assert all(r.done for r in done)
    assert eng.retried >= 1

    counts = validate_trace(tel.tracer.export())
    assert sum(counts.values()) > 0
    states = _assert_all_terminal(tel)
    assert set(states.values()) == {spans.RETIRED}
    # the fault and the retry both left scheduler-track marks
    names = {(e["tid"], e["name"]) for e in tel.tracer.events}
    assert (1000, "fault") in names and (1000, "retry") in names


def test_trace_valid_with_quarantine(model):
    """Retry budget zero: the quarantined request's timeline must end
    ``errored`` (terminal), not dangle in a live decode span."""
    cfg, params = model
    tel = Telemetry(trace=True)
    chaos = ChaosInjector(seed=5, schedule={"nan_logits": {2}})
    eng = _engine(cfg, params, tel, chaos=chaos, max_request_retries=0)
    for p in PROMPTS:
        eng.submit(list(p))
    done = eng.run()
    errs = [r for r in done if r.finish_reason == "error"]
    assert len(errs) == 1 and eng.quarantined == 1

    validate_trace(tel.tracer.export())
    states = _assert_all_terminal(tel)
    assert states[errs[0].rid] == spans.ERRORED
    assert sorted(states.values()).count(spans.RETIRED) == len(PROMPTS) - 1


def test_trace_valid_across_kill_and_restore(model):
    """Kill engine A at step k with requests in flight; restore into a
    fresh engine B.  Both traces validate, A's abandoned timelines are
    force-closed, B's restored timelines run to terminal states, and
    the restore is counted."""
    cfg, params = model
    telA = Telemetry(trace=True)
    engA = _engine(cfg, params, telA)
    for p in PROMPTS:
        engA.submit(list(p))
    for _ in range(3):  # mid-prefill / early-decode crash point
        engA.step()
    snap = engA.snapshot()
    in_flight = {r["rid"] for r in snap["host"]["requests"]}
    assert in_flight  # the crash point must actually strand requests

    # engine A is "killed": force-close whatever is still live
    closed = telA.close_open_timelines()
    assert closed == len(in_flight)
    validate_trace(telA.tracer.export())
    statesA = _assert_all_terminal(telA)
    assert all(statesA[rid] == spans.ERRORED for rid in in_flight)

    telB = Telemetry(trace=True)
    engB = _engine(cfg, params, telB)
    engB.restore(snap)
    # restored requests open fresh timelines under B's telemetry
    assert set(telB.timelines) == in_flight
    assert (telB.registry.counter("serve_requests_restored_total").value
            == len(in_flight))
    done = engB.run()
    assert {r.rid for r in done} == in_flight

    validate_trace(telB.tracer.export())
    statesB = _assert_all_terminal(telB)
    assert all(statesB[rid] == spans.RETIRED for rid in in_flight)
    names = {(e["tid"], e["name"]) for e in telB.tracer.events}
    assert (1000, "restore") in names


def test_trace_valid_chaos_then_restore(model):
    """The load_bench --trace shape end to end: seeded chaos during the
    run AND a kill-at-k restore — the restored engine's trace (with its
    own chaos marks) still validates and terminates every span."""
    cfg, params = model
    chaosA = ChaosInjector(seed=7, schedule={"step_fault": {1}})
    telA = Telemetry(trace=True)
    engA = _engine(cfg, params, telA, chaos=chaosA)
    for p in PROMPTS:
        engA.submit(list(p))
    for _ in range(4):
        engA.step()
    snap = engA.snapshot()
    telA.close_open_timelines()
    validate_trace(telA.tracer.export())
    _assert_all_terminal(telA)

    chaosB = ChaosInjector(seed=7, schedule={"step_fault": {0}})
    telB = Telemetry(trace=True)
    engB = _engine(cfg, params, telB, chaos=chaosB)
    engB.restore(snap)
    done = engB.run()
    assert all(r.done or r.finish_reason == "error" for r in done)

    validate_trace(telB.tracer.export())
    _assert_all_terminal(telB)
    # chaos fired in B's own run and self-reported through B's telemetry
    if engB.retried or engB.quarantined:
        assert (telB.registry.counter(
            "serve_chaos_injected_total",
            site="step_fault").value >= 1)
