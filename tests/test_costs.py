"""The per-step cost ledger (``repro.obs.costs``) and the perf-history
regression gate (``benchmarks.history``).

Three contracts:

* **Honesty** — for two small dense shapes, kv_bits 0/8, one paged
  decode step and one chunked prefill, the analytic FLOPs tables match
  what XLA actually compiled (``jax.jit(...).lower().compile()`` routed
  through the trip-count-aware ``repro.roofline.analysis.compiled_costs``)
  within 5%.
* **Attribution** — a served engine charges every dispatch to the ledger:
  per-op totals cover gemv (including the synthesized tied-embedding
  lm_head), attention, kv writes; per-request rows sum to the totals;
  a chaos-retried request's recomputed work lands in ``wasted_flops``
  and the ft/chaos counters surface through ``ServeEngine.metrics()``.
* **Regression gate** — ``benchmarks.history.check_regression`` fails a
  synthetic 20% tok/s regression against the recorded best, skips
  records from a different device/interpret provenance, and passes an
  unchanged record.
"""

import functools
import os
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.config.base import EngineConfig, ModelConfig, ServeConfig
from repro.models import decode_step_paged, init_params, prefill_chunk
from repro.obs import Telemetry, costs
from repro.roofline.analysis import compiled_costs
from repro.serve import ServeEngine
from repro.serve.pages import init_kv_pages

from conftest import reduced_f32

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SHAPES = [
    ModelConfig(name="a", family="dense", n_layers=2, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512),
    ModelConfig(name="b", family="dense", n_layers=3, d_model=256,
                n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=1024),
]
B, PAGE, NBLK, CHUNK = 4, 8, 4, 16
TOL = 0.05

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


# ------------------------------------------------- modeled vs compiled
def _paged_inputs(cfg, kv_bits):
    params = init_params(cfg, jax.random.PRNGKey(0))
    pages = init_kv_pages(cfg, B * NBLK + 1, PAGE, kv_bits=kv_bits)
    bt = jnp.arange(1, 1 + B * NBLK, dtype=jnp.int32).reshape(B, NBLK)
    return params, pages, bt


@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("cfg", SHAPES, ids=lambda c: c.name)
def test_decode_flops_match_compiled(cfg, kv_bits):
    params, pages, bt = _paged_inputs(cfg, kv_bits)
    fn = jax.jit(functools.partial(decode_step_paged, cfg=cfg, eng=None,
                                   attn_backend="gather"))
    comp = fn.lower(params, pages, bt, jnp.full((B,), 5, jnp.int32),
                    jnp.ones((B,), bool),
                    jnp.zeros((B, 1), jnp.int32)).compile()
    measured = compiled_costs(comp)["flops"]
    modeled = costs.total_cost(costs.decode_step_costs(
        costs.model_dims(cfg), batch=B, context=NBLK * PAGE,
        page_size=PAGE, kv_bits=kv_bits)).flops
    assert measured > 0
    ratio = modeled / measured
    assert 1 - TOL <= ratio <= 1 + TOL, (
        f"decode {cfg.name} kv{kv_bits}: modeled {modeled:.3e} vs "
        f"compiled {measured:.3e} (ratio {ratio:.4f})")


@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("cfg", SHAPES, ids=lambda c: c.name)
def test_prefill_flops_match_compiled(cfg, kv_bits):
    params, pages, bt = _paged_inputs(cfg, kv_bits)
    fn = jax.jit(functools.partial(prefill_chunk, cfg=cfg, eng=None,
                                   attn_backend="gather"))
    comp = fn.lower(params, pages, bt, jnp.zeros((B, CHUNK), jnp.int32),
                    jnp.zeros((B,), jnp.int32),
                    jnp.full((B,), CHUNK, jnp.int32)).compile()
    measured = compiled_costs(comp)["flops"]
    modeled = costs.total_cost(costs.prefill_chunk_costs(
        costs.model_dims(cfg), batch=B, chunk=CHUNK, context=NBLK * PAGE,
        page_size=PAGE, kv_bits=kv_bits)).flops
    assert measured > 0
    ratio = modeled / measured
    assert 1 - TOL <= ratio <= 1 + TOL, (
        f"prefill {cfg.name} kv{kv_bits}: modeled {modeled:.3e} vs "
        f"compiled {measured:.3e} (ratio {ratio:.4f})")


def test_tied_embedding_lm_head_synthesized():
    """``linear_specs`` walks the live param tree and cannot see a tied
    lm_head — the table builders must synthesize one, or the logits
    GEMV (the single largest decode op) goes unbilled."""
    cfg = reduced_f32("qwen2.5-3b")
    assert cfg.tie_embeddings
    params = init_params(cfg, jax.random.PRNGKey(0))
    specs = costs.linear_specs(params)
    assert not any(s.name.endswith("lm_head") for s in specs)
    table = costs.decode_step_costs(
        costs.model_dims(cfg), batch=2, context=32, page_size=4,
        specs=specs)
    assert "gemv/lm_head" in table
    assert table["gemv/lm_head"].flops > 0


# ------------------------------------------------- ledger in the engine
@pytest.fixture(scope="module")
def model():
    cfg = reduced_f32("qwen2.5-3b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, tel, *, chaos=None, max_new=5):
    scfg = ServeConfig(max_new_tokens=max_new,
                       engine=EngineConfig(backend="reference"),
                       max_request_retries=1)
    return ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                       mode="paged", page_size=4, prefill_chunk=3,
                       telemetry=tel, chaos=chaos)


def test_ledger_charges_every_dispatch(model):
    cfg, params = model
    tel = Telemetry(trace=False)
    eng = _engine(cfg, params, tel)
    for p in PROMPTS:
        eng.submit(list(p))
    done = eng.run()
    assert all(r.done for r in done)

    m = eng.metrics()
    led = m["costs"]
    assert led["total_flops"] > 0 and led["total_bytes"] > 0
    ops = set(led["by_op"])
    assert {"attn_decode", "attn_prefill", "kv_write", "other"} <= ops
    assert "gemv/lm_head" in ops  # tied embeddings: synthesized spec
    assert any(o.startswith("gemv/") and o != "gemv/lm_head" for o in ops)

    # even attribution: per-request rows sum back to the totals
    reqs = led["requests"]
    assert sorted(int(k) for k in reqs) == [r.rid for r in done]
    tot_f = sum(row["flops"] for row in reqs.values())
    assert tot_f == pytest.approx(led["total_flops"], rel=1e-6)
    assert led["wasted_flops"] == 0

    # the registry mirrors the per-op totals (Prometheus-exportable)
    counters = m["obs"]["metrics"]["counters"]
    flops_counters = {k: v for k, v in counters.items()
                      if k.startswith("serve_cost_flops_total")}
    assert sum(flops_counters.values()) == pytest.approx(
        led["total_flops"], rel=1e-6)


def test_retry_waste_attributed_under_chaos(model):
    from repro.ft import ChaosInjector

    cfg, params = model
    tel = Telemetry(trace=False)
    chaos = ChaosInjector(seed=0, schedule={"step_fault": {1}})
    eng = _engine(cfg, params, tel, chaos=chaos)
    for p in PROMPTS:
        eng.submit(list(p))
    done = eng.run()
    assert all(r.done for r in done)

    m = eng.metrics()
    assert m["ft"]["retried"] >= 1
    assert m["ft"]["quarantined"] == 0
    assert m["ft"]["chaos"].get("step_fault", 0) >= 1

    # work charged before the fault is recomputed: it must show as waste
    led = m["costs"]
    assert led["wasted_flops"] > 0
    retried = [row for row in led["requests"].values()
               if row["retries"] > 0]
    assert retried and all(row["wasted_flops"] > 0 for row in retried)

    # the injector self-reports through the engine's telemetry
    counters = m["obs"]["metrics"]["counters"]
    chaos_hits = sum(v for k, v in counters.items()
                     if k.startswith("serve_chaos_injected_total"))
    assert chaos_hits == sum(m["ft"]["chaos"].values())
    retry_hits = sum(v for k, v in counters.items()
                     if k.startswith("serve_retries_total"))
    assert retry_hits == m["ft"]["retried"]


def test_ledger_off_engine_reports_no_costs(model):
    cfg, params = model
    from repro.obs.telemetry import NULL_TELEMETRY

    eng = _engine(cfg, params, NULL_TELEMETRY)
    eng.submit([1, 2, 3])
    eng.run()
    m = eng.metrics()
    assert "costs" not in m and "obs" not in m
    assert m["ft"]["retried"] == 0  # ft block is always present


# ------------------------------------------------- perf-history gate
def _record(tok=100.0, bpt=50.0, device="cpu", interpret=True):
    return {"bench": "costs", "device_kind": device,
            "interpret_mode": interpret,
            "results": [{"arm": "ledger", "tok_per_s": tok}],
            "ledger": {"ledger_bytes_per_tok": bpt}}


def test_check_regression_fires_on_synthetic_regression(tmp_path):
    from benchmarks import history

    out = str(tmp_path / "BENCH_costs.json")
    hpath = history.append_record(out, _record())
    assert hpath == str(tmp_path / history.HISTORY_NAME)

    # unchanged record: no regression
    assert history.check_regression(_record(), hpath, "costs") == []
    # 20% tok/s drop and 20% bytes/token inflation: both caught
    regs = history.check_regression(_record(tok=80.0, bpt=60.0),
                                    hpath, "costs")
    keys = {k for k, _, _ in regs}
    assert any("tok_per_s" in k for k in keys)
    assert any("bytes_per_tok" in k for k in keys)
    # within tolerance: 5% off the best is not a regression at tol=10%
    assert history.check_regression(_record(tok=95.0), hpath, "costs") == []
    # a hardware run never gates against an interpret-mode baseline
    assert history.check_regression(
        _record(tok=10.0, device="TPU v4", interpret=False),
        hpath, "costs") == []


def test_history_provenance_and_best_prior(tmp_path):
    from benchmarks import history

    out = str(tmp_path / "BENCH_costs.json")
    history.append_record(out, _record(tok=100.0))
    history.append_record(out, _record(tok=120.0))
    history.append_record(out, _record(tok=90.0, device="TPU v4"))
    entries = history.load_history(history.history_path_for(out))
    assert len(entries) == 3
    assert all(e["bench"] == "costs" and "ts" in e and "commit" in e
               for e in entries)
    best = history.best_prior(entries, "costs", "cpu", True)
    tok_keys = [k for k in best if "tok_per_s" in k]
    assert tok_keys and best[tok_keys[0]] == 120.0  # best, not latest


def test_history_self_test_passes(capsys):
    from benchmarks import history

    assert history.main(["--self-test"]) == 0
    assert "self-test ok" in capsys.readouterr().out
