"""Minimal deterministic stand-in for the tiny `hypothesis` API surface
these tests use (``given``, ``settings``, ``st.integers``,
``st.sampled_from``).

The container image does not ship hypothesis; rather than losing the
property tests at collection time, this shim replays each property with a
fixed number of seeded pseudo-random examples.  It is NOT a shrinking
property-testing engine — when real hypothesis is installed it is used
instead (see the try/except import in each test module).
"""

from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 15


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng: random.Random):
        return self._sampler(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elements.sample(rng)
            for _ in range(rng.randint(min_size, max_size))])


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_shim_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(fn.__qualname__)  # deterministic per test
            for _ in range(n):
                draw = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **draw)

        # pytest must not see the strategy parameters as fixtures: expose
        # the original signature minus the drawn arguments.
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strats]
        runner.__signature__ = inspect.Signature(params)
        del runner.__wrapped__
        return runner

    return deco
