"""Attention path equivalences: dense == flash == local-gather == decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attend_decode,
    attend_dense,
    attend_flash,
    attend_local_gather,
)


def _qkv(b=2, s=128, hq=8, hkv=4, d=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
def test_flash_equals_dense(window, bq, bkv):
    q, k, v, pos = _qkv()
    od = attend_dense(q, k, v, pos, pos, window)
    of = attend_flash(q, k, v, pos, window, block_q=bq, block_kv=bkv)
    np.testing.assert_allclose(np.asarray(od), np.asarray(of),
                               rtol=1e-5, atol=1e-5)


def test_flash_traced_window():
    """Per-layer window flags are traced scalars under scan."""
    q, k, v, pos = _qkv()
    f = jax.jit(lambda w: attend_flash(q, k, v, pos, w, block_q=64,
                                       block_kv=64))
    od0 = attend_dense(q, k, v, pos, pos, 0)
    od32 = attend_dense(q, k, v, pos, pos, 32)
    np.testing.assert_allclose(np.asarray(f(jnp.int32(0))), np.asarray(od0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f(jnp.int32(32))), np.asarray(od32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_local_gather_equals_dense(window):
    q, k, v, pos = _qkv(s=256)
    od = attend_dense(q, k, v, pos, pos, window)
    og = attend_local_gather(q, k, v, pos, window)
    np.testing.assert_allclose(np.asarray(od), np.asarray(og),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_dense_last_position():
    """Decode with a cache == dense attention's last-row output."""
    q, k, v, pos = _qkv(s=64)
    out_full = attend_dense(q, k, v, pos, pos, 0)
    got = attend_decode(q[:, -1:], k, v, jnp.full((2,), 63), 0)
    np.testing.assert_allclose(np.asarray(out_full[:, -1:]), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_decode_window_masks_old_tokens():
    q, k, v, pos = _qkv(s=64)
    full = attend_decode(q[:, -1:], k, v, jnp.full((2,), 63), 0)
    windowed = attend_decode(q[:, -1:], k, v, jnp.full((2,), 63), 16)
    assert not np.allclose(np.asarray(full), np.asarray(windowed))
    # windowed == dense with the same sliding window
    ref = attend_dense(q, k, v, pos, pos, 16)[:, -1:]
    np.testing.assert_allclose(np.asarray(windowed), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_gqa_grouping():
    """GQA result == MHA with kv heads explicitly repeated."""
    q, k, v, pos = _qkv(hq=8, hkv=2)
    out_gqa = attend_dense(q, k, v, pos, pos, 0)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    out_mha = attend_dense(q, k_rep, v_rep, pos, pos, 0)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)


def test_causality():
    """Future tokens never influence past outputs."""
    q, k, v, pos = _qkv(s=32, seed=3)
    base = attend_dense(q, k, v, pos, pos, 0)
    k2 = k.at[:, -1].set(999.0)
    v2 = v.at[:, -1].set(999.0)
    pert = attend_dense(q, k2, v2, pos, pos, 0)
    np.testing.assert_allclose(np.asarray(base[:, :-1]),
                               np.asarray(pert[:, :-1]), rtol=1e-5, atol=1e-5)
