"""The unified engine API: PackedLinear pytree semantics, backend
equivalence (reference == bit_serial == pallas_interpret across bits,
radix and input ranks), plan resolution from EngineConfig, and the
deprecation shims (old gemv / engine_dense / param-dict call styles must
produce bit-identical results through the new dispatch)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig
from repro.core.gemv_engine import (
    QuantizedLinear,
    engine_dense,
    gemv,
    gemv_bit_serial_reference,
    gemv_reference,
    quantize_linear,
)
from repro.engine import (
    EnginePlan,
    PackedLinear,
    as_packed,
    as_plan,
    available_backends,
    pack_linear,
    plan_for_bits,
    register_backend,
    resolve_plan,
)

BACKENDS = ("reference", "bit_serial", "pallas_interpret")


def _data(b, k, n, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32))
    return w, x


# ---------------------------------------------------------------------------
# backend equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("radix", [1, 2, 4])
@pytest.mark.parametrize("rank", ["1d", "2d", "batched"])
def test_backend_equivalence(bits, radix, rank):
    if bits % radix != 0:
        pytest.skip(f"radix {radix} does not divide bits {bits}")
    w, x2 = _data(3, 128, 48, seed=bits * 10 + radix)
    x = {"1d": x2[0], "2d": x2,
         "batched": jnp.stack([x2, 2.0 * x2])}[rank]
    lin = pack_linear(w, bits)
    outs = {}
    for backend in BACKENDS:
        plan = EnginePlan(backend=backend, bits=bits, radix=radix)
        y = plan.apply(lin, x, out_dtype=jnp.float32)
        assert y.shape == x.shape[:-1] + (48,)
        outs[backend] = np.asarray(y)
    for backend in BACKENDS[1:]:
        np.testing.assert_allclose(
            outs["reference"], outs[backend], rtol=1e-5, atol=1e-4,
            err_msg=f"{backend} != reference (bits={bits} radix={radix} "
                    f"rank={rank})")


def test_backends_registered():
    for b in BACKENDS + ("pallas_tpu",):
        assert b in available_backends()


def test_custom_backend_registration():
    @register_backend("test_double_ref")
    def double(plan, lin, x, out_dtype):
        from repro.engine.backends import get_backend

        return 2.0 * get_backend("reference")(plan, lin, x, out_dtype)

    w, x = _data(2, 64, 16)
    lin = pack_linear(w, 8)
    y_ref = EnginePlan(backend="reference", bits=8).apply(lin, x)
    y2 = EnginePlan(backend="test_double_ref", bits=8).apply(lin, x)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y_ref),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# PackedLinear pytree semantics
# ---------------------------------------------------------------------------


def test_packed_linear_is_pytree():
    w, x = _data(2, 64, 32)
    lin = pack_linear(w, 4)
    # leaves are packed+scale only; static metadata survives tree ops
    leaves = jax.tree.leaves(lin)
    assert len(leaves) == 2
    mapped = jax.tree.map(lambda a: a, lin)
    assert isinstance(mapped, PackedLinear)
    assert mapped.bits == 4 and mapped.in_features == 64

    # works as a jit argument and under eval_shape
    y = jax.jit(lambda l, v: plan_for_bits(l.bits).apply(l, v))(lin, x)
    assert y.shape == (2, 32)
    abstract = jax.eval_shape(lambda l: l, lin)
    assert abstract.bits == 4


def test_packed_linear_scan_over_stacked_layers():
    rng = np.random.default_rng(3)
    ws = jnp.asarray(rng.standard_normal((5, 32, 16)).astype(np.float32))
    lin = pack_linear(ws, 8)  # stacked (L, K, N)
    assert lin.packed.shape == (5, 32, 16)
    x = jnp.asarray(rng.standard_normal((2, 32)).astype(np.float32))
    plan = plan_for_bits(8)

    def body(carry, layer):
        return carry + plan.apply(layer, x).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros(()), lin)
    expect = sum(
        float(plan.apply(jax.tree.map(lambda a: a[i], lin), x).sum())
        for i in range(5))
    np.testing.assert_allclose(float(total), expect, rtol=1e-5)


def test_bits_validated_at_pack_time():
    w, _ = _data(1, 64, 8)
    for bad in (0, 1, 3, 16, None):
        with pytest.raises(ValueError):
            pack_linear(w, bad)
    with pytest.raises(ValueError):
        pack_linear(jnp.ones((3, 8)), 4)  # K*bits not a whole byte multiple


def test_legacy_dict_without_bits_requires_hint():
    w, _ = _data(1, 64, 8)
    lin = pack_linear(w, 4)
    legacy = {"packed": lin.packed, "scale": lin.scale}  # no "bits"
    with pytest.raises(ValueError):
        as_packed(legacy)  # no silent default-to-8
    ok = as_packed(legacy, bits_hint=4)
    assert ok.bits == 4
    np.testing.assert_array_equal(np.asarray(ok.packed),
                                  np.asarray(lin.packed))


def test_dequantize_roundtrip_error_bounded():
    w, _ = _data(1, 128, 32, seed=9)
    for bits in (2, 4, 8):
        lin = pack_linear(w, bits)
        err = float(jnp.max(jnp.abs(lin.dequantize() - w)))
        step = float(jnp.max(jnp.abs(w))) / (2 ** (bits - 1) - 1)
        assert err <= step, (bits, err, step)


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------


def test_resolve_plan_none_and_disabled():
    assert resolve_plan(None) is None
    assert resolve_plan(EngineConfig()) is None  # weight_bits=0 disables


def test_resolve_plan_memoized():
    cfg = EngineConfig(weight_bits=8, radix=2, backend="reference")
    p1, p2 = resolve_plan(cfg), resolve_plan(EngineConfig(
        weight_bits=8, radix=2, backend="reference"))
    assert p1 is p2  # "resolved once" is literal
    assert p1.backend == "reference" and p1.bits == 8 and p1.radix == 2
    assert as_plan(p1) is p1  # plans pass through untouched


def test_engine_config_has_no_use_pallas():
    """The deprecated ``EngineConfig.use_pallas`` knob is gone (removed at
    the scheduled re-anchor): passing it is a ``TypeError``, and dispatch
    is named solely by ``backend``.  (The ``gemv(use_pallas=)`` *call*
    shim in ``core.gemv_engine`` is a separate surface and remains.)"""
    with pytest.raises(TypeError):
        EngineConfig(weight_bits=4, use_pallas=False)
    assert not hasattr(EngineConfig(), "use_pallas")


def test_resolve_plan_auto_off_tpu():
    plan = resolve_plan(EngineConfig(weight_bits=8))
    if jax.default_backend() != "tpu":
        assert plan.backend == "reference"
    else:
        assert plan.backend == "pallas_tpu"


def test_plan_rejects_bad_config():
    with pytest.raises(KeyError):
        EnginePlan(backend="no_such_backend", bits=8)
    with pytest.raises(ValueError):
        EnginePlan(backend="reference", bits=8, radix=3)
    with pytest.raises(ValueError):
        EnginePlan(backend="reference", bits=2, radix=4)  # radix > bits
    with pytest.raises(ValueError):
        dataclasses.replace(EnginePlan(backend="reference", bits=8), bits=5)


def test_plan_carries_tile_sizes_from_config():
    plan = resolve_plan(EngineConfig(weight_bits=8, tile_m=128, tile_k=256,
                                     backend="reference"))
    assert plan.block_n == 128 and plan.block_k == 256


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_gemv_shim_matches_plan():
    w, x = _data(4, 256, 64, seed=5)
    ql = quantize_linear(w, 8)
    assert isinstance(ql, QuantizedLinear)
    lin = as_packed(ql)
    plan_ref = EnginePlan(backend="reference", bits=8)
    plan_pal = EnginePlan(backend="pallas_interpret", bits=8, radix=2)

    y_old = gemv(ql, x)                                    # old jnp path
    y_new = plan_ref.apply(lin, x, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    y_old_p = gemv(ql, x, use_pallas=True, interpret=True, radix=2)
    y_new_p = plan_pal.apply(lin, x, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_old_p), np.asarray(y_new_p))


def test_engine_dense_shim():
    w, x = _data(2, 128, 32, seed=6)
    # engine off: plain matmul
    y0 = engine_dense(w, x)
    np.testing.assert_allclose(
        np.asarray(y0), np.asarray(x @ w), rtol=1e-6)
    # engine on: identical to the plan path
    ql = quantize_linear(w, 4)
    y1 = engine_dense(ql, x, engine_bits=4)
    y2 = EnginePlan(backend="reference", bits=4).apply(ql, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_oracles_still_agree():
    """The named oracles kernel tests import keep their exact semantics."""
    w, x = _data(3, 64, 24, seed=7)
    for bits in (2, 4, 8):
        ql = quantize_linear(w, bits)
        y_ref = gemv_reference(ql, x)
        y_bs = gemv_bit_serial_reference(ql, x, radix=1)
        y_plan = EnginePlan(backend="bit_serial", bits=bits).apply(
            ql, x, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_bs),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_plan),
                                   rtol=1e-5, atol=1e-4)


def test_model_layers_dense_accepts_all_containers():
    """models.layers.dense: plan threading + every weight container."""
    from repro.models.layers import dense, engine_apply

    w, x = _data(2, 64, 16, seed=8)
    bias = jnp.asarray(np.linspace(-1, 1, 16, dtype=np.float32))
    plan = EnginePlan(backend="reference", bits=8)
    lin = pack_linear(w, 8, bias=bias)

    y_new = dense(lin, x, plan)
    y_cfg = dense(lin, x, EngineConfig(weight_bits=8, backend="reference"))
    y_dict = dense({"packed": lin.packed, "scale": lin.scale, "bits": 8,
                    "bias": bias}, x, plan)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_cfg))
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_dict))
    # engine_apply shim without a config dispatches at the weight's own
    # bits (bias included by the plan — no silent bits=8-with-no-bias path)
    y_shim = engine_apply(lin, x, None)
    np.testing.assert_allclose(np.asarray(y_shim), np.asarray(y_new),
                               rtol=1e-6, atol=1e-6)


def test_quantize_params_emits_packed_linear():
    from conftest import reduced_f32
    from repro.models import init_params, quantize_params

    cfg = reduced_f32("mistral-large-123b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    q = quantize_params(params, cfg, 4)
    attn = q["layers"]["attn"]
    assert isinstance(attn["wq"], PackedLinear)
    assert attn["wq"].bits == 4
    assert isinstance(q["lm_head"], PackedLinear)
    # norms / embeddings stay dense
    assert not isinstance(q["embed"], PackedLinear)
    assert not isinstance(q["final_norm"], PackedLinear)
