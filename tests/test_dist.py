"""Distribution layer: sharding rules, mesh construction, multi-device
numerics.  Multi-device tests run in a subprocess with 8 forced host
devices so this process's single-device view is untouched."""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config.base import MeshConfig
from repro.dist import make_mesh, use_mesh
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    param_spec,
    param_shardings,
    pool_pages_for_mesh,
)
from repro.launch.steps import abstract_params

from conftest import reduced_f32


def _run_sub(code: str):
    pre = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        sys.path.insert(0, "tests")
        import jax, jax.numpy as jnp
        import numpy as np
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", pre + textwrap.dedent(code)],
                         capture_output=True, text=True, cwd=repo,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestMeshConfig:
    def test_shapes(self):
        assert MeshConfig(multi_pod=False).shape == (16, 16)
        assert MeshConfig(multi_pod=True).shape == (2, 16, 16)
        assert MeshConfig(multi_pod=True).n_devices == 512
        assert MeshConfig(multi_pod=True).data_axes == ("pod", "data")


class TestParamSpecs:
    def _specs(self, arch):
        cfg = reduced_f32(arch)
        ap = abstract_params(cfg)

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        return jax.tree_util.tree_map_with_path(
            lambda p, l: param_spec(p, l, FakeMesh()), ap), cfg

    def test_dense_tp_rules(self):
        specs, cfg = self._specs("mistral-large-123b")
        # embed vocab-sharded (32768 % 16 == 0)
        assert specs["embed"] == P("model", None)
        attn = specs["layers"]["attn"]
        assert attn["wq"]["w"] == P(None, None, "model")
        assert attn["wo"]["w"] == P(None, "model", None)
        mlp = specs["layers"]["mlp"]
        assert mlp["w_gate"]["w"] == P(None, None, "model")
        assert mlp["w_down"]["w"] == P(None, "model", None)
        assert specs["final_norm"] == P(None)
        assert specs["lm_head"]["w"] == P(None, "model")

    def test_moe_expert_parallel(self):
        specs, cfg = self._specs("qwen3-moe-235b-a22b")
        moe = specs["layers"]["moe"]
        # stacked (L, E, D, F): experts axis gets the model axis when E%16==0
        e = cfg.n_experts
        expect = "model" if e % 16 == 0 else None
        assert moe["w_gate"] == P(None, expect and "model", None, None) or \
            moe["w_gate"][1] in ("model", None)

    def test_non_divisible_falls_back_to_replication(self):
        specs, cfg = self._specs("mamba2-130m")
        # in_proj width (2*di+2*st+nh) is not divisible by 16 -> replicated
        ssm = specs["layers"]["ssm"]
        assert ssm["in_proj"]["w"][-1] is None
        # out_proj (di=128 divisible? reduced: di=128 -> 128%16==0 -> sharded)
        assert ssm["out_proj"]["w"][-2] in ("model", None)

    def test_batch_sharded_on_data_axes(self):
        cfg = reduced_f32("qwen2.5-3b")
        mesh = make_mesh((1, 1), ("data", "model"))
        ab = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        sh = batch_shardings(mesh, ab)
        assert sh["tokens"].spec == P(("data",), None)


class TestKVPagesSpecs:
    """The paged-serving pytree through the KV-cache rules: pools shard
    pages-over-data and heads-over-model, scale pools follow their K/V
    pool's head sharding, block tables / pos / active shard the lane axis
    over data only (satellite of the mesh-native refactor)."""

    def _mesh(self):
        return make_mesh((1, 1), ("data", "model"))

    def test_pool_and_scale_specs(self):
        from repro.serve.pages import init_kv_pages

        cfg = reduced_f32("qwen2.5-3b")
        pages = jax.eval_shape(
            lambda: init_kv_pages(cfg, 8, 4, kv_bits=8))
        sh = cache_shardings(self._mesh(), pages)
        # (L, P, page, Hkv, Dh): pages over data, KV heads over model
        assert sh.k.spec == P(None, ("data",), None, "model", None)
        assert sh.v.spec == sh.k.spec
        # (L, P, page, Hkv): the scale pool's trailing head axis must
        # match its K/V pool (an unsharded scale would desync dequant)
        assert sh.k_scale.spec == P(None, ("data",), None, "model")
        assert sh.v_scale.spec == sh.k_scale.spec

    def test_page_state_specs(self):
        state = {
            "block_tables": jax.ShapeDtypeStruct((4, 8), jnp.int32),
            "pos": jax.ShapeDtypeStruct((4,), jnp.int32),
            "active": jax.ShapeDtypeStruct((4,), jnp.bool_),
        }
        sh = cache_shardings(self._mesh(), state)
        assert sh["block_tables"].spec == P(("data",), None)
        assert sh["pos"].spec == P(("data",))
        assert sh["active"].spec == P(("data",))

    def test_pool_padding_for_mesh(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        assert pool_pages_for_mesh(9, mesh) == 9  # data product 1: no pad
        assert pool_pages_for_mesh(9, None) == 9

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}

        assert pool_pages_for_mesh(9, FakeMesh()) == 12
        assert pool_pages_for_mesh(12, FakeMesh()) == 12


class TestMultiDevice:
    def test_sharded_train_step_matches_single_device(self):
        """(2,4) mesh train step == single-device train step numerics."""
        _run_sub("""
        from conftest import reduced_f32, make_batch
        from repro.models import init_params
        from repro.config.base import TrainConfig
        from repro.train.trainer import make_train_step
        from repro.optim import make_optimizer
        from repro.launch.steps import _attach
        from repro.dist import make_mesh, use_mesh
        from repro.dist.sharding import param_shardings, batch_shardings, opt_state_shardings
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = reduced_f32("qwen2.5-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1), batch=8, seq=16)
        tcfg = TrainConfig()
        step = make_train_step(cfg, tcfg, donate=False)
        init_fn, _ = make_optimizer("adamw")
        opt = init_fn(params)

        # single device
        p1, o1, _, m1 = step(params, opt, {}, batch)

        # sharded
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            ps = param_shardings(mesh, params)
            params_s = jax.device_put(params, ps)
            opt_s = jax.device_put(opt, opt_state_shardings(mesh, opt))
            batch_s = jax.device_put(batch, batch_shardings(mesh, batch))
            p2, o2, _, m2 = step(params_s, opt_s, {}, batch_s)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / abs(l1) < 1e-4, (l1, l2)
        import numpy as np
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)), rtol=2e-3, atol=2e-3)
        print("sharded == single-device OK", l1, l2)
        """)

    def test_compressed_psum_matches_plain(self):
        _run_sub("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum_leaf
        from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        def plain(x):
            return jax.lax.psum(x, "pod")

        @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        def comp(x):
            return compressed_psum_leaf(x, "pod", bits=8)

        a, b = plain(g), comp(g)
        scale = float(jnp.max(jnp.abs(a)))
        err = float(jnp.max(jnp.abs(a - b))) / scale
        assert err < 0.05, err
        print("compressed psum rel err", err)
        """)

    def test_compressed_psum_bits4_exactness_bound(self):
        """bits=4: pin the docstring's bound — every participant rounds by
        at most scale/2 with the shared scale = pmax(absmax)/qmax, qmax =
        2^(4-1)-1 = 7, so |comp - plain| <= n_dev * scale / 2.  And on an
        integer grid whose absmax is exactly qmax the scale is 1.0 and the
        4-bit wire is lossless."""
        _run_sub("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum_leaf
        from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("pod",))

        def pair(x, bits):
            @partial(shard_map, mesh=mesh, in_specs=P("pod"),
                     out_specs=P("pod"))
            def plain(v):
                return jax.lax.psum(v, "pod")

            @partial(shard_map, mesh=mesh, in_specs=P("pod"),
                     out_specs=P("pod"))
            def comp(v):
                return compressed_psum_leaf(v, "pod", bits=bits)

            return plain(x), comp(x)

        g = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        a, b = pair(g, 4)
        absmax = float(jnp.max(jnp.abs(g)))      # pmax of shard maxes
        bound = 8 * (absmax / 7.0) / 2.0
        err = float(jnp.max(jnp.abs(a - b)))
        assert err <= bound * 1.0001, (err, bound)
        print("bits=4 err", err, "<= bound", bound)

        gi = np.random.default_rng(0).integers(-7, 8, (8, 32))
        gi = jnp.asarray(gi.astype(np.float32)).at[0, 0].set(7.0)
        a2, b2 = pair(gi, 4)
        np.testing.assert_array_equal(np.asarray(a2), np.asarray(b2))
        print("bits=4 integer grid lossless")
        """)

    def test_serve_step_sharded_decode(self):
        """Decode with sequence-sharded 'model' axis matches single-dev."""
        _run_sub("""
        from conftest import reduced_f32
        from repro.models import init_params, init_cache, decode_step
        from repro.dist import make_mesh, use_mesh
        from repro.dist.sharding import param_shardings, cache_shardings
        cfg = reduced_f32("gemma3-27b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        cache = init_cache(cfg, 2, max_len=16)
        tok = jnp.ones((2, 1), jnp.int32)
        l1, c1 = decode_step(params, cache, tok, cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            ps = jax.device_put(params, param_shardings(mesh, params))
            cs = jax.device_put(cache, cache_shardings(mesh, cache))
            l2, c2 = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))(ps, cs, tok)
        import numpy as np
        np.testing.assert_allclose(np.asarray(l1), np.asarray(jax.device_get(l2)), rtol=2e-4, atol=2e-4)
        print("sharded decode OK")
        """)
