"""Property tests: quantization + bit-plane packing invariants."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bitplane import (
    from_bitplanes,
    pack_weights,
    to_bitplanes,
    unpack_weights,
)
from repro.core.quantize import dequantize, quantize_symmetric


@given(
    bits=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 16),
    n=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_pack_unpack_roundtrip(bits, k, n, seed):
    per_byte = 8 // bits
    k = k * per_byte  # packing axis must divide
    rng = np.random.default_rng(seed)
    qmax = 2 ** (bits - 1) - 1
    q = rng.integers(-qmax, qmax + 1, size=(k, n)).astype(np.int8)
    packed = pack_weights(jnp.asarray(q), bits, axis=0)
    assert packed.shape == (k // per_byte, n)
    back = unpack_weights(packed, bits, axis=0)
    np.testing.assert_array_equal(np.asarray(back), q)


@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_bitplane_reassembly(bits, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1)
    q = rng.integers(lo, hi, size=(5, 7))
    planes = to_bitplanes(q, bits)
    assert planes.shape == (bits, 5, 7)
    assert set(np.unique(planes)) <= {0, 1}
    np.testing.assert_array_equal(from_bitplanes(planes, bits), q)


@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    scale_pow=st.integers(-3, 3),
)
@settings(max_examples=40, deadline=None)
def test_quantize_error_bound(bits, seed, scale_pow):
    """|w - deq(q)| <= scale/2 elementwise (symmetric round-to-nearest)."""
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((32, 8)) * 10.0 ** scale_pow).astype(np.float32)
    q, scale = quantize_symmetric(jnp.asarray(w), bits, axis=0)
    deq = np.asarray(dequantize(q, scale))
    err = np.abs(w - deq)
    bound = np.broadcast_to(np.asarray(scale) / 2, w.shape) + 1e-7
    assert np.all(err <= bound)


def test_quantize_preserves_sign_and_zero():
    w = jnp.asarray([[0.0, -1.0, 1.0, -0.5]]).T
    q, scale = quantize_symmetric(w, 8, axis=0)
    q = np.asarray(q)
    assert q[0, 0] == 0
    assert q[1, 0] < 0 and q[2, 0] > 0
    assert q[1, 0] == -q[2, 0]


def test_quantize_zero_matrix():
    q, scale = quantize_symmetric(jnp.zeros((4, 4)), 8, axis=0)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(scale)))
