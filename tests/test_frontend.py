"""Async streaming front-end (``repro.serve.frontend``).

Pins the tentpole claims: streamed tokens are identical to the
synchronous batch loop's for the same seeds (streaming changes *when*,
never *which*); streams progress through the documented lifecycle
states; cancellation and deadline timeout release pages **and
prefix-cache pins immediately** — mid-chunked-prefill included — with
the allocator invariants intact (the PR 5 pin-before-capacity-check
path assumed admission either completed or was refused); and the
bounded admission queue sheds with a reason instead of deadlocking.
"""

import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.models import init_params
from repro.serve import AdmissionRejected, ServeEngine, ServeFrontend
from repro.serve.frontend import (
    CANCELLED,
    DECODING,
    DONE,
    QUEUED,
    SHED,
    TIMED_OUT,
)

from conftest import reduced_f32

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _engine(cfg, params, *, sched="fcfs", n_slots=2, max_len=64,
            max_new=5, prefix_cache=False, **scfg_kw):
    scfg = ServeConfig(max_new_tokens=max_new, sched=sched,
                       prefix_cache=prefix_cache,
                       engine=EngineConfig(backend="reference"), **scfg_kw)
    return ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                       mode="paged", page_size=4, prefill_chunk=3)


def _alloc_clean(eng):
    """Post-drain allocator hygiene: no references, no mapped pages, and
    every page either free or cache-resident."""
    alloc = eng.alloc
    assert alloc.refcount.sum() == 0
    assert (alloc.refcount >= 0).all()
    assert all(not m for m in alloc._mapped)
    cached = eng.prefix_cache.cached_pages if eng.prefix_cache else 0
    assert alloc.free_pages == alloc.n_pages - 1 - cached
    if eng.prefix_cache is not None:
        assert (eng.prefix_cache.evictable_count()
                == eng.prefix_cache._recount_evictable())


# -------------------------------------------------------------- identity
@pytest.mark.parametrize("sched", ["fcfs", "budget"])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_streamed_tokens_identical_to_batch(rng, sched, prefix_cache):
    """Token-identity gate: iterating streams (which interleaves engine
    steps with consumption) yields exactly the synchronous ``run()``
    output, under both schedulers, with and without the prefix cache."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = PROMPTS + [list(PROMPTS[0]), [2, 2, 2, 2, 2]]

    ref_eng = _engine(cfg, params, prefix_cache=prefix_cache)
    refs = [ref_eng.submit(list(p)) for p in prompts]
    ref_eng.run()

    eng = _engine(cfg, params, sched=sched, prefix_cache=prefix_cache)
    fe = ServeFrontend(eng)
    streams = [fe.submit(list(p)) for p in prompts]
    # consume streams round-robin, one token at a time — the adversarial
    # interleaving for a "streaming changed the tokens" bug
    iters = [iter(s) for s in streams]
    collected = [[] for _ in streams]
    pending = set(range(len(streams)))
    while pending:
        for i in sorted(pending):
            try:
                collected[i].append(next(iters[i]))
            except StopIteration:
                pending.discard(i)
    for i, (ref, got) in enumerate(zip(refs, collected)):
        assert ref.output == got, (sched, prefix_cache, i)
        assert streams[i].state == DONE
    _alloc_clean(eng)


# ------------------------------------------------------------- lifecycle
def test_stream_states_and_incremental_delivery(rng):
    """States walk queued -> prefilling -> decoding -> done, and tokens
    arrive incrementally (first token observable while the request is
    still decoding), with a single lane forcing real queueing."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, n_slots=1, max_new=6)
    fe = ServeFrontend(eng)
    first = fe.submit(list(range(1, 9)), max_new_tokens=6)
    second = fe.submit([50, 51], max_new_tokens=2)
    assert first.state == QUEUED and second.state == QUEUED

    seen_states = set()
    token_observations = []
    while not first.finished:
        fe.step()
        seen_states.add(first.state)
        token_observations.append(len(first.tokens))
        if first.state == DECODING:
            assert second.state == QUEUED  # single lane: second waits
    assert seen_states >= {DECODING, DONE}
    # incremental: tokens were visible before the stream finished
    assert any(0 < n < 6 for n in token_observations), token_observations
    assert first.tokens == first.req.output and len(first.tokens) == 6
    assert first.ttft() is not None and first.ttft() >= 0

    fe.drain()
    assert second.state == DONE and len(second.tokens) == 2
    _alloc_clean(eng)


def test_shed_when_queue_full(rng):
    """Bounded admission queue: overflow submissions come back as
    terminal ``shed`` streams with a reason; admitted work completes
    untouched; a shed stream iterates as empty."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, n_slots=1, max_new=2, max_queue=2)
    fe = ServeFrontend(eng)
    streams = [fe.submit([10 + i], max_new_tokens=2) for i in range(6)]
    shed = [s for s in streams if s.state == SHED]
    live = [s for s in streams if s.state != SHED]
    # admission happens inside step(), so submits only queue: 2 fit the
    # bounded queue, the other 4 shed at the door
    assert len(shed) == 4 and fe.shed_count == 4
    assert all(s.shed_reason == "queue_full" for s in shed)
    assert all(list(s) == [] for s in shed)  # iterates empty, no hang
    fe.drain()
    assert all(s.state == DONE and len(s.tokens) == 2 for s in live)
    assert eng.shed_count == 4
    _alloc_clean(eng)


def test_pool_too_small_is_shed_not_deadlock(rng):
    """A prompt that can *never* be granted must shed at the door, not
    sit in the queue deadlocking everything behind eviction+preemption.
    The allocator constructor refuses genuinely undersized pools, so the
    guard is defense-in-depth — simulate a shrunken pool to pin it."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, n_slots=2, max_len=96, max_new=2)
    eng.alloc.n_pages = 9   # pretend only 8 usable pages exist
    fe = ServeFrontend(eng)
    s = fe.submit(list(range(60)))          # needs 16 pages: hopeless
    assert s.state == SHED and s.shed_reason == "pool_too_small"
    with pytest.raises(AdmissionRejected, match="pool_too_small"):
        eng.submit(list(range(60)))
    ok = fe.submit([1, 2, 3])               # 1 page: fine
    fe.drain()
    assert ok.state == DONE


# ----------------------------------------------------------- cancellation
def test_cancel_mid_prefill_releases_pages_and_pins(rng):
    """THE satellite regression: a request cancelled mid-chunked-prefill
    — after admission pinned shared prefix pages (refcount++), allocated
    private pages, and queued a COW fork — must release everything
    immediately: refcounts return to cache-only residency, the pending
    fork is dropped before its dst page is reused, and the remaining
    traffic's greedy output is unchanged."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)

    warm = list(range(1, 13))                  # 3 full pages
    forker = warm[:10] + [99, 100]             # 2 full + mid-page fork
    bystander = [7, 8, 9]

    ref_eng = _engine(cfg, params, prefix_cache=True, n_slots=2)
    ref_eng.submit(list(warm))
    ref_by = ref_eng.submit(list(bystander))
    ref_eng.run()

    eng = _engine(cfg, params, prefix_cache=True, n_slots=2)
    fe = ServeFrontend(eng)
    fe.submit(list(warm)).result()             # populate the cache
    base_ref = eng.alloc.refcount.copy()
    assert eng.prefix_cache.cached_pages == 3

    victim = fe.submit(list(forker), max_new_tokens=8)
    # admit + pin WITHOUT running the engine step: the fork is pending
    # and the prefill has not advanced — the rawest mid-admission state
    eng.sched.admit()
    assert any(f[1] != f[2] for f in eng.sched.pending_forks)
    assert eng.alloc.refcount.sum() > base_ref.sum()  # pins + privates

    assert victim.cancel()
    assert victim.state == CANCELLED
    assert eng.sched.pending_forks == [], "cancel must drop queued forks"
    # pins rolled back: refcounts exactly as before the victim arrived
    np.testing.assert_array_equal(eng.alloc.refcount, base_ref)
    assert (eng.prefix_cache.evictable_count()
            == eng.prefix_cache._recount_evictable())

    # second phase: cancel mid-prefill after a real step, with a
    # bystander in the other lane — its stream must come out untouched
    victim2 = fe.submit(list(forker), max_new_tokens=8)
    by = fe.submit(list(bystander))
    fe.step()
    assert victim2.state in ("prefilling", "decoding")
    assert victim2.cancel()
    fe.drain()
    assert by.state == DONE
    assert by.tokens == ref_by.output, "bystander tokens disturbed"
    # everything drained: refcounts back to cache-only residency
    np.testing.assert_array_equal(eng.alloc.refcount, base_ref)
    _alloc_clean(eng)


def test_cancel_queued_and_decoding(rng):
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params, n_slots=1, max_new=8)
    fe = ServeFrontend(eng)
    running = fe.submit([1, 2, 3], max_new_tokens=8)
    queued = fe.submit([4, 5], max_new_tokens=8)
    for tok in running:
        if len(running.tokens) >= 2:
            break
    assert running.state == DECODING
    assert queued.cancel() and queued.state == CANCELLED
    got = len(running.tokens)
    assert running.cancel()
    assert running.state == CANCELLED
    assert len(running.tokens) == got, "cancel must keep streamed tokens"
    assert not fe.step()                       # nothing live remains
    assert running.req.finish_reason == "cancelled"
    _alloc_clean(eng)
    # double-cancel is a no-op
    assert not running.cancel()


def test_deadline_timeout_releases_and_reports(rng):
    """Deadlines on the injected clock: a request that cannot finish in
    time is cancelled with state ``timed_out``, keeps its partial
    tokens, frees its pages, and later requests proceed normally."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    clock = ManualClock()
    eng = _engine(cfg, params, n_slots=2, max_new=50, max_len=64)
    fe = ServeFrontend(eng, clock=clock)
    doomed = fe.submit([1, 2, 3], max_new_tokens=50, deadline_s=5.0)
    safe = fe.submit([4, 5, 6], max_new_tokens=3)
    for _ in range(4):
        fe.step()
        clock.advance(1.0)
    assert doomed.state in ("prefilling", "decoding")
    partial = len(doomed.tokens)
    clock.advance(10.0)                        # blow the deadline
    fe.step()
    assert doomed.state == TIMED_OUT
    assert doomed.req.finish_reason == "timed_out"
    assert len(doomed.tokens) >= partial
    assert fe.timeout_count == 1
    fe.drain()
    assert safe.state == DONE and len(safe.tokens) == 3
    _alloc_clean(eng)
    # a queued request past its deadline times out without ever running
    lane_hog = fe.submit([1] * 20, max_new_tokens=40)
    lane_hog2 = fe.submit([2] * 20, max_new_tokens=40)
    never = fe.submit([9, 9], deadline_s=0.5)
    clock.advance(1.0)
    fe.step()
    assert never.state == TIMED_OUT and never.tokens == []


# ------------------------------------------------------------ validation
def test_submit_validation_still_raises(rng):
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = _engine(cfg, params)
    fe = ServeFrontend(eng)
    with pytest.raises(ValueError, match="empty prompt"):
        fe.submit([])
    with pytest.raises(ValueError, match="priority"):
        fe.submit([1], priority="urgent")


# ------------------------------------------------- robustness satellites
def test_deadline_sweep_double_cancel_guard(rng):
    """A stream whose request already reached a terminal state (here:
    cancelled out-of-band through the engine) must not be counted as a
    timeout when its deadline later trips — ``engine.cancel`` returns
    False and the sweep respects it, keeping the stream's real state."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    clock = ManualClock()
    eng = _engine(cfg, params, max_new=50)
    fe = ServeFrontend(eng, clock=clock)
    s = fe.submit([1, 2, 3], deadline_s=1.0)
    fe.step()
    eng.cancel(s.req)              # out-of-band hang-up
    clock.advance(5.0)             # deadline now blown as well
    fe.step()
    assert s.state == CANCELLED    # not overwritten to timed_out
    assert s.req.finish_reason == "cancelled"
    assert fe.timeout_count == 0


def test_frontend_shed_and_timeout_counters(rng):
    """shed/timeout land in the obs registry (labelled by reason), not
    just the front-end's local tallies."""
    from repro.obs import Telemetry

    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    tel = Telemetry()
    clock = ManualClock()
    scfg = ServeConfig(max_new_tokens=50, max_queue=2,
                       engine=EngineConfig(backend="reference"))
    eng = ServeEngine(cfg, params, scfg, n_slots=1, max_len=64,
                      mode="paged", page_size=4, prefill_chunk=3,
                      telemetry=tel)
    fe = ServeFrontend(eng, clock=clock)
    keep = [fe.submit([1, 2, 3]), fe.submit([2, 3])]
    doomed = fe.submit([3, 4])     # bounded queue: refused at the door
    assert doomed.state == SHED
    assert fe.shed_count == 1
    assert tel.registry.counter(
        "frontend_shed_total", reason=doomed.shed_reason).value == 1

    clock.advance(0.1)
    fe.step()
    victim = keep[1]
    victim.deadline_s = 0.01       # force the sweep to trip it
    clock.advance(1.0)
    fe.step()
    assert victim.state == TIMED_OUT
    assert fe.timeout_count == 1
    assert tel.registry.counter("frontend_timeouts_total").value == 1
    fe.drain()
