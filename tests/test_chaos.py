"""Fault-tolerant serving: deterministic chaos injection, per-request
failure isolation, and the runtime invariant auditor.

The contract under test: a seeded :class:`~repro.ft.ChaosInjector`
replays *exactly* (same seed -> same fire sequence at every site); a
lane's step fault or non-finite logits quarantines only that request —
within the retry budget the request is requeued recompute-style and its
greedy output is token-identical to a fault-free run — while every
other lane keeps decoding; and ``ServeEngine.audit()`` proves the
allocator / prefix-cache / scheduler bookkeeping after every op, both
on healthy runs (never trips) and against hand-planted corruption
(always trips).
"""

import random

import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.ft import ChaosInjector
from repro.models import init_params
from repro.serve import AuditError, ServeEngine, ServeFrontend

from conftest import reduced_f32

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # the image does not ship hypothesis: seeded replay
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

PROMPTS = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]


def _engine(cfg, params, *, chaos=None, max_new=5, n_slots=2, max_len=32,
            prefix_cache=False, **scfg_kw):
    scfg = ServeConfig(max_new_tokens=max_new,
                       engine=EngineConfig(backend="reference"), **scfg_kw)
    return ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                       mode="paged", page_size=4, prefill_chunk=3,
                       chaos=chaos, prefix_cache=prefix_cache)


def _run(cfg, params, **kw):
    eng = _engine(cfg, params, **kw)
    for p in PROMPTS:
        eng.submit(p)
    done = eng.run()
    return eng, {r.rid: r for r in done}


@pytest.fixture(scope="module")
def model():
    import jax

    cfg = reduced_f32("qwen2.5-3b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def baseline(model):
    cfg, params = model
    _, done = _run(cfg, params, audit=1)
    return {rid: list(r.output) for rid, r in done.items()}


# ------------------------------------------------------------- injector
class TestInjector:
    def test_same_seed_replays_exactly(self):
        a = ChaosInjector(seed=9, rates={"step_fault": 0.3,
                                         "nan_logits": 0.2})
        b = ChaosInjector(seed=9, rates={"step_fault": 0.3,
                                         "nan_logits": 0.2})
        for _ in range(200):
            assert a.fire("step_fault") == b.fire("step_fault")
            assert a.fire("nan_logits") == b.fire("nan_logits")
        assert a.log == b.log
        assert a.pick("step_fault", 7) == b.pick("step_fault", 7)

    def test_sites_are_independent_streams(self):
        """Replay is exact even when *other* sites are consulted a
        different number of times (cross-site call order shifts as the
        engine's schedule shifts)."""
        a = ChaosInjector(seed=9, rates={"step_fault": 0.3})
        b = ChaosInjector(seed=9, rates={"step_fault": 0.3,
                                         "page_grant": 0.5})
        seq_a, seq_b = [], []
        for i in range(100):
            if i % 3 == 0:
                b.fire("page_grant")  # extra consultations on b only
            seq_a.append(a.fire("step_fault"))
            seq_b.append(b.fire("step_fault"))
        assert seq_a == seq_b

    def test_schedule_fires_exact_occurrences(self):
        ch = ChaosInjector(seed=0, schedule={"cancel": {0, 3}})
        fired = [ch.fire("cancel") for _ in range(6)]
        assert fired == [True, False, False, True, False, False]
        assert ch.log == [("cancel", 0), ("cancel", 3)]
        assert ch.fired("cancel") == 2 and ch.fired() == 2

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError):
            ChaosInjector(rates={"not_a_site": 0.5})
        with pytest.raises(ValueError):
            ChaosInjector(schedule={"bogus": {1}})
        ch = ChaosInjector()
        with pytest.raises(ValueError):
            ch.fire("bogus")

    def test_summary_counts_by_site(self):
        ch = ChaosInjector(seed=1, schedule={"step_fault": {0, 1},
                                             "cancel": {0}})
        for _ in range(3):
            ch.fire("step_fault")
            ch.fire("cancel")
        assert ch.summary() == {"step_fault": 2, "cancel": 1}


# ------------------------------------------------- per-request isolation
class TestIsolation:
    def test_page_grant_faults_token_identity(self, model, baseline):
        """Allocator grant failures force rollbacks and re-admission;
        retired outputs stay token-identical to the fault-free run."""
        cfg, params = model
        ch = ChaosInjector(seed=3, rates={"page_grant": 0.3})
        eng, done = _run(cfg, params, chaos=ch, audit=2,
                         max_request_retries=3)
        assert ch.fired("page_grant") > 0
        for rid, r in done.items():
            if r.finish_reason != "error":
                assert list(r.output) == baseline[rid], rid
        eng.audit()

    def test_nan_retry_preserves_tokens(self, model, baseline):
        """One poisoned dispatch, retry budget available: the victim is
        requeued recompute-style and finishes with identical output."""
        cfg, params = model
        ch = ChaosInjector(seed=5, schedule={"nan_logits": {2}})
        eng, done = _run(cfg, params, chaos=ch, audit=1,
                         max_request_retries=2)
        assert ch.fired("nan_logits") == 1
        assert eng.quarantined == 0
        assert {rid: list(r.output) for rid, r in done.items()} == baseline
        assert any(r.retries == 1 for r in done.values())

    def test_nan_quarantine_isolates_one_request(self, model, baseline):
        """Retry budget zero: exactly one request errors (pages
        released, counted), every other lane's output is untouched."""
        cfg, params = model
        ch = ChaosInjector(seed=5, schedule={"nan_logits": {2}})
        eng, done = _run(cfg, params, chaos=ch, audit=1,
                         max_request_retries=0)
        errs = [r for r in done.values() if r.finish_reason == "error"]
        assert len(errs) == 1 and eng.quarantined == 1
        assert errs[0].cancelled and not errs[0].done
        assert len(done) == len(PROMPTS)  # quarantined rid is returned too
        for rid, r in done.items():
            if r.finish_reason != "error":
                assert list(r.output) == baseline[rid], rid
        assert eng.metrics()["quarantined"] == 1
        # quarantine released everything it held
        assert eng.alloc.refcount.sum() == 0
        eng.audit()

    def test_step_faults_and_preempt_storms(self, model, baseline):
        """Simulated device errors on prefill *and* decode dispatches
        plus mass-eviction storms: recompute recovery keeps identity."""
        cfg, params = model
        ch = ChaosInjector(seed=7, rates={"step_fault": 0.15,
                                          "preempt_storm": 0.1})
        eng, done = _run(cfg, params, chaos=ch, audit=2,
                         max_request_retries=5)
        assert ch.fired("step_fault") > 0
        for rid, r in done.items():
            if r.finish_reason != "error":
                assert list(r.output) == baseline[rid], rid

    def test_quarantine_scrubs_poisoned_pages(self, model):
        """NaN written into a faulted lane's KV pages must not outlive
        the fault: attention masks additively (score + -inf), so a NaN
        in the masked tail of a reused page would poison the *next*
        tenant's softmax.  Quarantine zeroes the lane's private pages
        before the free list gets them back."""
        import jax.numpy as jnp

        cfg, params = model
        clean = _engine(cfg, params, n_slots=1)
        clean.submit([5, 6, 7])
        want = list(clean.run()[0].output)

        eng = _engine(cfg, params, n_slots=1, max_request_retries=0)
        victim = eng.submit([1, 2, 3, 4, 5])
        eng.step()  # prefill lands: slot 0 owns real KV pages
        assert eng.alloc._mapped[0]
        idx = jnp.asarray(eng.alloc._mapped[0], jnp.int32)
        eng.pages = eng.pages.replace(
            k=eng.pages.k.at[:, idx].set(jnp.nan),
            v=eng.pages.v.at[:, idx].set(jnp.nan))
        eng._fault(0, victim, "nan_logits")  # budget 0 -> quarantine
        assert victim.finish_reason == "error"
        assert np.isfinite(np.asarray(eng.pages.k)).all()
        assert np.isfinite(np.asarray(eng.pages.v)).all()
        eng.audit()
        # the pool is safe to reuse: same tokens as the clean engine
        after = eng.submit([5, 6, 7])
        eng.run()
        assert list(after.output) == want

    def test_frontend_surfaces_error_state(self, model):
        """A quarantined request's stream terminates in state 'error'
        (not cancelled/timed_out); other streams finish normally."""
        cfg, params = model
        ch = ChaosInjector(seed=5, schedule={"nan_logits": {2}})
        eng = _engine(cfg, params, chaos=ch, max_request_retries=0)
        fe = ServeFrontend(eng)
        streams = [fe.submit(p) for p in PROMPTS]
        fe.drain()
        states = [s.state for s in streams]
        assert states.count("error") == 1, states
        assert all(s in ("done", "error") for s in states)


# --------------------------------------------------------------- auditor
class TestAuditor:
    def test_healthy_run_never_trips(self, model):
        cfg, params = model
        eng, _ = _run(cfg, params, audit=2, prefix_cache=True)
        eng.audit()

    def test_catches_refcount_drift(self, model):
        cfg, params = model
        eng = _engine(cfg, params)
        eng.submit(PROMPTS[0])
        eng.step()
        eng.audit()
        eng.alloc.refcount[eng.alloc._mapped[0][0]] += 1
        with pytest.raises(AuditError, match="refcount"):
            eng.audit()

    def test_catches_block_table_corruption(self, model):
        cfg, params = model
        eng = _engine(cfg, params)
        eng.submit(PROMPTS[0])
        eng.step()
        eng.alloc.block_tables[0, 0] = eng.alloc.free[-1]
        with pytest.raises(AuditError):
            eng.audit()

    def test_catches_leaked_page(self, model):
        cfg, params = model
        eng = _engine(cfg, params)
        eng.submit(PROMPTS[0])
        eng.step()
        eng.alloc.free.pop()  # page now in no free list, no lane, no cache
        with pytest.raises(AuditError, match="leaked"):
            eng.audit()

    def test_catches_double_residency(self, model):
        cfg, params = model
        eng = _engine(cfg, params)
        eng.submit(PROMPTS[0])
        eng.step()
        req = eng.sched.slot_req[0]
        eng.sched.queue.append(req)
        with pytest.raises(AuditError, match="both queued and resident"):
            eng.audit()

    def test_catches_cache_blocked_drift(self, model):
        cfg, params = model
        eng, _ = _run(cfg, params, prefix_cache=True)
        eng.audit()
        eng.prefix_cache._blocked += 1
        with pytest.raises(AuditError):
            eng.audit()

    def test_audit_on_slots_mode_rejected(self, model):
        cfg, params = model
        scfg = ServeConfig(max_new_tokens=2, audit=1,
                           engine=EngineConfig(backend="reference"))
        with pytest.raises(ValueError, match="audit"):
            ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                        mode="slots")


# ------------------------------------------------------------------ soak
class TestSoak:
    """Seeded random-op storm with the auditor after *every* op."""

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_random_ops_hold_invariants(self, model, seed):
        cfg, params = model
        rng = random.Random(seed)
        ch = ChaosInjector(seed=seed,
                           rates={"page_grant": 0.05, "step_fault": 0.05,
                                  "nan_logits": 0.05,
                                  "preempt_storm": 0.02})
        eng = _engine(cfg, params, chaos=ch, prefix_cache=True,
                      max_new=4, max_request_retries=1)
        live = []
        for _ in range(30):
            op = rng.random()
            if op < 0.4:
                n = rng.randint(1, 6)
                live.append(eng.submit(
                    [rng.randint(1, cfg.vocab_size - 1)
                     for _ in range(n)]))
            elif op < 0.5 and live:
                eng.cancel(live.pop(rng.randrange(len(live))))
            elif eng.has_work():
                eng.step()
            eng.audit()
        while eng.has_work():
            eng.step()
            eng.audit()
