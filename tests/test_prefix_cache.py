"""Prefix-cache subsystem: radix-tree KV reuse with ref-counted
copy-on-write pages (``repro.serve.prefix_cache``).

The pinning claim: **greedy decode with prefix-cache hits is
token-identical to cold-path decode** — across full-page and mid-page
(COW-fork) split points, ``kv_bits`` 0 and 8, unsharded and an
8-host-device ``(data, model)`` mesh, after eviction, and under
preemption.  A hit only substitutes resident KV bytes for recomputed
ones; it must never change a token.

Plus the allocator-invariant property tests (``test_sharding_props``
style): refcounts never negative, the null page is never allocated /
freed / shared / evicted, alloc-free-alloc reuses pages, and eviction
only ever touches refcount-0 cached pages.
"""

import os
import subprocess
import sys
import textwrap

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import EngineConfig, ServeConfig
from repro.models import init_params
from repro.serve import (
    PageAllocator,
    PrefixCache,
    ServeEngine,
    fork_tail_page,
    init_kv_pages,
)
from repro.serve.pages import NULL_PAGE

from conftest import reduced_f32

PS = 4  # page size for every engine test in this file

# prompt geometry (page_size=4): A's pages cover [1..4][5..8][9..12];
# B diverges mid-page inside A's third page (tokens 9, 10 then 60, 61
# — kept inside every arch's reduced vocab: musicgen's is only 64),
# C repeats A exactly (the cap leaves 1 suffix token -> partial match of
# the last page), D shares nothing.
A = list(range(1, 13))
B = list(range(1, 11)) + [60, 61]
C = list(A)
D = [71, 72, 73, 74, 75, 76, 77, 78, 79]


def _gen(cfg, params, prompts, *, prefix_cache, n_slots=1, max_len=32,
         max_new=5, n_pages=None, kv_bits=0, prefill_chunk=3):
    scfg = ServeConfig(
        max_new_tokens=max_new,
        engine=EngineConfig(kv_bits=kv_bits, backend="reference"))
    eng = ServeEngine(cfg, params, scfg, n_slots=n_slots, max_len=max_len,
                      mode="paged", page_size=PS, n_pages=n_pages,
                      prefill_chunk=prefill_chunk,
                      prefix_cache=prefix_cache)
    for p in prompts:
        eng.submit(list(p))
    return eng, sorted(eng.run(), key=lambda r: r.rid)


def _assert_identical(cold, hot, tag):
    for a, b in zip(cold, hot):
        assert a.output == b.output, (tag, a.rid, a.output, b.output)


# ---------------------------------------------------------------- identity
@pytest.mark.parametrize("kv_bits", [0, 8])
def test_hits_token_identical_full_and_mid_page(rng, kv_bits):
    """Full-page and mid-page (COW) split points, kv_bits 0/8: cache-hit
    greedy decode matches cold decode token for token, and the hit path
    really ran (hits, forks, and fewer prefill tokens computed)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = [A, B, C, D]
    e0, cold = _gen(cfg, params, prompts, prefix_cache=False,
                    kv_bits=kv_bits)
    e1, hot = _gen(cfg, params, prompts, prefix_cache=True,
                   kv_bits=kv_bits)
    _assert_identical(cold, hot, f"kv{kv_bits}")
    st_ = e1.prefix_stats()
    assert st_["hits"] >= 2 and st_["cow_forks"] >= 2, st_
    # B's match ends mid-page (10 tokens: 2 full pages + a 2-token fork);
    # C's match is capped at len-1 = 11 (2 full pages + a 3-token fork)
    assert st_["hit_tokens"] == 10 + 11, st_
    # prefill compute scales with the unique suffix, not the total prompt
    assert e1.prefill_computed == e0.prefill_computed - st_["hit_tokens"]


def test_full_page_split_no_fork(rng):
    """A shared prefix that ends exactly on a page boundary is served from
    full shared pages alone — refcounted, no COW copy."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    base = list(range(1, 9))                     # 8 tokens = 2 full pages
    prompts = [base + [30 + i, 40 + i] for i in range(3)]
    e0, cold = _gen(cfg, params, prompts, prefix_cache=False)
    e1, hot = _gen(cfg, params, prompts, prefix_cache=True)
    _assert_identical(cold, hot, "full-page")
    st_ = e1.prefix_stats()
    assert st_["cow_forks"] == 0, st_
    assert st_["hit_tokens"] == 2 * 8, st_      # two later requests hit


def test_concurrent_lanes_and_chunk_sizes(rng):
    """Hits with several lanes in flight and across chunk geometries keep
    identity (per-request prefill offsets ride the batched chunk path)."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = [A, B, C, D, B, A]
    _, ref = _gen(cfg, params, prompts, prefix_cache=False)
    for n_slots in (2, 3):
        for chunk in (1, 2, 5):
            _, hot = _gen(cfg, params, prompts, prefix_cache=True,
                          n_slots=n_slots, prefill_chunk=chunk)
            _assert_identical(ref, hot, (n_slots, chunk))


def test_identity_after_eviction(rng):
    """A pool too small to keep every prefix resident forces LRU eviction
    of refcount-0 cached pages; evicted prefixes recompute cold and the
    stream stays token-identical."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = [A, B, C, D, B, A]
    e0, cold = _gen(cfg, params, prompts, prefix_cache=False, n_pages=9,
                    max_new=8)
    e1, hot = _gen(cfg, params, prompts, prefix_cache=True, n_pages=9,
                   max_new=8)
    _assert_identical(cold, hot, "eviction")
    assert e1.prefix_cache.evicted_pages > 0
    # drained engine: every surviving page is either free or cached, and
    # no references remain
    assert e1.alloc.used_pages == e1.prefix_cache.cached_pages
    assert e1.alloc.refcount.sum() == 0
    assert (e1.alloc.refcount >= 0).all()


def test_identity_under_preemption(rng):
    """Preemption (recompute-style) composes with the cache: the preempted
    request re-matches whatever prefix is still resident on re-admission
    and the greedy stream is unchanged."""
    cfg = reduced_f32("qwen2.5-3b")
    params = init_params(cfg, rng)
    prompts = [A, B, C, D]
    e0, cold = _gen(cfg, params, prompts, prefix_cache=False, n_slots=3,
                    max_len=48, n_pages=14, max_new=16)
    e1, hot = _gen(cfg, params, prompts, prefix_cache=True, n_slots=3,
                   max_len=48, n_pages=14, max_new=16)
    assert e1.preemptions > 0
    _assert_identical(cold, hot, "preemption")


@pytest.mark.parametrize("arch", ["gemma3-27b", "qwen3-moe-235b-a22b",
                                  "musicgen-medium"])
def test_hits_token_identical_other_families(arch, rng):
    """Sliding-window / moe / audio families through the same tree."""
    cfg = reduced_f32(arch, capacity_factor=8.0)
    params = init_params(cfg, rng)
    prompts = [A, B, C]
    _, cold = _gen(cfg, params, prompts, prefix_cache=False)
    e1, hot = _gen(cfg, params, prompts, prefix_cache=True)
    _assert_identical(cold, hot, arch)
    assert e1.prefix_stats()["hits"] >= 2


def test_prefix_cache_on_mesh_token_identical():
    """8 forced host devices, (data=4, model=2) mesh: prefix-cache hits on
    the sharded pool (pages over data, heads over model; tree/refcounts
    host-side like block tables) match the unsharded cold stream."""
    pre = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src"); sys.path.insert(0, "tests")
        import jax
        from conftest import reduced_f32
        from repro.config.base import EngineConfig, ServeConfig
        from repro.dist import make_mesh
        from repro.models import init_params
        from repro.serve import ServeEngine

        cfg = reduced_f32("qwen2.5-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        A = list(range(1, 13)); B = list(range(1, 11)) + [99, 100]
        prompts = [A, B, list(A), list(B)]

        def gen(mesh=None, prefix_cache=False, kv_bits=0):
            scfg = ServeConfig(max_new_tokens=6, engine=EngineConfig(
                kv_bits=kv_bits, backend="reference"))
            eng = ServeEngine(cfg, params, scfg, n_slots=2, max_len=32,
                              mode="paged", page_size=4, prefill_chunk=3,
                              prefix_cache=prefix_cache, mesh=mesh)
            for p in prompts:
                eng.submit(list(p))
            return eng, sorted(eng.run(), key=lambda r: r.rid)

        mesh = make_mesh((4, 2), ("data", "model"))
        for kv in (0, 8):
            _, cold = gen(kv_bits=kv)
            e, hot = gen(mesh=mesh, prefix_cache=True, kv_bits=kv)
            kspec = e.pages.k.sharding.spec
            assert "data" in str(kspec) and "model" in str(kspec), kspec
            st = e.prefix_stats()
            assert st["hits"] >= 2 and st["cow_forks"] >= 1, st
            for a, b in zip(cold, hot):
                assert a.output == b.output, (kv, a.rid, a.output, b.output)
            print("kv", kv, "mesh hit == unsharded cold:", st)
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", pre], capture_output=True,
                         text=True, cwd=repo, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]


# --------------------------------------------------------- tree mechanics
def test_match_insert_semantics():
    alloc = PageAllocator(n_pages=17, page_size=4, n_slots=2, max_len=32)
    cache = PrefixCache(alloc)
    alloc.attach_cache(cache)
    assert alloc.ensure(0, 12)                    # 3 private pages
    row = alloc.block_row(0)
    toks = list(range(100, 112))                  # 12 tokens = 3 full pages
    assert cache.insert(toks, row) == 3
    assert cache.cached_pages == 3

    # full-page + mid-page match, capped at len-1
    m = cache.match(toks)                         # identical prompt
    assert [int(p) for p in m.full_pages] == [int(row[0]), int(row[1])]
    assert m.partial == (int(row[2]), 3)          # 3 of 4 tail tokens
    assert m.matched_tokens == 11                 # never the full prompt

    m2 = cache.match(toks[:10] + [7, 7])          # diverges mid-page 3
    assert m2.partial == (int(row[2]), 2) and m2.matched_tokens == 10

    m3 = cache.match([1] + toks)                  # different first token
    assert not m3 and m3.matched_tokens == 0

    m4 = cache.match(toks[:4])                    # 4 tokens: cap -> 3 (COW)
    assert m4.full_pages == [] and m4.partial == (int(row[0]), 3)

    # duplicate insert is a no-op; a foreign row with the same tokens
    # keeps the first owner's pages
    assert cache.insert(toks, row) == 0
    assert alloc.ensure(1, 12)
    assert cache.insert(toks, alloc.block_row(1)) == 0
    assert cache.cached_pages == 3


def test_fork_tail_page_copies_all_layers_and_scales():
    cfg = reduced_f32("qwen2.5-3b")
    for kv_bits in (0, 8):
        pages = init_kv_pages(cfg, 5, 4, kv_bits=kv_bits)
        key = jax.random.PRNGKey(1)
        fill = jax.random.normal(key, pages.k[:, 2].shape)
        pages = pages.replace(k=pages.k.at[:, 2].set(
            fill.astype(pages.k.dtype)))
        if kv_bits:
            pages = pages.replace(k_scale=pages.k_scale.at[:, 2].set(0.5))
        forked = fork_tail_page(pages, jnp.int32(2), jnp.int32(4))
        np.testing.assert_array_equal(np.asarray(forked.k[:, 4]),
                                      np.asarray(forked.k[:, 2]))
        np.testing.assert_array_equal(np.asarray(forked.v[:, 4]),
                                      np.asarray(forked.v[:, 2]))
        if kv_bits:
            np.testing.assert_array_equal(
                np.asarray(forked.k_scale[:, 4]),
                np.asarray(forked.k_scale[:, 2]))


def test_prefix_cache_requires_paged_mode():
    cfg = reduced_f32("mamba2-130m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(cfg, params, ServeConfig(max_new_tokens=2),
                    n_slots=1, max_len=16, mode="slots", prefix_cache=True)


# ------------------------------------------------ allocator property tests
#
# A random op-sequence drives one PageAllocator + PrefixCache pair; after
# every op the global invariants must hold.  test_sharding_props style:
# ops never corrupt, they only succeed or refuse.

_OPS = st.lists(
    st.tuples(st.sampled_from(["ensure", "free", "insert", "evict",
                               "share"]),
              st.integers(0, 2),            # slot
              st.integers(1, 24)),          # token count / evict count
    min_size=1, max_size=40)


def _check_invariants(alloc, cache):
    assert (alloc.refcount >= 0).all(), "refcount went negative"
    assert alloc.refcount[NULL_PAGE] == 0
    assert NULL_PAGE not in alloc.free, "null page on the free list"
    assert not cache.holds(NULL_PAGE), "null page cached"
    # a page is free XOR mapped/cached; mapped refcount == #mapping slots
    from collections import Counter
    mapped = Counter(p for slot in alloc._mapped for p in slot)
    for page in range(1, alloc.n_pages):
        assert alloc.refcount[page] == mapped.get(page, 0), page
        if page in alloc.free:
            assert alloc.refcount[page] == 0 and not cache.holds(page)
    # no page mapped twice into one slot, none duplicated on the free list
    assert len(alloc.free) == len(set(alloc.free))
    # the incrementally maintained evictable counter always agrees with a
    # from-scratch recount (the pre-incremental full-tree walk)
    assert cache.evictable_count() == cache._recount_evictable(), \
        "incremental evictable counter drifted from the tree recount"


@settings(max_examples=40)
@given(ops=_OPS)
def test_allocator_invariants_under_random_ops(ops):
    alloc = PageAllocator(n_pages=13, page_size=4, n_slots=3, max_len=24)
    cache = PrefixCache(alloc)
    alloc.attach_cache(cache)
    token_streams = [[100 * (s + 1) + i for i in range(24)]
                     for s in range(3)]
    for op, slot, n in ops:
        if op == "ensure":
            alloc.ensure(slot, min(n, 24))
        elif op == "free":
            alloc.free_slot(slot)
        elif op == "insert":
            toks = token_streams[slot][:min(n, 4 * len(
                alloc._mapped[slot]))]
            cache.insert(toks, alloc.block_row(slot))
        elif op == "evict":
            before = {p: int(alloc.refcount[p]) for p in list(
                cache._by_page)}
            cache.evict(n % 4 + 1)
            # eviction only ever touched refcount-0 pages
            gone = set(before) - set(cache._by_page)
            assert all(before[p] == 0 for p in gone), (gone, before)
        elif op == "share":
            m = cache.match(token_streams[slot])
            if m.full_pages and not alloc._mapped[slot]:
                alloc.map_shared(slot, m.full_pages)
        _check_invariants(alloc, cache)


@settings(max_examples=40)
@given(ops=_OPS)
def test_incremental_evictable_counter_matches_recount(ops):
    """The O(1) evictable counter (blocked-subtree bookkeeping adjusted
    on refcount 0<->1 transitions and insert/evict) equals the full-tree
    recount after arbitrary op interleavings — including partial frees,
    shared re-pins of interior pages, and evictions that expose parents.

    Denser than the generic invariant test: two slots intentionally walk
    the *same* token stream so shared pins exercise the 0<->1 hook on
    interior nodes, not only leaves.
    """
    alloc = PageAllocator(n_pages=13, page_size=4, n_slots=3, max_len=24)
    cache = PrefixCache(alloc)
    shared = [300 + i for i in range(24)]
    streams = [shared, shared, [900 + i for i in range(24)]]
    for op, slot, n in ops:
        if op == "ensure":
            alloc.ensure(slot, min(n, 24))
        elif op == "free":
            alloc.free_slot(slot)
        elif op == "insert":
            toks = streams[slot][:min(n, 4 * len(alloc._mapped[slot]))]
            cache.insert(toks, alloc.block_row(slot))
        elif op == "evict":
            cache.evict(n % 4 + 1)
        elif op == "share":
            m = cache.match(streams[slot])
            if m.full_pages and not alloc._mapped[slot]:
                alloc.map_shared(slot, m.full_pages)
        assert cache.evictable_count() == cache._recount_evictable(), \
            (op, slot, n)
    # drain everything: counter must walk back to the empty-tree fixpoint
    for slot in range(3):
        alloc.free_slot(slot)
    cache.evict(cache.cached_pages)
    assert cache.cached_pages == 0
    assert cache.evictable_count() == cache._recount_evictable() == 0


def test_lru_heap_evicts_least_recently_used_first():
    """The lazy heap preserves the old scan's LRU order: a re-matched
    (touched) chain outlives an untouched one under partial eviction."""
    alloc = PageAllocator(n_pages=17, page_size=4, n_slots=2, max_len=32)
    cache = PrefixCache(alloc)
    old_toks = [100 + i for i in range(8)]   # 2 pages, inserted first
    new_toks = [500 + i for i in range(8)]
    alloc.ensure(0, 9)
    cache.insert(old_toks, alloc.block_row(0))
    alloc.free_slot(0)
    alloc.ensure(1, 9)
    cache.insert(new_toks, alloc.block_row(1))
    alloc.free_slot(1)
    old_pages = [cache.match(old_toks + [1]).full_pages,
                 cache.match(new_toks + [1]).full_pages]
    # touch the *old* chain so it becomes most-recently-used
    cache.match(old_toks + [7])
    assert cache.evict(2) == 2
    # the untouched (new) chain died; the touched one survived
    assert all(cache.holds(int(p)) for p in old_pages[0])
    assert not any(cache.holds(int(p)) for p in old_pages[1])


@settings(max_examples=20)
@given(n_tokens=st.integers(1, 24), waves=st.integers(2, 5))
def test_alloc_free_alloc_reuses_pages(n_tokens, waves):
    """Without a cache holding pages resident, free_slot returns every
    page and the next allocation reuses them — the pool never leaks."""
    alloc = PageAllocator(n_pages=9, page_size=4, n_slots=1, max_len=24)
    seen = set()
    for _ in range(waves):
        assert alloc.ensure(0, n_tokens)
        pages = set(alloc._mapped[0])
        assert NULL_PAGE not in pages
        if seen:
            assert pages == seen, "alloc-free-alloc changed the page set"
        seen = pages
        alloc.free_slot(0)
        assert alloc.free_pages == 8
        assert alloc.refcount.sum() == 0


def test_null_page_never_granted_or_freed():
    alloc = PageAllocator(n_pages=5, page_size=2, n_slots=1, max_len=8)
    assert alloc.ensure(0, 8)
    assert NULL_PAGE not in alloc._mapped[0]
    with pytest.raises(ValueError):
        alloc._release_page(NULL_PAGE)
    with pytest.raises(ValueError):
        alloc.map_shared(0, [NULL_PAGE])


def test_deep_chain_does_not_recurse():
    """A long prompt caches as one deep node chain (one node per page);
    the capacity walk must be iterative — 2000 cached pages used to blow
    Python's recursion limit inside admission."""
    alloc = PageAllocator(n_pages=2102, page_size=4, n_slots=1,
                          max_len=8400)
    cache = PrefixCache(alloc)
    alloc.attach_cache(cache)
    assert alloc.ensure(0, 2000 * 4)
    toks = list(range(2000 * 4))
    assert cache.insert(toks, alloc.block_row(0)) == 2000
    alloc.free_slot(0)
    assert cache.evictable_count() == 2000
    m = cache.match(toks)
    assert len(m.full_pages) == 1999 and m.partial[1] == 3
    assert alloc.can_allocate(2100)
    assert cache.evict(2000) == 2000
    assert alloc.free_pages == 2101


def test_eviction_skips_referenced_and_interior_pages():
    """evict() drains leaf-first and never touches a page with live
    references — a shared prefix pins itself and its ancestors."""
    alloc = PageAllocator(n_pages=17, page_size=4, n_slots=2, max_len=32)
    cache = PrefixCache(alloc)
    alloc.attach_cache(cache)
    assert alloc.ensure(0, 12)
    toks = list(range(200, 212))
    cache.insert(toks, alloc.block_row(0))
    row = alloc.block_row(0)
    alloc.free_slot(0)                  # all 3 cached pages refcount 0
    assert cache.evictable_count() == 3

    # re-share the *first* page only: it is pinned; its descendants are
    # still evictable leaves
    alloc.map_shared(1, [int(row[0])])
    assert cache.evictable_count() == 2
    assert cache.evict(10) == 2
    assert cache.holds(int(row[0]))
    assert alloc.refcount[int(row[0])] == 1
    # unpin: now the last page drains too
    alloc.free_slot(1)
    assert cache.evict(10) == 1
    assert cache.cached_pages == 0
