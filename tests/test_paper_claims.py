"""The paper's headline numbers, asserted out of our analytical models.

Every claim cites its anchor in the paper (section/table/figure)."""

import numpy as np
import pytest

from repro.core.controller import CycleModel
from repro.core.latency_model import (
    CCB_GEMV_PES,
    FIG6_DESIGNS,
    IMAGINE_FSYS_MHZ,
    TABLE_I,
    TABLE_IV,
    TABLE_V,
    TPU_V1_MHZ,
    TPU_V1_PES,
    TPU_V2_PES,
    U55,
    clock_speedup_range,
    execution_time_us,
    peak_tops,
)
from repro.core.tile_array import (
    BRAMS_PER_TILE,
    PES_PER_TILE,
    TileArrayGeometry,
    u55_geometry,
)


class TestClockClaims:
    def test_737mhz_system_clock(self):
        """§V-C: 'The final design met the timing at 737 MHz clock' ==
        the U55 BRAM Fmax."""
        assert IMAGINE_FSYS_MHZ == 737.0
        assert TABLE_V["IMAGine"][4] == 737
        assert TABLE_V["IMAGine"][3] == 100.0  # 100% BRAM utilization

    def test_faster_than_tpu_and_hanguang(self):
        """§V-C: clocks faster than TPU v1-v2 (700 MHz) and Hanguang 800."""
        assert IMAGINE_FSYS_MHZ > TPU_V1_MHZ
        assert IMAGINE_FSYS_MHZ > 700.0

    def test_speedup_range_2_65_to_3_2(self):
        """Abstract/§V-D: '2.65x - 3.2x faster clock'."""
        lo, hi = clock_speedup_range()
        assert abs(lo - 2.65) < 0.02
        assert 3.15 < hi < 3.20

    def test_table1_relative_frequencies(self):
        """Table I: PiCaSO is the only prior design at 100% of BRAM Fmax."""
        for name, (_, _, f_bram, f_pim, _) in TABLE_I.items():
            if name == "PiCaSO":
                assert f_pim == f_bram
            else:
                assert f_pim < f_bram


class TestScaleClaims:
    def test_64k_pes_on_u55(self):
        """§I/Table IV: 64K bit-serial MACs using 100% of U55 BRAMs."""
        assert U55.brams == 2016
        assert U55.max_pes == 64512          # '64K'
        assert abs(U55.max_pes - 65536) / 65536 < 0.02

    def test_pe_count_equals_tpu_v1_and_4x_tpu_v2(self):
        """§V-C: equal PEs to TPU v1 (64K), 4x TPU v2 (16K)."""
        assert abs(U55.max_pes - TPU_V1_PES) / TPU_V1_PES < 0.02
        assert U55.max_pes > 3.9 * TPU_V2_PES

    def test_table4_pe_counts(self):
        """Table IV: Max PE# = 32 x BRAM count for every device."""
        expect = {"U55": 64512, "V7-a": 24000, "US-a": 23040, "US-d": 86016}
        for dev in TABLE_IV:
            assert dev.max_pes == dev.brams * 32
            if dev.short_id in expect:
                assert dev.max_pes == expect[dev.short_id]

    def test_100pct_bram_scaling(self):
        """Fig. 4: IMAGine scales to 100% of BRAMs on all representatives —
        geometry never requires more than the available BRAM."""
        for dev in TABLE_IV:
            g = TileArrayGeometry(dev)
            assert g.n_tiles * BRAMS_PER_TILE <= dev.brams
            assert g.n_pes == g.n_tiles * PES_PER_TILE
            # >= 94% of BRAMs used as PIM (residue < one tile)
            assert g.n_tiles * BRAMS_PER_TILE / dev.brams > 0.94


class TestThroughputClaims:
    def test_0_33_tops_at_8bit(self):
        """§V-C: 'IMAGine can only deliver up to 0.33 TOPS at 8-bit'."""
        tops = peak_tops(p=8)
        assert abs(tops - 0.33) / 0.33 < 0.05, tops

    def test_tpu_v1_92_tops_convention(self):
        """Sanity: the op-counting convention reproduces TPU v1's 92 TOPS."""
        tpu = 2 * TPU_V1_PES * TPU_V1_MHZ * 1e6 / 1e12
        assert abs(tpu - 91.75) < 0.1

    def test_slice4_roughly_halves_mac_latency(self):
        r2 = CycleModel(precision=8, radix_bits=1).mac()
        r4 = CycleModel(precision=8, radix_bits=2).mac()
        assert 0.45 < r4 / r2 < 0.62


class TestFig6Claims:
    DIMS = [64, 128, 256, 512, 1024, 2048]

    def test_bramac_shortest_cycle_latency(self):
        """§V-E: 'BRAMAC has the shortest cycle latency'."""
        for d in self.DIMS:
            bramac = FIG6_DESIGNS["BRAMAC"][0](d, 8)
            for name in ("IMAGine", "CCB", "SPAR-2"):
                assert bramac < FIG6_DESIGNS[name][0](d, 8), (d, name)

    def test_imagine_between_ccb_and_spar2(self):
        """§V-E: IMAGine cycles longer than CCB everywhere; 'significantly
        shorter compared to SPAR-2' — the separation appears at the larger
        dims where SPAR-2's NEWS walk dominates (Fig. 6's visible gap)."""
        for d in self.DIMS:
            im = FIG6_DESIGNS["IMAGine"][0](d, 8)
            assert FIG6_DESIGNS["CCB"][0](d, 8) < im, d
            spar2 = FIG6_DESIGNS["SPAR-2"][0](d, 8)
            if d >= 1024:
                assert im < 0.5 * spar2, d
            else:
                assert im < 1.05 * spar2, d

    def test_spar2_latency_grows_linearly(self):
        """§V-E: SPAR-2 latency 'increasing almost linearly with matrix
        dimension'."""
        l1 = FIG6_DESIGNS["SPAR-2"][0](1024, 8)
        l2 = FIG6_DESIGNS["SPAR-2"][0](2048, 8)
        assert 2.5 < l2 / l1 < 6.0  # superlinear growth vs dim doubling

    def test_imagine_fastest_execution_time(self):
        """§V-E: 'IMAGine outperforms all other GEMV engines in terms of
        overall execution time' — the paper's central result."""
        for d in self.DIMS:
            t_im = execution_time_us("IMAGine", d, 8)
            for name in ("CCB", "CoMeFa", "SPAR-2"):
                assert t_im < execution_time_us(name, d, 8), (d, name)

    def test_slice4_matches_ccb_cycles(self):
        """§V-E: slice4 'can run almost as fast as CCB/CoMeFa-based GEMV
        implementations' in cycle latency."""
        for d in self.DIMS:
            s4 = FIG6_DESIGNS["IMAGine-slice4"][0](d, 8)
            ccb = FIG6_DESIGNS["CCB"][0](d, 8)
            assert s4 < 1.9 * ccb, d

    def test_bramac_no_system_frequency(self):
        """§V-E: BRAMAC's execution time cannot be plotted (no f_sys)."""
        with pytest.raises(ValueError):
            execution_time_us("BRAMAC", 256, 8)


class TestGeometryClaims:
    def test_tile_is_12_bram(self):
        """Table III: one GEMV tile consumes 12 BRAMs (12x2 PIM blocks)."""
        assert BRAMS_PER_TILE == 12
        assert PES_PER_TILE == 384

    def test_u55_gemv_capacity(self):
        g = u55_geometry()
        assert g.n_tiles == 168
        d = g.max_square_gemv(bits=8)
        assert 1000 < d < 4096  # device-resident square GEMV range
        assert g.occupancy(d, d) <= 1.0
