"""Optimizers, schedules, gradient compression, data pipeline."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_shim import given, settings, strategies as st

from repro.config.base import ModelConfig, TrainConfig
from repro.data import DataPipeline
from repro.optim import (
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    compress_decompress,
    cosine_warmup,
    ef_state_init,
    error_feedback_compress,
    make_optimizer,
    sgd_init,
    sgd_update,
)

from conftest import reduced_f32


def _quad_problem(seed=0):
    """min ||w - target||^2 — any sane optimizer converges."""
    k = jax.random.PRNGKey(seed)
    target = jax.random.normal(k, (8, 8))
    params = {"w": jnp.zeros((8, 8))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_converges(name):
    params, loss, target = _quad_problem()
    init_fn, update_fn = make_optimizer(name)
    state = init_fn(params)
    tcfg = TrainConfig(weight_decay=0.0, beta1=0.9 if name != "sgd" else 0.0)
    lr = jnp.asarray(0.1)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = update_fn(g, state, params, tcfg, lr)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 200


def test_adamw_matches_reference_numpy():
    """First two AdamW steps vs a hand-rolled numpy implementation."""
    params = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.25]])}
    tcfg = TrainConfig(weight_decay=0.01, beta1=0.9, beta2=0.95, eps=1e-8)
    state = adamw_init(params)
    lr = jnp.asarray(0.1)
    p1, state = adamw_update(g, state, params, tcfg, lr)

    w = np.array([[1.0, -2.0]])
    gn = np.array([[0.5, 0.25]])
    m = 0.1 * gn
    v = 0.05 * gn * gn
    mh, vh = m / 0.1, v / 0.05
    w1 = w - 0.1 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * w)
    np.testing.assert_allclose(np.asarray(p1["w"]), w1, rtol=1e-5)


def test_adamw_bf16_params_fp32_state():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.inner["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
    p1, s1 = adamw_update(g, state, params, TrainConfig(), jnp.asarray(1e-2))
    assert p1["w"].dtype == jnp.bfloat16


def test_cosine_warmup_schedule():
    lr0 = float(cosine_warmup(0, 1.0, warmup=10, total=100))
    lr_w = float(cosine_warmup(10, 1.0, warmup=10, total=100))
    lr_end = float(cosine_warmup(100, 1.0, warmup=10, total=100))
    assert lr0 < 0.11
    assert abs(lr_w - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-3  # min_frac floor
    # monotone decay after warmup
    lrs = [float(cosine_warmup(s, 1.0, 10, 100)) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compress_decompress_bounded_error(bits, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    out = compress_decompress(g, bits)
    qmax = 2 ** (bits - 1) - 1
    scale = float(jnp.max(jnp.abs(g))) / qmax
    assert float(jnp.max(jnp.abs(out - g))) <= scale / 2 + 1e-6


def test_error_feedback_unbiased_accumulation():
    """sent + ef' == grads + ef (no information lost across steps)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (32,))}
    ef = ef_state_init(g)
    sent, ef2 = error_feedback_compress(g, ef, bits=8)
    np.testing.assert_allclose(
        np.asarray(sent["w"] + ef2["w"]), np.asarray(g["w"]), rtol=1e-5,
        atol=1e-6)


def test_error_feedback_convergence():
    """EF-compressed SGD still converges on the quadratic."""
    params, loss, target = _quad_problem(3)
    state = sgd_init(params)
    ef = ef_state_init(params)
    tcfg = TrainConfig(beta1=0.0, weight_decay=0.0)
    for _ in range(300):
        g = jax.grad(loss)(params)
        g, ef = error_feedback_compress(g, ef, bits=4)
        params, state = sgd_update(g, state, params, tcfg, jnp.asarray(0.05))
    assert float(loss(params)) < 1e-2


class TestDataPipeline:
    def _cfg(self):
        return reduced_f32("qwen2.5-3b")

    def test_determinism_and_restart(self):
        cfg = self._cfg()
        p1 = DataPipeline(cfg, batch=4, seq_len=16, seed=5)
        b0 = p1.batch_at(0)
        b1 = p1.batch_at(1)
        # a fresh pipeline resumed at step 1 yields the identical batch
        p2 = DataPipeline(cfg, batch=4, seq_len=16, seed=5)
        np.testing.assert_array_equal(p2.batch_at(1)["tokens"], b1["tokens"])
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_labels_shifted(self):
        cfg = self._cfg()
        p = DataPipeline(cfg, batch=2, seq_len=8, seed=0)
        b = p.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_disjoint(self):
        cfg = self._cfg()
        batches = [
            DataPipeline(cfg, batch=8, seq_len=16, seed=1,
                         host_id=h, n_hosts=2).batch_at(0)["tokens"]
            for h in (0, 1)
        ]
        assert batches[0].shape == (4, 16)
        assert not np.array_equal(batches[0], batches[1])

    def test_vlm_audio_batches(self):
        vlm = reduced_f32("llava-next-mistral-7b")
        b = DataPipeline(vlm, batch=2, seq_len=8).batch_at(0)
        assert b["img_embeds"].shape == (2, vlm.img_tokens, vlm.d_model)
        audio = reduced_f32("musicgen-medium")
        b = DataPipeline(audio, batch=2, seq_len=8).batch_at(0)
        assert b["tokens"].shape == (2, 8, audio.n_codebooks)

    def test_prefetch_thread(self):
        cfg = self._cfg()
        p = DataPipeline(cfg, batch=2, seq_len=8, prefetch=2)
        p.start_prefetch()
        b = p.get_prefetched()
        assert b["tokens"].shape == (2, 8)
        p.stop()


class TestPrefetchRobustness:
    def _cfg(self):
        return reduced_f32("qwen2.5-3b")

    def test_full_queue_never_drops_a_batch(self):
        """Slow consumer, prefetch=1: the worker hits queue.Full
        constantly.  Every batch must still arrive exactly once, in
        order — the old code regenerated (and so skipped) a batch on
        every Full."""
        import time

        cfg = self._cfg()
        p = DataPipeline(cfg, batch=2, seq_len=8, seed=3, prefetch=1)
        expected = [DataPipeline(cfg, batch=2, seq_len=8,
                                 seed=3).batch_at(i) for i in range(6)]
        p.start_prefetch()
        time.sleep(0.4)  # let the worker slam into Full repeatedly
        try:
            for i in range(6):
                got = p.get_prefetched()
                np.testing.assert_array_equal(
                    got["tokens"], expected[i]["tokens"]), i
                time.sleep(0.05)
        finally:
            p.stop()

    def test_worker_exception_propagates(self):
        """A worker that dies must surface its exception through
        get_prefetched, not present as an eternal queue.Empty."""
        cfg = self._cfg()
        p = DataPipeline(cfg, batch=2, seq_len=8, prefetch=2)
        p.batch_at = lambda step: (_ for _ in ()).throw(
            OSError("disk gone"))
        p.start_prefetch()
        with pytest.raises(RuntimeError, match="prefetch worker") as ei:
            p.get_prefetched(timeout=5.0)
        assert isinstance(ei.value.__cause__, OSError)
        p.stop()
