"""Mamba2 (SSD — state-space duality) block, chunked-scan training path and
O(1)-state decode path.

Follows arXiv:2405.21060: per-head scalar decay A, state size ``ssm_state``,
heads of width ``ssm_head_dim``; the SSD algorithm splits the sequence into
chunks — within-chunk terms computed as masked (attention-like) matmuls,
cross-chunk terms carried by a ``lax.scan`` over per-chunk states.  Decode
is the exact recurrence h' = a·h + dt·x⊗B, y = C·h' + D·x.

The training path memory is O(B · S · (heads·hd + state)) — no S^2 blocks —
so the 500k-token cell is compile-feasible; state is the only cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import EngineConfig, ModelConfig
from repro.models.layers import dense, init_linear, rms_norm_gated


def init_ssm(key, cfg: ModelConfig, dtype):
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, cw = cfg.n_ssm_heads, cfg.conv_width
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * st
    return {
        # order: [z (di), x (di), B (st), C (st), dt (nh)]
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * st + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),      # A = -exp(a_log) = -1
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus ~= 0.12
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": init_linear(ks[2], di, d, dtype),
    }


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di : 2 * di]
    b_in = zxbcdt[..., 2 * di : 2 * di + st]
    c_in = zxbcdt[..., 2 * di + st : 2 * di + 2 * st]
    dt = zxbcdt[..., 2 * di + 2 * st :]
    return z, xs, b_in, c_in, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along S.  u: (B,S,C); w: (cw,C).

    Returns (out, new_state) where state is the last (cw-1) inputs.
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros(u.shape[:1] + (cw - 1,) + u.shape[2:], u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)               # (B, S+cw-1, C)
    out = sum(
        full[:, i : i + u.shape[1]] * w[i][None, None] for i in range(cw)
    ) + b[None, None]
    new_state = full[:, -(cw - 1) :] if cw > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_state


def _scoped(name):
    import functools

    def wrap(fn):
        @functools.wraps(fn)
        def inner(*a, **k):
            with jax.named_scope(name):
                return fn(*a, **k)
        return inner
    return wrap


@_scoped("ssd_chunked")
def ssd_chunked(
    xh: jnp.ndarray,      # (B, S, H, P)  inputs per head
    dt: jnp.ndarray,      # (B, S, H)     softplus'd timestep
    a: jnp.ndarray,       # (H,)          negative decay rate (A = -exp(a_log))
    b_in: jnp.ndarray,    # (B, S, N)     input projection B
    c_in: jnp.ndarray,    # (B, S, N)     output projection C
    chunk: int,
    h0: Optional[jnp.ndarray] = None,     # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked algorithm.  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    bsz, s, nh, p = xh.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # running decay statistics stay f32 (cumsum / exp numerics); the BIG
    # tensors (decay mask, inputs, GB kernel) live in the model dtype with
    # f32 matmul accumulation — the hillclimb-C memory optimization.
    cdt = xh.dtype
    la = dt * a[None, None, :]                      # log decay (B,S,H), <= 0
    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(cdt)

    lac = la.reshape(bsz, nc, chunk, nh)
    cum = jnp.cumsum(lac, axis=2)                   # within-chunk cumulative
    total = cum[:, :, -1]                           # (B,nc,H) chunk log-decay

    xc = xdt.reshape(bsz, nc, chunk, nh, p)
    bc = b_in.reshape(bsz, nc, chunk, n).astype(cdt)
    cc = c_in.reshape(bsz, nc, chunk, n).astype(cdt)

    # ---- intra-chunk (diagonal blocks): attention-like masked matmul -------
    # M[i,j] = C_i·B_j * exp(cum_i - cum_j)  for j <= i.  The (L,L,H) decay
    # tensor is the SSD memory hot-spot, so heads are processed in groups of
    # <= 8 under a scan to bound live memory at O(B·nc·L·L·8).
    gb = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                    preferred_element_type=jnp.float32).astype(cdt)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    hg = min(8, nh)
    assert nh % hg == 0, (nh, hg)
    cum_g = cum.reshape(bsz, nc, chunk, nh // hg, hg).transpose(3, 0, 1, 2, 4)
    xc_g = xc.reshape(bsz, nc, chunk, nh // hg, hg, p).transpose(3, 0, 1, 2, 4, 5)

    def head_group(_, inp):
        cum_i, xc_i = inp                            # (B,nc,L,hg), (B,nc,L,hg,P)
        dec = cum_i[:, :, :, None, :] - cum_i[:, :, None, :, :]
        m = jnp.where(causal[None, None, :, :, None], jnp.exp(dec), 0.0)
        y_g = jnp.einsum("bcij,bcijh,bcjhp->bcihp", gb, m.astype(cdt), xc_i,
                         preferred_element_type=jnp.float32)
        return None, y_g

    _, y_groups = jax.lax.scan(head_group, None, (cum_g, xc_g))
    y_intra = y_groups.transpose(1, 2, 3, 0, 4, 5).reshape(
        bsz, nc, chunk, nh, p
    )

    # ---- chunk states: what each chunk contributes to the carried state ----
    # state_c = sum_j exp(total - cum_j) * B_j ⊗ x_j
    decay_to_end = jnp.exp(total[:, :, None] - cum).astype(cdt)  # (B,nc,L,H)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, decay_to_end, xc,
                             preferred_element_type=jnp.float32)

    # ---- inter-chunk scan over carried state --------------------------------
    h_init = (jnp.zeros((bsz, nh, p, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        ch_state, ch_total = inp                           # (B,H,P,N), (B,H)
        h_out = h                                          # state entering chunk
        h_next = h * jnp.exp(ch_total)[:, :, None, None] + ch_state
        return h_next, h_out

    h_final, h_enter = jax.lax.scan(
        step, h_init,
        (chunk_state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    # ---- inter-chunk contribution to outputs --------------------------------
    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", cc, jnp.exp(cum).astype(cdt),
        h_enter.astype(cdt), preferred_element_type=jnp.float32
    )
    y = (y_intra + y_inter).reshape(bsz, s, nh, p)
    return y.astype(xh.dtype), h_final


def ssm_forward(
    params,
    x: jnp.ndarray,                     # (B, S, D)
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
) -> jnp.ndarray:
    """Training/prefill path (no cache)."""
    y, _, _ = _ssm_run(params, x, cfg, eng, conv_state=None, h0=None)
    return y


def ssm_decode_step(params, x, cfg, conv_state, h,
                    eng: Optional[EngineConfig] = None):
    """x: (B, 1, D).  Exact recurrence; returns (y, conv_state, h)."""
    return _ssm_run(params, x, cfg, eng, conv_state=conv_state, h0=h,
                    decode=True)


@_scoped("_ssm_run")
def _ssm_run(params, x, cfg, eng, conv_state, h0, decode: bool = False):
    bsz, s, _ = x.shape
    nh, p, st = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = dense(params["in_proj"], x, eng)
    z, xs, b_in, c_in, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv_out, new_conv_state = _causal_conv(
        conv_in, params["conv_w"], params["conv_b"], conv_state
    )
    di = cfg.d_inner
    xs = conv_out[..., :di]
    b_in = conv_out[..., di : di + st]
    c_in = conv_out[..., di + st :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])                                     # (H,)
    xh = xs.reshape(bsz, s, nh, p)

    if decode:
        # h' = exp(dt·a)·h + dt·x ⊗ B ;  y = C·h' + D·x
        la = jnp.exp(dt[:, 0] * a[None])                  # (B,H)
        xdt = xh[:, 0] * dt[:, 0, :, None]                # (B,H,P)
        h = (h0.astype(jnp.float32) * la[:, :, None, None]
             + jnp.einsum("bhp,bn->bhpn", xdt, b_in[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0].astype(jnp.float32), h)
        y = y[:, None] + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        h_final = h
    else:
        y, h_final = ssd_chunked(
            xh, dt, a, b_in, c_in, cfg.ssm_chunk, h0
        )
        y = y.astype(jnp.float32) + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)

    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm_gated(y, z, params["norm_scale"], cfg.norm_eps)
    out = dense(params["out_proj"], y, eng)
    return out, new_conv_state, h_final
