"""Attention paths: dense masked, chunked-flash (online softmax, scan over
KV blocks — O(S·block) memory, required for the 32k prefill cells), decode
with KV cache (bf16/f32 or int8 bit-planed), and decode/prefill reads
through a paged-KV block table (the continuous-batching serving layout).

All paths share GQA semantics: Hq query heads grouped over Hkv KV heads.
"""

from __future__ import annotations

from typing import Optional

import functools

import jax
import jax.numpy as jnp

from repro.dist.hints import with_hint

NEG_INF = -1e30
FLASH_THRESHOLD = 4096  # switch to chunked path at/above this many KV tokens
FLASH_BLOCK_Q = 512
FLASH_BLOCK_KV = 1024

# On TPU hardware flip this to route attend_flash through the fused Pallas
# kernel (kernels/flash_attention): scores and softmax stats stay in VMEM,
# collapsing attention HBM traffic to Q/K/V/O.  The CPU dry-run keeps the
# jnp path; the kernel wrapper picks interpret-vs-compiled itself from the
# engine backend registry (repro.engine.default_interpret), so flipping
# this flag is safe on any host.
PALLAS_FLASH = False


def _scoped(name):
    def wrap(fn):
        @functools.wraps(fn)
        def inner(*a, **k):
            with jax.named_scope(name):
                return fn(*a, **k)
        return inner
    return wrap


def _group_query_heads(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B,S,Hq,D) -> (B,S,Hkv,G,D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _mask(q_pos, kv_pos, window):
    """Causal (+ optional sliding window) mask: (…, Sq, Skv) boolean.

    ``window`` may be a python int or a traced scalar (per-layer flag under
    a scan); window <= 0 means full causal attention.
    """
    causal = kv_pos[..., None, :] <= q_pos[..., :, None]
    near = kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.logical_and(causal, jnp.where(window > 0, near, True))


@_scoped("attend_dense")
def attend_dense(
    q: jnp.ndarray,            # (B, Sq, Hq, D)
    k: jnp.ndarray,            # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,        # (B, Sq)
    kv_pos: jnp.ndarray,       # (B, Skv)
    window: int = 0,
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Skv) bool
    softcap: float = 0.0,
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    qg = _group_query_heads(q, n_kv)                       # (B,Sq,Hkv,G,D)
    scale = d ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = _mask(q_pos, kv_pos, window)[:, None, None]     # (B,1,1,Sq,Skv)
    if kv_valid is not None:
        mask = jnp.logical_and(mask, kv_valid[:, None, None, None, :])
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


@_scoped("attend_dense_quant")
def attend_dense_quant(
    q: jnp.ndarray,            # (B, Sq, Hq, D)
    k: jnp.ndarray,            # (B, Skv, Hkv, D) int8
    v: jnp.ndarray,
    k_scale: jnp.ndarray,      # (B, Skv, Hkv)
    v_scale: jnp.ndarray,
    q_pos: jnp.ndarray,        # (B, Sq)
    kv_pos: jnp.ndarray,       # (B, Skv)
    window: int = 0,
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Skv) bool
) -> jnp.ndarray:
    """Dense attention over an int8 KV view without dequantizing it.

    The chunked-prefill int8 path used to materialize the *entire*
    gathered KV view in fp32 (4× the cache bytes per chunk) just to call
    :func:`attend_dense`.  Here the scales fold into the probabilities
    exactly as :func:`attend_decode_quant` does on the decode path —
    ``scores_t = (q·k_t)·s_k[t]``, ``out = Σ_t (p_t·s_v[t])·v_t`` — so
    the contraction reads the int8 view directly (1 byte/element) and the
    fp32 copy never exists.
    """
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    qg = _group_query_heads(q, n_kv).astype(jnp.bfloat16)  # (B,Sq,Hkv,G,D)
    scale = d ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32) * scale
    ks = k_scale.astype(jnp.float32).transpose(0, 2, 1)    # (B,Hkv,Skv)
    scores = scores * ks[:, :, None, None, :]
    mask = _mask(q_pos, kv_pos, window)[:, None, None]     # (B,1,1,Sq,Skv)
    if kv_valid is not None:
        mask = jnp.logical_and(mask, kv_valid[:, None, None, None, :])
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    vs = v_scale.astype(jnp.float32).transpose(0, 2, 1)
    pv = probs * vs[:, :, None, None, :]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pv.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


@_scoped("attend_flash")
def attend_flash(
    q: jnp.ndarray,            # (B, S, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,
    positions: jnp.ndarray,    # (B, S)
    window: int = 0,
    block_q: int = FLASH_BLOCK_Q,
    block_kv: int = FLASH_BLOCK_KV,
) -> jnp.ndarray:
    """Chunked online-softmax causal attention (pure-jnp flash).

    Outer ``lax.scan`` over query blocks, inner ``lax.scan`` over KV blocks,
    running (max, sumexp, out) carry — peak live memory is
    O(B · Hq · block_q · block_kv) instead of O(S^2).  Fully-masked KV
    blocks are still *computed* (static schedule) but contribute zeros; the
    windowed-gather path below avoids that waste for local layers.
    """
    if PALLAS_FLASH and isinstance(window, int):
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, window=window)
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    blk = max(block_q, block_kv)
    if s % blk != 0:
        # pad to a block multiple; padded keys get position +inf (masked by
        # causality for every real query), padded query outputs are sliced.
        pad = blk - s % blk
        out = attend_flash(
            jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(positions, ((0, 0), (0, pad)),
                    constant_values=2**30),
            window, block_q, block_kv)
        return out[:, :s]
    nq, nkv = s // block_q, s // block_kv
    scale = d ** -0.5

    # keep storage dtype; accumulate in f32 inside each block step
    qb = q.reshape(b, nq, block_q, hq, d)
    qpb = positions.reshape(b, nq, block_q)
    kb = k.reshape(b, nkv, block_kv, n_kv, d)
    vb = v.reshape(b, nkv, block_kv, n_kv, d)
    kpb = positions.reshape(b, nkv, block_kv)

    def q_step(_, qi):
        q_blk, q_pos = qi                                  # (B,bq,H,D), (B,bq)
        qg = q_blk.reshape(b, block_q, n_kv, g, d)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk, v_blk, kv_pos = ki
            sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk,
                            preferred_element_type=jnp.float32) * scale
            msk = _mask(q_pos, kv_pos, window)[:, None, None]
            sc = jnp.where(msk, sc, NEG_INF)
            blk_max = jnp.max(sc, axis=-1)                 # (B,Hkv,G,bq)
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(sc - new_m[..., None])
            new_l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            new_acc = acc * corr[..., None] + pv
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((b, n_kv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, block_q, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kpb.transpose(1, 0, 2)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,Hkv,G,bq,D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, block_q, hq, d)
        return None, out

    _, outs = jax.lax.scan(
        q_step, None,
        (qb.transpose(1, 0, 2, 3, 4), qpb.transpose(1, 0, 2)),
    )
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d)
    return out.astype(q.dtype)


@_scoped("attend_local_gather")
def attend_local_gather(
    q: jnp.ndarray,            # (B, S, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,
    positions: jnp.ndarray,    # (B, S)
    window: int,
) -> jnp.ndarray:
    """Sliding-window attention without O(S^2) score blocks.

    Each query block of size W attends to the gathered [start-W, end) KV
    range (2W keys) — total FLOPs O(S · 2W · D) instead of O(S^2 · D).
    This is the beyond-baseline optimization used by the gemma3 hillclimb.
    """
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    w = window
    assert s % w == 0, (s, w)
    nq = s // w
    scale = d ** -0.5

    # pad one window of KV history at the front
    kpad = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    ppad = jnp.pad(positions, ((0, 0), (w, 0)), constant_values=-1)

    qb = q.reshape(b, nq, w, hq, d).astype(jnp.float32)
    qpb = positions.reshape(b, nq, w)
    # window i covers padded range [i*w, i*w + 2w)
    kw = jnp.stack([jax.lax.dynamic_slice_in_dim(kpad, i * w, 2 * w, 1)
                    for i in range(nq)], 1).astype(jnp.float32)
    vw = jnp.stack([jax.lax.dynamic_slice_in_dim(vpad, i * w, 2 * w, 1)
                    for i in range(nq)], 1).astype(jnp.float32)
    pw = jnp.stack([jax.lax.dynamic_slice_in_dim(ppad, i * w, 2 * w, 1)
                    for i in range(nq)], 1)

    qg = qb.reshape(b, nq, w, n_kv, g, d)
    sc = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg, kw) * scale
    msk = _mask(qpb, pw, w)[:, :, None, None]
    msk = jnp.logical_and(msk, (pw >= 0)[:, :, None, None, None, :])
    sc = jnp.where(msk, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, vw)
    return out.reshape(b, s, hq, d).astype(q.dtype)


@_scoped("attend_decode")
def attend_decode(
    q: jnp.ndarray,            # (B, 1, Hq, D)
    k_cache: jnp.ndarray,      # (B, T, Hkv, D)
    v_cache: jnp.ndarray,
    cur_pos: jnp.ndarray,      # (B,) current token position (0-based)
    window: int = 0,
) -> jnp.ndarray:
    """Single-token decode over a (possibly sequence-sharded) KV cache."""
    b, t, n_kv, d = k_cache.shape
    hq = q.shape[2]
    g = hq // n_kv
    scale = d ** -0.5
    # NOTE: the cache stays in its storage dtype — einsum accumulates in
    # f32 via preferred_element_type.  Upcasting the cache would force XLA
    # to materialize a full-cache f32 copy inside the per-layer loop (a 60x
    # HBM-traffic bug caught by the dry-run profiler).
    qg = q.reshape(b, n_kv, g, d).astype(k_cache.dtype)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(t)[None, :]                        # (1,T)
    valid = kv_pos <= cur_pos[:, None]
    near = kv_pos > cur_pos[:, None] - window
    valid = jnp.logical_and(valid, jnp.where(window > 0, near, True))
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


@_scoped("attend_decode_quant")
def attend_decode_quant(
    q: jnp.ndarray,            # (B, 1, Hq, D)
    k_cache: jnp.ndarray,      # (B, T, Hkv, D) int8
    v_cache: jnp.ndarray,
    k_scale: jnp.ndarray,      # (B, T, Hkv)
    v_scale: jnp.ndarray,
    cur_pos: jnp.ndarray,      # (B,)
    window: int = 0,
) -> jnp.ndarray:
    """Decode attention over an int8 cache: scores_t = (q·k_t)·s_k[t];
    output = Σ_t (p_t·s_v[t])·v_t — scales fold into the probabilities so
    the contraction stays int8 (1 byte/element of cache traffic)."""
    b, t, n_kv, dh = k_cache.shape
    hq = q.shape[2]
    g = hq // n_kv
    scale = dh ** -0.5
    qg = q.reshape(b, n_kv, g, dh).astype(jnp.bfloat16)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg,
                    k_cache.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32) * scale
    sc = sc * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    kv_pos = jnp.arange(t)[None, :]
    valid = kv_pos <= cur_pos[:, None]
    near = kv_pos > cur_pos[:, None] - window
    valid = jnp.logical_and(valid, jnp.where(window > 0, near, True))
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bhgk,bkhd->bhgd", pv.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged-KV reads: K/V live in a shared (P, page, Hkv, D) page pool and are
# addressed per request through a (B, n_blocks) block table.
# ---------------------------------------------------------------------------


def gather_kv_pages(pages: jnp.ndarray,
                    block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialize each lane's logical KV view from the page pool.

    ``pages``: ``(P, page, ...)`` physical pool (one layer of K, V or a
    scale pool); ``block_tables``: ``(B, n_blocks)`` int32 physical page
    ids in logical order.  Returns ``(B, n_blocks * page, ...)`` — logical
    position ``t`` of lane ``b`` lives at
    ``pages[block_tables[b, t // page], t % page]``.
    """
    g = jnp.take(pages, block_tables, axis=0)      # (B, nblk, page, ...)
    b, nblk, page = g.shape[:3]
    out = g.reshape((b, nblk * page) + g.shape[3:])
    # mesh-native serving: lanes over the data axes, KV heads over
    # ``model`` — axis 2 is Hkv for K/V pools (B, T, Hkv, Dh) *and* for
    # scale pools (B, T, Hkv), so one hint covers both.  No-op off-mesh.
    return with_hint(out, ("pod", "data"), None, "model")


@_scoped("attend_paged_decode")
def attend_paged_decode(
    q: jnp.ndarray,            # (B, 1, Hq, D)
    k_pages: jnp.ndarray,      # (P, page, Hkv, D) — one layer's pool
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    cur_pos: jnp.ndarray,      # (B,) position of the newest token
    window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,  # (P, page, Hkv) int8 pools only
    v_scale: Optional[jnp.ndarray] = None,
    attn_backend: str = "gather",
    mesh=None,
    model_axis: str = "model",
) -> jnp.ndarray:
    """Single-token decode reading K/V through the block table.

    ``attn_backend`` picks the read path (resolved once into the plan —
    ``EnginePlan.attn_backend`` — and threaded down, never decided here):

    * ``gather`` — the reference: materialize each lane's logical KV view
      from the pool, then attend.  The gathered view is exactly the dense
      cache the fixed-slot engine holds (unwritten logical positions are
      masked by ``cur_pos``), so this path is token-identical to
      :func:`attend_decode` — pages only change *where* the bytes live,
      not the math.
    * ``pallas_interpret`` / ``pallas_tpu`` — the fused in-place kernel
      (``repro.kernels.paged_attention``): the block table drives the K/V
      BlockSpec index maps, pages are read from the pool exactly once and
      the gathered copy never exists; token-identity against ``gather``
      is pinned by ``tests/test_paged_attention.py``.

    ``mesh`` (fused backends only): shard_map the kernel over
    ``model_axis`` — each shard's kernel invocation runs on the
    contiguous KV-head slice its pool shard already holds
    (``repro.engine.sharded.sharded_paged_attention``).  The gather path
    composes with a mesh through its sharding hints instead and ignores
    these arguments.
    """
    if attn_backend in ("pallas_interpret", "pallas_tpu"):
        from repro.kernels.paged_attention.ops import paged_attention

        return paged_attention(q, k_pages, v_pages, block_tables, cur_pos,
                               window, k_scale, v_scale,
                               attn_backend=attn_backend,
                               mesh=mesh, model_axis=model_axis)
    if attn_backend != "gather":
        raise ValueError(f"unknown attention backend {attn_backend!r}")
    kg = gather_kv_pages(k_pages, block_tables)
    vg = gather_kv_pages(v_pages, block_tables)
    if k_scale is not None:
        ksg = gather_kv_pages(k_scale, block_tables)
        vsg = gather_kv_pages(v_scale, block_tables)
        return attend_decode_quant(q, kg, vg, ksg, vsg, cur_pos, window)
    return attend_decode(q, kg, vg, cur_pos, window)


@_scoped("attend_paged_prefill")
def attend_paged_prefill(
    q: jnp.ndarray,            # (B, C, Hq, D) — one prefill chunk
    k_pages: jnp.ndarray,      # (P, page, Hkv, D) — one layer's pool
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    positions: jnp.ndarray,    # (B, C) logical positions of the chunk
    pos0: jnp.ndarray,         # (B,) tokens already resident per lane
    seq_lens: jnp.ndarray,     # (B,) total valid after this chunk
    window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    attn_backend: str = "gather",
    mesh=None,
    model_axis: str = "model",
) -> jnp.ndarray:
    """One prefill chunk's attention reading K/V through the block table.

    The chunk's K/V must already be scattered into the pool; lane ``b``'s
    queries cover logical positions ``[pos0[b], pos0[b]+C)`` (suffix-only
    prefill after a prefix-cache hit arrives with ``pos0`` mid-page) and
    attend the lane's full resident prefix plus this chunk, causally,
    clipped to ``limit = min(seq_lens, pos0 + C)``.

    * ``gather`` — materialize the logical view, then :func:`attend_dense`
      / :func:`attend_dense_quant` (the reference; carries sharding hints).
    * ``pallas_interpret`` / ``pallas_tpu`` — the fused prefill grid
      (``kernels.paged_attention.paged_prefill_pallas``): per-lane
      ``pos0`` / ``seq_lens`` travel as scalar-prefetch operands and the
      gathered ``(B, T, Hkv, D)`` view never exists.  With a ``mesh`` the
      kernel shard_maps over ``model_axis`` like the decode path.
    """
    if attn_backend in ("pallas_interpret", "pallas_tpu"):
        from repro.kernels.paged_attention.ops import paged_prefill_attention

        return paged_prefill_attention(
            q, k_pages, v_pages, block_tables, pos0, seq_lens, window,
            k_scale, v_scale, attn_backend=attn_backend,
            mesh=mesh, model_axis=model_axis)
    if attn_backend != "gather":
        raise ValueError(f"unknown attention backend {attn_backend!r}")
    b, c = q.shape[:2]
    t_total = block_tables.shape[1] * k_pages.shape[1]
    kv_pos = jnp.broadcast_to(
        jnp.arange(t_total, dtype=jnp.int32)[None, :], (b, t_total))
    limit = jnp.minimum(seq_lens, pos0 + c)
    kv_valid = kv_pos < limit[:, None]
    kg = gather_kv_pages(k_pages, block_tables)
    vg = gather_kv_pages(v_pages, block_tables)
    if k_scale is not None:
        ksg = gather_kv_pages(k_scale, block_tables)
        vsg = gather_kv_pages(v_scale, block_tables)
        return attend_dense_quant(q, kg, vg, ksg, vsg, positions, kv_pos,
                                  window, kv_valid=kv_valid)
    return attend_dense(q, kg, vg, positions, kv_pos, window,
                        kv_valid=kv_valid)
