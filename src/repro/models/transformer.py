"""Generic decoder-family LM covering all 10 assigned architectures.

One ``init_params`` / ``forward`` / ``decode_step`` triple drives every
family (dense / vlm / audio / moe / ssm / hybrid).  Layers are stacked and
executed under ``lax.scan`` so HLO size — and therefore dry-run compile time
for the 88/94-layer configs — is O(1) in depth.  Per-layer variation
(gemma3's 5:1 local:global windows) rides along as scanned flag arrays.

The IMAGine engine plugs in through ``quantize_params`` + the ``eng``
argument: every linear then reads b-bit packed weights (b/8 bytes/weight of
HBM traffic) — the paper's PIM GEMV as the serving fast path.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import EngineConfig, ModelConfig
from repro.dist.hints import shard_batch_seq
from repro.dist.sharding import _ROW as _ROW_PARALLEL
from repro.engine import as_plan, pack_linear, resolve_attn_backend
from repro.models.attention import (
    FLASH_THRESHOLD,
    attend_decode,
    attend_decode_quant,
    attend_dense,
    attend_flash,
    attend_local_gather,
    attend_paged_decode,
    attend_paged_prefill,
)
from repro.models.layers import (
    apply_rope,
    dense,
    init_embedding,
    init_linear,
    rms_norm,
    swiglu,
)
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import init_ssm, ssm_decode_step, ssm_forward

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig, dtype):
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, hq * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, hkv * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, hkv * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], hq * dh, d, dtype),
    }


def _init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out = {
        "w_up": init_linear(ks[1], d, f, dtype),
        "w_down": init_linear(ks[2], f, d, dtype),
    }
    if cfg.mlp_gated:
        out["w_gate"] = init_linear(ks[0], d, f, dtype)
    return out


def _init_block(key, cfg: ModelConfig, dtype):
    """One scanned layer for the cfg's family."""
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.family in ("dense", "vlm", "audio"):
        return {
            "ln1": jnp.zeros((d,), dtype),
            "attn": _init_attn(ks[0], cfg, dtype),
            "ln2": jnp.zeros((d,), dtype),
            "mlp": _init_mlp(ks[1], cfg, dtype),
        }
    if cfg.family == "moe":
        return {
            "ln1": jnp.zeros((d,), dtype),
            "attn": _init_attn(ks[0], cfg, dtype),
            "ln2": jnp.zeros((d,), dtype),
            "moe": init_moe(ks[1], cfg, dtype),
        }
    if cfg.family in ("ssm", "hybrid"):
        return {
            "ln1": jnp.zeros((d,), dtype),
            "ssm": init_ssm(ks[0], cfg, dtype),
        }
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = _dtype(cfg)
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    if cfg.family == "audio":
        emb = jax.vmap(
            lambda k: init_embedding(k, cfg.vocab_size, cfg.d_model, dtype)
        )(jax.random.split(k_emb, cfg.n_codebooks))
    else:
        emb = init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype)

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)

    params: Params = {
        "embed": emb,
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        ks = jax.random.split(k_shared, 2)
        params["shared_attn"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": _init_attn(ks[0], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(ks[1], cfg, dtype),
        }
    if not cfg.tie_embeddings:
        out_dim = cfg.vocab_size * cfg.n_codebooks
        params["lm_head"] = init_linear(k_head, cfg.d_model, out_dim, dtype)
    return params


# ---------------------------------------------------------------------------
# shared block application
# ---------------------------------------------------------------------------


def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) per-layer sliding window (0 = global/full attention)."""
    win = [0 if cfg.is_global_layer(i) else cfg.sliding_window
           for i in range(cfg.n_layers)]
    return jnp.asarray(win, jnp.int32)


def _attn_apply(p, x, positions, cfg, eng, window, *, use_flash: bool,
                local_gather: bool = False):
    """Full-sequence attention sub-block.  Returns (out, (k, v))."""
    b, s, d = x.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = dense(p["attn"]["wq"], h, eng).reshape(b, s, hq, dh)
    k = dense(p["attn"]["wk"], h, eng).reshape(b, s, hkv, dh)
    v = dense(p["attn"]["wv"], h, eng).reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if local_gather and isinstance(window, int) and window > 0:
        o = attend_local_gather(q, k, v, positions, window)
    elif use_flash:
        o = attend_flash(q, k, v, positions, window)
    else:
        o = attend_dense(q, k, v, positions, positions, window)
    o = dense(p["attn"]["wo"], o.reshape(b, s, hq * dh), eng)
    return x + o, (k, v)


def _mlp_apply(p, x, cfg, eng):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + swiglu(p["mlp"], h, eng)


def _moe_apply(p, x, cfg, eng):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_block(p["moe"], h, cfg, eng)
    return x + y, aux


def _ssm_apply(p, x, cfg, eng):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    return x + ssm_forward(p["ssm"], h, cfg, eng)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (B,S,D), positions (B,S))."""
    if cfg.family == "audio":
        toks = batch["tokens"]                       # (B, S, K)
        x = sum(
            jnp.take(params["embed"][k], toks[..., k], axis=0)
            for k in range(cfg.n_codebooks)
        )
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B,S,D)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype)    # (B, S_img, D)
        x = jnp.concatenate([img, x], axis=1)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _lm_logits(params, x, cfg, eng):
    with jax.named_scope("_lm_logits"):
        return _lm_logits_inner(params, x, cfg, eng)


def _lm_logits_inner(params, x, cfg, eng):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = dense(params["lm_head"], h, eng)
    if cfg.family == "audio":
        b, s = logits.shape[:2]
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    return logits


def forward(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
    remat: str = "block",
    local_gather: bool = False,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits, aux_loss) — or
    (hidden, aux_loss) with ``return_hidden`` (the chunked-CE train path
    computes the LM head per sequence chunk instead of materializing the
    full (B, S, vocab) logits)."""
    eng = as_plan(eng)  # EngineConfig | EnginePlan | None -> resolved plan
    x, positions = embed_inputs(params, batch, cfg)
    x = shard_batch_seq(x)
    s = x.shape[1]
    use_flash = s >= FLASH_THRESHOLD

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        windows = _layer_windows(cfg)

        def body(carry, xs):
            x, aux = carry
            lp, win = xs
            x, _ = _attn_apply(lp, x, positions, cfg, eng, win,
                               use_flash=use_flash,
                               local_gather=local_gather)
            if cfg.family == "moe":
                x, a = _moe_apply(lp, x, cfg, eng)
                aux = aux + a
            else:
                x = _mlp_apply(lp, x, cfg, eng)
            return (x, aux), None

        if local_gather and cfg.sliding_window > 0 and cfg.global_every > 0:
            # static local/global split cannot ride a traced window flag;
            # run layers unscanned in groups (hillclimb-C variant).
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                win = 0 if cfg.is_global_layer(i) else cfg.sliding_window
                x, _ = _attn_apply(lp, x, positions, cfg, eng, win,
                                   use_flash=use_flash, local_gather=True)
                x = _mlp_apply(lp, x, cfg, eng)
        else:
            fn = jax.checkpoint(body) if remat != "none" else body
            (x, aux), _ = jax.lax.scan(
                fn, (x, jnp.zeros((), jnp.float32)),
                (params["layers"], windows),
            )

    elif cfg.family == "ssm":

        def body(carry, lp):
            return _ssm_apply(lp, carry, cfg, eng), None

        fn = jax.checkpoint(body) if remat != "none" else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
        aux = jnp.zeros((), jnp.float32)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every

        def body(carry, xs):
            x = carry
            lp, idx = xs
            x = _ssm_apply(lp, x, cfg, eng)

            def with_attn(x):
                x, _ = _attn_apply(shared, x, positions, cfg, eng, 0,
                                   use_flash=use_flash)
                return _mlp_apply(shared, x, cfg, eng)

            x = jax.lax.cond((idx + 1) % every == 0, with_attn,
                             lambda x: x, x)
            return x, None

        fn = jax.checkpoint(body) if remat != "none" else body
        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        x, _ = jax.lax.scan(fn, x, (params["layers"], idxs))
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm" and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1] :]
    if return_hidden:
        return x, aux
    logits = _lm_logits(params, x, cfg, eng)
    return logits, aux


def loss_fn(logits: jnp.ndarray, labels: jnp.ndarray,
            aux: jnp.ndarray = 0.0) -> jnp.ndarray:
    """Token-mean cross entropy (+ router aux).  labels: int, same leading
    shape as logits minus the vocab axis."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux


def chunked_ce(params: Params, hidden: jnp.ndarray, labels: jnp.ndarray,
               cfg: ModelConfig, eng: Optional[EngineConfig] = None,
               chunk: int = 512, aux: jnp.ndarray = 0.0) -> jnp.ndarray:
    """Cross entropy with the LM head applied per sequence chunk.

    Peak live logits are (B, chunk, V) instead of (B, S, V) — the standard
    large-vocab memory optimization (MaxText-style); numerically identical
    to ``loss_fn(_lm_logits(hidden))``.
    """
    eng = as_plan(eng)
    b, s = hidden.shape[:2]
    chunk = min(chunk, s)
    if s % chunk != 0:
        return loss_fn(_lm_logits(params, hidden, cfg, eng), labels, aux)
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape((b, nc, chunk) + labels.shape[2:]).swapaxes(0, 1)

    def body(acc, xs):
        hc, lc = xs
        logits = _lm_logits(params, hc, cfg, eng)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(nll), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros(()), (hs, ls))
    n_tok = labels.size
    return total / n_tok + aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, split_local: bool = False,
               stacked: bool = True, kv_bits: int = 0) -> Params:
    """Decode cache.

    ``split_local=True`` (gemma3 hillclimb) allocates window-capped ring
    buffers for local layers instead of full-length.

    ``stacked=False`` stores per-layer caches as tuples instead of one
    (L, ...) array: the decode step then runs an unrolled layer loop where
    every cache update is an in-place scatter on its own (donated) buffer —
    no stacked loop-carry, which on TPU avoids spurious cache copies and is
    the production decode layout.  The dry-run serve cells use this.

    ``kv_bits=8`` (beyond-paper: the IMAGine bit-plane idea applied to the
    cache) stores K/V as int8 with per-(token, head) scales — halving the
    dominant decode-memory term vs bf16.
    """
    dtype = dtype or _dtype(cfg)
    if kv_bits:
        dtype = jnp.int8
    dh, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    cache: Params = {"pos": jnp.zeros((batch,), jnp.int32)}

    def maybe_split(arr):
        if stacked:
            return arr
        return tuple(arr[i] for i in range(arr.shape[0]))

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if split_local and cfg.sliding_window > 0 and cfg.global_every > 0:
            n_glob = sum(cfg.is_global_layer(i) for i in range(cfg.n_layers))
            n_loc = cfg.n_layers - n_glob
            w = cfg.sliding_window
            cache["k_global"] = maybe_split(
                jnp.zeros((n_glob, batch, max_len, hkv, dh), dtype))
            cache["v_global"] = maybe_split(
                jnp.zeros((n_glob, batch, max_len, hkv, dh), dtype))
            cache["k_local"] = maybe_split(
                jnp.zeros((n_loc, batch, w, hkv, dh), dtype))
            cache["v_local"] = maybe_split(
                jnp.zeros((n_loc, batch, w, hkv, dh), dtype))
        else:
            shape = (cfg.n_layers, batch, max_len, hkv, dh)
            cache["k"] = maybe_split(jnp.zeros(shape, dtype))
            cache["v"] = maybe_split(jnp.zeros(shape, dtype))
            if kv_bits:
                sshape = (cfg.n_layers, batch, max_len, hkv)
                cache["k_scale"] = maybe_split(jnp.zeros(sshape, jnp.bfloat16))
                cache["v_scale"] = maybe_split(jnp.zeros(sshape, jnp.bfloat16))
    elif cfg.family in ("ssm", "hybrid"):
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        cache["conv"] = maybe_split(jnp.zeros(
            (cfg.n_layers, batch, cfg.conv_width - 1, conv_ch), dtype))
        cache["h"] = maybe_split(jnp.zeros(
            (cfg.n_layers, batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
             cfg.ssm_state), jnp.float32))
        if cfg.family == "hybrid" and cfg.attn_every:
            sites = cfg.n_layers // cfg.attn_every
            cache["k"] = maybe_split(
                jnp.zeros((sites, batch, max_len, hkv, dh), dtype))
            cache["v"] = maybe_split(
                jnp.zeros((sites, batch, max_len, hkv, dh), dtype))
    return cache


# ---------------------------------------------------------------------------
# prefill: forward + cache population (the serving prompt phase)
# ---------------------------------------------------------------------------


def prefill(
    params: Params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    cache: Params,
    eng: Optional[EngineConfig] = None,
) -> Tuple[jnp.ndarray, Params]:
    """Run the prompt through the model, filling the decode cache.

    Returns (last-token logits (B,1,V...), cache).  The compute is the same
    chunked-flash forward as training (no S^2 blocks); K/V per layer are
    collected as scan outputs and written into the cache.
    """
    eng = as_plan(eng)
    x, positions = embed_inputs(params, batch, cfg)
    x = shard_batch_seq(x)
    b, s = x.shape[:2]
    use_flash = s >= FLASH_THRESHOLD
    new_cache = dict(cache)
    t = None

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        t = cache["k"].shape[2]
        windows = _layer_windows(cfg)

        def body(carry, xs):
            x = carry
            lp, win = xs
            x, (k, v) = _attn_apply(lp, x, positions, cfg, eng, win,
                                    use_flash=use_flash)
            if cfg.family == "moe":
                x, _ = _moe_apply(lp, x, cfg, eng)
            else:
                x = _mlp_apply(lp, x, cfg, eng)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
        pad = t - s
        new_cache["k"] = jnp.pad(
            ks.astype(cache["k"].dtype), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        new_cache["v"] = jnp.pad(
            vs.astype(cache["v"].dtype), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))

    elif cfg.family in ("ssm", "hybrid"):
        from repro.models.ssm import _ssm_run

        shared = params.get("shared_attn")
        every = cfg.attn_every
        if "k" in cache:
            t = cache["k"].shape[2]

        def body(carry, xs):
            x, ck_all, cv_all = carry
            lp, idx, conv0 = xs
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, conv_state, h_state = _ssm_run(
                lp["ssm"], h, cfg, eng, conv_state=None, h0=None)
            x = x + y
            if shared is not None:
                site = (idx + 1) // every - 1

                def with_attn(op):
                    x, ck_all, cv_all = op
                    x, (k, v) = _attn_apply(shared, x, positions, cfg, eng, 0,
                                            use_flash=use_flash)
                    x = _mlp_apply(shared, x, cfg, eng)
                    pad = ck_all.shape[2] - s
                    kp = jnp.pad(k.astype(ck_all.dtype),
                                 ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vp = jnp.pad(v.astype(cv_all.dtype),
                                 ((0, 0), (0, pad), (0, 0), (0, 0)))
                    ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, kp, site, 0)
                    cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, vp, site, 0)
                    return x, ck_all, cv_all

                x, ck_all, cv_all = jax.lax.cond(
                    (idx + 1) % every == 0, with_attn, lambda op: op,
                    (x, ck_all, cv_all))
            return (x, ck_all, cv_all), (conv_state, h_state)

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        if "k" in cache:
            init = (x, cache["k"], cache["v"])
        else:
            dh, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
            dummy = jnp.zeros((1, b, 1, max(hkv, 1), max(dh, 1)), x.dtype)
            init = (x, dummy, dummy)
        (x, nck, ncv), (convs, hs) = jax.lax.scan(
            body, init, (params["layers"], idxs, cache["conv"]))
        new_cache["conv"] = convs.astype(cache["conv"].dtype)
        new_cache["h"] = hs
        if "k" in cache:
            new_cache["k"], new_cache["v"] = nck, ncv
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm" and "img_embeds" in batch:
        x = x[:, batch["img_embeds"].shape[1] :]
    new_cache["pos"] = jnp.full((b,), s, jnp.int32)
    logits = _lm_logits(params, x[:, -1:], cfg, eng)
    return logits, new_cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def _attn_decode_apply(p, x, cache_k, cache_v, pos, cfg, eng, window,
                       scales=None):
    """One cached-attention sub-block for a single new token.

    cache_k/v: (B, T, Hkv, Dh); pos: (B,) position of the new token.
    ``scales``: (k_scale, v_scale) (B, T, Hkv) when the cache is int8
    (beyond-paper quantized-KV mode).  Returns (x, new_k, new_v[, scales]).
    """
    b, _, d = x.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = dense(p["attn"]["wq"], h, eng).reshape(b, 1, hq, dh)
    k = dense(p["attn"]["wk"], h, eng).reshape(b, 1, hkv, dh)
    v = dense(p["attn"]["wv"], h, eng).reshape(b, 1, hkv, dh)
    pos2 = pos[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    bidx = jnp.arange(b)
    t = cache_k.shape[1]
    slot = jnp.minimum(pos, t - 1)

    if scales is not None:
        # int8 cache: symmetric per-(token, head) quantization at write
        k_sc, v_sc = scales
        kq, ks_new = _quantize_kv(k[:, 0])
        vq, vs_new = _quantize_kv(v[:, 0])
        new_k = cache_k.at[bidx, slot].set(kq)
        new_v = cache_v.at[bidx, slot].set(vq)
        k_sc = k_sc.at[bidx, slot].set(ks_new.astype(k_sc.dtype))
        v_sc = v_sc.at[bidx, slot].set(vs_new.astype(v_sc.dtype))
        o = _attend_decode_quant(q, new_k, new_v, k_sc, v_sc, pos, window)
        o = dense(p["attn"]["wo"], o.reshape(b, 1, hq * dh), eng)
        return x + o, new_k, new_v, (k_sc, v_sc)

    new_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    new_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    o = attend_decode(q, new_k, new_v, pos, window)
    o = dense(p["attn"]["wo"], o.reshape(b, 1, hq * dh), eng)
    return x + o, new_k, new_v


def _quantize_kv(val):
    """Symmetric per-(…, head) int8 quantization of a K/V write.

    ``val``: ``(..., Hkv, Dh)`` float -> (int8 of the same shape,
    ``(..., Hkv)`` float scales).
    """
    absmax = jnp.max(jnp.abs(val.astype(jnp.float32)), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    qv = jnp.clip(jnp.round(val.astype(jnp.float32)
                            / scale[..., None]), -127, 127)
    return qv.astype(jnp.int8), scale


# moved to repro.models.attention (shared with the paged read path); the
# underscore name is kept as an alias for existing importers.
_attend_decode_quant = attend_decode_quant


def _attn_decode_apply_ring(p, x, cache_k, cache_v, pos, cfg, eng, window):
    """Ring-buffer variant for window-capped local caches (split_local)."""
    b, _, d = x.shape
    dh, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    w = cache_k.shape[1]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = dense(p["attn"]["wq"], h, eng).reshape(b, 1, hq, dh)
    k = dense(p["attn"]["wk"], h, eng).reshape(b, 1, hkv, dh)
    v = dense(p["attn"]["wv"], h, eng).reshape(b, 1, hkv, dh)
    pos2 = pos[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    bidx = jnp.arange(b)
    slot = pos % w
    new_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    new_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    # ring positions: slot i holds absolute position derived from pos
    ring = jnp.arange(w)[None, :]
    cur_slot = slot[:, None]
    age = (cur_slot - ring) % w                      # 0 = newest
    abs_pos = pos[:, None] - age
    valid = abs_pos >= 0
    scale = dh ** -0.5
    qg = q.reshape(b, hkv, hq // hkv, dh).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg, new_k.astype(jnp.float32)) * scale
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr, new_v.astype(jnp.float32))
    o = o.reshape(b, 1, hq * dh).astype(x.dtype)
    o = dense(p["attn"]["wo"], o, eng)
    return x + o, new_k, new_v


def decode_step(
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,                 # (B, 1) or (B, 1, K) for audio
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
) -> Tuple[jnp.ndarray, Params]:
    """One token of autoregressive decode.  Returns (logits, new_cache)."""
    eng = as_plan(eng)
    pos = cache["pos"]                   # (B,)
    if cfg.family == "audio":
        x = sum(
            jnp.take(params["embed"][k], tokens[..., k], axis=0)
            for k in range(cfg.n_codebooks)
        )
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    new_cache = dict(cache)
    unstacked = isinstance(cache.get("k", cache.get("conv")), tuple)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if "k_global" in cache:
            x = _decode_split_local(params, cache, new_cache, x, pos, cfg, eng)
        elif unstacked:
            windows = [0 if cfg.is_global_layer(i) else cfg.sliding_window
                       for i in range(cfg.n_layers)]
            quant_kv = "k_scale" in cache
            nk, nv, nks, nvs = [], [], [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                if quant_kv:
                    x, k_i, v_i, (ks_i, vs_i) = _attn_decode_apply(
                        lp, x, cache["k"][i], cache["v"][i], pos, cfg, eng,
                        windows[i],
                        scales=(cache["k_scale"][i], cache["v_scale"][i]))
                    nks.append(ks_i)
                    nvs.append(vs_i)
                else:
                    x, k_i, v_i = _attn_decode_apply(
                        lp, x, cache["k"][i], cache["v"][i], pos, cfg, eng,
                        windows[i])
                if cfg.family == "moe":
                    x, _ = _moe_apply(lp, x, cfg, eng)
                else:
                    x = _mlp_apply(lp, x, cfg, eng)
                nk.append(k_i)
                nv.append(v_i)
            new_cache["k"], new_cache["v"] = tuple(nk), tuple(nv)
            if quant_kv:
                new_cache["k_scale"] = tuple(nks)
                new_cache["v_scale"] = tuple(nvs)
        else:
            windows = _layer_windows(cfg)

            def body(x, xs):
                lp, win, ck, cv = xs
                x, nk, nv = _attn_decode_apply(lp, x, ck, cv, pos, cfg, eng, win)
                if cfg.family == "moe":
                    x, _ = _moe_apply(lp, x, cfg, eng)
                else:
                    x = _mlp_apply(lp, x, cfg, eng)
                return x, (nk, nv)

            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], windows, cache["k"], cache["v"])
            )
            new_cache["k"], new_cache["v"] = nk, nv

    elif cfg.family in ("ssm", "hybrid"):
        if unstacked:
            x, new_cache = _decode_ssm_unrolled(
                params, cache, new_cache, x, pos, cfg, eng)
            new_cache["pos"] = pos + 1
            logits = _lm_logits(params, x, cfg, eng)
            return logits, new_cache
        shared = params.get("shared_attn")
        every = cfg.attn_every
        attn_cache = [cache.get("k"), cache.get("v")]

        def body(carry, xs):
            x, ck_all, cv_all = carry
            lp, idx, conv, h = xs
            hnorm = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, new_conv, new_h = ssm_decode_step(
                lp["ssm"], hnorm, cfg, conv, h, eng)
            x = x + y
            if shared is not None:
                site = (idx + 1) // every - 1

                def with_attn(op):
                    x, ck_all, cv_all = op
                    ck = jax.lax.dynamic_index_in_dim(ck_all, site, 0, False)
                    cv = jax.lax.dynamic_index_in_dim(cv_all, site, 0, False)
                    x, nk, nv = _attn_decode_apply(
                        shared, x, ck, cv, pos, cfg, eng, 0)
                    x = _mlp_apply(shared, x, cfg, eng)
                    ck_all = jax.lax.dynamic_update_index_in_dim(
                        ck_all, nk, site, 0)
                    cv_all = jax.lax.dynamic_update_index_in_dim(
                        cv_all, nv, site, 0)
                    return x, ck_all, cv_all

                x, ck_all, cv_all = jax.lax.cond(
                    (idx + 1) % every == 0, with_attn, lambda op: op,
                    (x, ck_all, cv_all))
            return (x, ck_all, cv_all), (new_conv, new_h)

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        init = (x, attn_cache[0], attn_cache[1])
        if attn_cache[0] is None:
            dh, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
            dummy = jnp.zeros((1, x.shape[0], 1, hkv, dh), x.dtype)
            init = (x, dummy, dummy)
        (x, nck, ncv), (nconv, nh) = jax.lax.scan(
            body, init, (params["layers"], idxs, cache["conv"], cache["h"])
        )
        new_cache["conv"], new_cache["h"] = nconv, nh
        if "k" in cache:
            new_cache["k"], new_cache["v"] = nck, ncv
    else:
        raise ValueError(cfg.family)

    new_cache["pos"] = pos + 1
    logits = _lm_logits(params, x, cfg, eng)
    return logits, new_cache


def _decode_ssm_unrolled(params, cache, new_cache, x, pos, cfg, eng):
    """Unrolled ssm/hybrid decode over tuple caches (production layout)."""
    shared = params.get("shared_attn")
    every = cfg.attn_every
    nconv, nh = [], []
    nk = list(cache["k"]) if "k" in cache else []
    nv = list(cache["v"]) if "k" in cache else []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        hnorm = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, conv_i, h_i = ssm_decode_step(
            lp["ssm"], hnorm, cfg, cache["conv"][i], cache["h"][i], eng)
        x = x + y
        nconv.append(conv_i)
        nh.append(h_i)
        if shared is not None and (i + 1) % every == 0:
            site = (i + 1) // every - 1
            x, k_s, v_s = _attn_decode_apply(
                shared, x, nk[site], nv[site], pos, cfg, eng, 0)
            x = _mlp_apply(shared, x, cfg, eng)
            nk[site], nv[site] = k_s, v_s
    new_cache["conv"], new_cache["h"] = tuple(nconv), tuple(nh)
    if nk:
        new_cache["k"], new_cache["v"] = tuple(nk), tuple(nv)
    return x, new_cache


def _decode_split_local(params, cache, new_cache, x, pos, cfg, eng):
    """Unscanned decode for the split local/global cache layout (gemma3
    hillclimb): local layers use window-sized ring buffers."""
    gi = li = 0
    nk_g, nv_g = list(cache["k_global"]), list(cache["v_global"])
    nk_l, nv_l = list(cache["k_local"]), list(cache["v_local"])
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        if cfg.is_global_layer(i):
            x, nk, nv = _attn_decode_apply(
                lp, x, nk_g[gi], nv_g[gi], pos, cfg, eng, 0)
            nk_g[gi], nv_g[gi] = nk, nv
            gi += 1
        else:
            x, nk, nv = _attn_decode_apply_ring(
                lp, x, nk_l[li], nv_l[li], pos, cfg, eng, cfg.sliding_window)
            nk_l[li], nv_l[li] = nk, nv
            li += 1
        x = _mlp_apply(lp, x, cfg, eng)
    if isinstance(cache["k_global"], tuple):
        new_cache["k_global"], new_cache["v_global"] = tuple(nk_g), tuple(nv_g)
        new_cache["k_local"], new_cache["v_local"] = tuple(nk_l), tuple(nv_l)
    else:
        new_cache["k_global"] = jnp.stack(nk_g)
        new_cache["v_global"] = jnp.stack(nv_g)
        new_cache["k_local"] = jnp.stack(nk_l)
        new_cache["v_local"] = jnp.stack(nv_l)
    return x


# ---------------------------------------------------------------------------
# paged-KV serving: decode + chunked prefill against a page-table cache
# ---------------------------------------------------------------------------


def _scatter_targets(block_tables, positions, valid, page_size):
    """Physical (page, offset) scatter targets for logical ``positions``.

    ``positions`` may be (B,) (decode) or (B, C) (a prefill chunk); invalid
    writes (idle lanes, chunk padding) are routed to the null page 0, which
    no block table references.
    """
    nblk = block_tables.shape[1]
    blk = jnp.clip(positions // page_size, 0, nblk - 1)
    if positions.ndim == 1:                       # decode: (B,)
        rows = jnp.arange(block_tables.shape[0])
    else:                                         # prefill chunk: (B, C)
        rows = jnp.arange(block_tables.shape[0])[:, None]
    pidx = jnp.where(valid, block_tables[rows, blk], 0)
    poff = positions % page_size
    return pidx, poff


def decode_step_paged(
    params: Params,
    pages,                               # KVPages: k/v (L, P, page, Hkv, Dh)
    block_tables: jnp.ndarray,           # (B, n_blocks) int32
    pos: jnp.ndarray,                    # (B,) logical token count per lane
    active: jnp.ndarray,                 # (B,) bool — lanes decoding now
    tokens: jnp.ndarray,                 # (B, 1) or (B, 1, K) for audio
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
    attn_backend: Optional[str] = None,
    mesh=None,
    model_axis: str = "model",
) -> Tuple[jnp.ndarray, Any]:
    """One token of autoregressive decode over paged KV.

    Token-identical to :func:`decode_step` on the fixed-slot cache: the
    block table only relocates KV bytes into shared pages.  Inactive lanes
    (idle, or mid-prefill — their pages must stay frozen) scatter their
    garbage K/V into the null page and their logits are ignored by the
    caller.  ``attn_backend`` overrides the plan's resolved decode-read
    path (``gather`` reference vs the fused in-place Pallas kernel); None
    defers to the plan, and no plan means "auto".  ``mesh`` /
    ``model_axis`` shard_map the fused kernel over the pool's
    heads-over-model placement (None defers to the plan's mesh; the
    gather path uses its hints instead).  Returns
    ``(logits, new_pages)``.
    """
    eng = as_plan(eng)
    if attn_backend is None and eng is not None:
        attn_backend = eng.attn_backend
    attn_backend = resolve_attn_backend(attn_backend)
    if mesh is None and eng is not None:
        mesh, model_axis = eng.mesh, eng.model_axis
    b = tokens.shape[0]
    dh, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    if cfg.family == "audio":
        x = sum(
            jnp.take(params["embed"][k], tokens[..., k], axis=0)
            for k in range(cfg.n_codebooks)
        )
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    # lanes over the data axes (no-op off-mesh) — matches the pool's
    # pages-over-data placement so scatters stay local to the lane's shard
    x = shard_batch_seq(x)
    quant = pages.k_scale is not None
    pidx, poff = _scatter_targets(block_tables, pos, active,
                                  pages.page_size)
    windows = _layer_windows(cfg)
    pos2 = pos[:, None]

    def body(x, xs):
        lp, win = xs["lp"], xs["win"]
        kp, vp = xs["kp"], xs["vp"]
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = dense(lp["attn"]["wq"], h, eng).reshape(b, 1, hq, dh)
        k = dense(lp["attn"]["wk"], h, eng).reshape(b, 1, hkv, dh)
        v = dense(lp["attn"]["wv"], h, eng).reshape(b, 1, hkv, dh)
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
        ys = {}
        if quant:
            kq, ks_new = _quantize_kv(k[:, 0])
            vq, vs_new = _quantize_kv(v[:, 0])
            nkp = kp.at[pidx, poff].set(kq)
            nvp = vp.at[pidx, poff].set(vq)
            nks = xs["ks"].at[pidx, poff].set(
                ks_new.astype(xs["ks"].dtype))
            nvs = xs["vs"].at[pidx, poff].set(
                vs_new.astype(xs["vs"].dtype))
            o = attend_paged_decode(q, nkp, nvp, block_tables, pos, win,
                                    k_scale=nks, v_scale=nvs,
                                    attn_backend=attn_backend,
                                    mesh=mesh, model_axis=model_axis)
            ys["ks"], ys["vs"] = nks, nvs
        else:
            nkp = kp.at[pidx, poff].set(k[:, 0].astype(kp.dtype))
            nvp = vp.at[pidx, poff].set(v[:, 0].astype(vp.dtype))
            o = attend_paged_decode(q, nkp, nvp, block_tables, pos, win,
                                    attn_backend=attn_backend,
                                    mesh=mesh, model_axis=model_axis)
        o = dense(lp["attn"]["wo"], o.reshape(b, 1, hq * dh), eng)
        x = x + o
        if cfg.family == "moe":
            x, _ = _moe_apply(lp, x, cfg, eng)
        else:
            x = _mlp_apply(lp, x, cfg, eng)
        ys["kp"], ys["vp"] = nkp, nvp
        return x, ys

    xs = {"lp": params["layers"], "win": windows,
          "kp": pages.k, "vp": pages.v}
    if quant:
        xs["ks"], xs["vs"] = pages.k_scale, pages.v_scale
    x, ys = jax.lax.scan(body, x, xs)
    new_pages = pages.replace(
        k=ys["kp"], v=ys["vp"],
        k_scale=ys.get("ks"), v_scale=ys.get("vs"))
    logits = _lm_logits(params, x, cfg, eng)
    return logits, new_pages


def prefill_chunk(
    params: Params,
    pages,                               # KVPages
    block_tables: jnp.ndarray,           # (B, n_blocks) int32
    tokens: jnp.ndarray,                 # (B, C) or (B, C, K) for audio
    pos0: jnp.ndarray,                   # (B,) tokens already prefilled
    seq_lens: jnp.ndarray,               # (B,) total valid after this chunk
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
    attn_backend: Optional[str] = None,
    mesh=None,
    model_axis: str = "model",
) -> Tuple[jnp.ndarray, Any]:
    """One batched chunk of prompt prefill against paged KV.

    Lane ``b`` contributes tokens for logical positions
    ``[pos0[b], seq_lens[b])``; trailing chunk padding (and idle lanes,
    ``seq_lens == pos0``) is masked — padded K/V lands in the null page
    and padded queries attend nothing real.  Attention sees the lane's
    *full* resident prefix (pages written by earlier chunks or shared via
    the prefix cache) plus this chunk, so running ``prefill_chunk`` to
    completion over any chunk size matches the one-shot :func:`prefill`
    numerics.  ``attn_backend`` picks the read path like on the decode
    step: ``gather`` materializes the logical view per layer; the fused
    backends run the in-kernel prefill grid
    (:func:`repro.models.attention.attend_paged_prefill`) and the
    gathered ``(B, T, Hkv, Dh)`` view never exists.  Returns
    ``(last-valid-token logits (B, 1, V...), new_pages)``.
    """
    eng = as_plan(eng)
    if attn_backend is None and eng is not None:
        attn_backend = eng.attn_backend
    attn_backend = resolve_attn_backend(attn_backend)
    if mesh is None and eng is not None:
        mesh, model_axis = eng.mesh, eng.model_axis
    c = tokens.shape[1]
    dh, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    positions = pos0[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    valid_q = positions < seq_lens[:, None]
    x, positions = embed_inputs(
        params, {"tokens": tokens, "positions": positions}, cfg)
    x = shard_batch_seq(x)
    b = x.shape[0]
    quant = pages.k_scale is not None
    pidx, poff = _scatter_targets(block_tables, positions, valid_q,
                                  pages.page_size)
    windows = _layer_windows(cfg)

    def body(x, xs):
        lp, win = xs["lp"], xs["win"]
        kp, vp = xs["kp"], xs["vp"]
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = dense(lp["attn"]["wq"], h, eng).reshape(b, c, hq, dh)
        k = dense(lp["attn"]["wk"], h, eng).reshape(b, c, hkv, dh)
        v = dense(lp["attn"]["wv"], h, eng).reshape(b, c, hkv, dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        ys = {}
        if quant:
            kq, ks_new = _quantize_kv(k)
            vq, vs_new = _quantize_kv(v)
            nkp = kp.at[pidx, poff].set(kq)
            nvp = vp.at[pidx, poff].set(vq)
            nks = xs["ks"].at[pidx, poff].set(
                ks_new.astype(xs["ks"].dtype))
            nvs = xs["vs"].at[pidx, poff].set(
                vs_new.astype(xs["vs"].dtype))
            # scales stay folded into the probabilities on both read
            # paths (attend_dense_quant math == the fused grid's in-VMEM
            # folding) — the int8 view is never dequantized wholesale.
            o = attend_paged_prefill(q, nkp, nvp, block_tables, positions,
                                     pos0, seq_lens, win,
                                     k_scale=nks, v_scale=nvs,
                                     attn_backend=attn_backend,
                                     mesh=mesh, model_axis=model_axis)
            ys["ks"], ys["vs"] = nks, nvs
        else:
            nkp = kp.at[pidx, poff].set(k.astype(kp.dtype))
            nvp = vp.at[pidx, poff].set(v.astype(vp.dtype))
            o = attend_paged_prefill(q, nkp, nvp, block_tables, positions,
                                     pos0, seq_lens, win,
                                     attn_backend=attn_backend,
                                     mesh=mesh, model_axis=model_axis)
        o = dense(lp["attn"]["wo"], o.reshape(b, c, hq * dh), eng)
        x = x + o
        if cfg.family == "moe":
            x, _ = _moe_apply(lp, x, cfg, eng)
        else:
            x = _mlp_apply(lp, x, cfg, eng)
        ys["kp"], ys["vp"] = nkp, nvp
        return x, ys

    xs = {"lp": params["layers"], "win": windows,
          "kp": pages.k, "vp": pages.v}
    if quant:
        xs["ks"], xs["vs"] = pages.k_scale, pages.v_scale
    x, ys = jax.lax.scan(body, x, xs)
    new_pages = pages.replace(
        k=ys["kp"], v=ys["vp"],
        k_scale=ys.get("ks"), v_scale=ys.get("vs"))
    last = jnp.clip(seq_lens - pos0 - 1, 0, c - 1)
    h_last = x[jnp.arange(b), last][:, None]
    logits = _lm_logits(params, h_last, cfg, eng)
    return logits, new_pages


# ---------------------------------------------------------------------------
# engine quantization of trained params
# ---------------------------------------------------------------------------

_QUANT_KEYS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "in_proj", "out_proj", "lm_head"}
# _ROW_PARALLEL (imported above from dist.sharding._ROW — one source of
# truth): these consume model-sharded activations, so the sharded backend
# must split their contraction axis to agree with the param placement.


def quantize_params(params: Params, cfg: ModelConfig, bits: int = 8) -> Params:
    """Convert trained params into IMAGine-engine serving format: every
    large linear becomes a :class:`~repro.engine.PackedLinear` (bit-packed
    along the contraction axis, ``bits`` validated and frozen into the
    pytree at pack time, mesh partition preference derived from the name).
    Embeddings, norms, convs, router stay dense."""

    def walk(node, name: str = ""):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in _QUANT_KEYS:
                    part = "row" if k in _ROW_PARALLEL else "col"
                    if isinstance(v, dict) and "w" in v:  # {"w", "bias"?}
                        out[k] = pack_linear(v["w"], bits, bias=v.get("bias"),
                                             partition=part)
                    elif isinstance(v, jnp.ndarray) and v.ndim >= 2:
                        out[k] = pack_linear(v, bits)     # stacked experts
                    else:
                        out[k] = walk(v, k)
                else:
                    out[k] = walk(v, k)
            return out
        return node

    return walk(params)
