"""Mixture-of-Experts block: token-choice top-k routing with capacity,
sort-based static-shape dispatch, expert-parallel sharding over the
``model`` mesh axis.

Covers llama4-scout (16e top-1 + shared expert) and qwen3-moe (128e top-8).
The dispatch buffer is (E, C, D) with C = ceil(T·k/E · capacity_factor);
tokens over capacity are dropped (standard token-choice semantics).  The
(E, ...) leading axis is the EP axis — XLA lowers the scatter/gather across
it to all-to-all collectives, which the roofline collective term measures.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import EngineConfig, ModelConfig
from repro.dist.hints import shard_experts, with_hint
from repro.engine import as_plan
from repro.models.layers import dense, engine_apply, init_linear, is_quantized, swiglu

# EP dispatch mode.  "a2a" (default) pins the dispatch buffer's sharding on
# both sides of the expert exchange so GSPMD lowers it to compact
# all-to-alls and the combine gather/scatter stay row-local.  "gspmd"
# leaves placement to propagation — kept for the §Perf baseline: it lets
# GSPMD materialize the combine as full-tensor all-reduces (measured 39x
# worse on qwen3-moe train_4k).
EP_DISPATCH = "a2a"


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std = 1.0 / (d ** 0.5)
    params = {
        "router": init_linear(ks[0], d, e, dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * std).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff
        params["shared"] = {
            "w_gate": init_linear(jax.random.fold_in(ks[4], 1), d, fs, dtype),
            "w_up": init_linear(jax.random.fold_in(ks[4], 2), d, fs, dtype),
            "w_down": init_linear(jax.random.fold_in(ks[4], 3), fs, d, dtype),
        }
    return params


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(-(-n_tokens * cfg.top_k * cfg.capacity_factor // cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _scoped(name):
    import functools

    def wrap(fn):
        @functools.wraps(fn)
        def inner(*a, **k):
            with jax.named_scope(name):
                return fn(*a, **k)
        return inner
    return wrap


@_scoped("moe_block")
def moe_block(
    params,
    x: jnp.ndarray,                 # (B, S, D) — or (T, D), treated as B=1
    cfg: ModelConfig,
    eng: Optional[EngineConfig] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss).

    GROUP-WISE token-choice routing (GShard/Switch style): each sequence
    (batch row) routes its own tokens with a per-group capacity.  All
    sort/rank/scatter work happens along the row axis, which is sharded
    over the data axes — so dispatch is communication-free and the only
    collective is the (data <-> model) resharding of the (B, E, C, D)
    dispatch buffer, which XLA lowers to an all-to-all: exactly the EP
    pattern the roofline's collective term should see.
    """
    eng = as_plan(eng)
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(s, cfg)

    logits = with_hint(dense(params["router"], x).astype(jnp.float32),
                       ("pod", "data"), None, None)           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style) ----------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs) * cfg.router_aux_coef

    # ---- per-row sort-based dispatch (static shapes, no cross-row comm) ----
    flat_e = top_i.reshape(b, s * k)
    flat_g = top_p.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)    # (B, S*k)
    # segment starts per row: first index of each expert id in the sorted row
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e)))(sorted_e)  # (B, E)
    rank = (jnp.arange(s * k)[None, :]
            - jnp.take_along_axis(starts, sorted_e, axis=-1))
    keep = rank < c
    dst = jnp.where(keep, sorted_e * c + rank, e * c)         # overflow slot
    src_tok = order // k                                      # (B, S*k)

    rows = jnp.arange(b)[:, None]
    xsrc = jnp.take_along_axis(x, src_tok[..., None], axis=1)  # (B, S*k, D)
    buf = jnp.zeros((b, e * c + 1, d), x.dtype).at[rows, dst].set(
        jnp.where(keep[..., None], xsrc, 0))
    buf = buf[:, : e * c].reshape(b, e, c, d)

    # ---- expert compute (batched einsum over the EP axis) -------------------
    def _apply(p, h):
        if is_quantized(p):
            return engine_apply(p, h, eng)
        return jnp.matmul(h, p.astype(h.dtype))  # (B,E,C,·) @ (E,·,·)

    def expert_ff(h):
        gate = _apply(params["w_gate"], h)
        up = _apply(params["w_up"], h)
        return _apply(params["w_down"], jax.nn.silu(gate) * up)

    if EP_DISPATCH == "a2a":
        # pin the exchange: rows-sharded (local scatter result) -> experts-
        # sharded (one all-to-all, bf16 wire) -> compute -> back to rows-
        # sharded (one all-to-all) so the combine below is communication-free.
        buf = with_hint(buf.astype(x.dtype), None, "model", None, None)
        out4 = expert_ff(buf).astype(x.dtype)
        out4 = with_hint(out4, ("pod", "data"), None, None, None)
    else:
        buf = shard_experts(buf)
        out4 = shard_experts(expert_ff(buf))
    out_buf = out4.reshape(b, e * c, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((b, 1, d), x.dtype)], axis=1)

    # ---- combine (row-local: out_buf and x share row sharding) --------------
    gathered = jnp.take_along_axis(out_buf, dst[..., None], axis=1)
    gathered = gathered * (jnp.take_along_axis(flat_g, order, axis=-1)
                           * keep)[..., None].astype(x.dtype)
    y = jnp.zeros((b, s, d), x.dtype).at[rows, src_tok].add(gathered)
    y = with_hint(y, ("pod", "data"), None, None)

    if "shared" in params:
        y = y + swiglu(params["shared"], x, eng)
    if squeeze:
        y = y[0]
    return y, aux
