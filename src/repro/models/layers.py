"""Shared building blocks: norms, RoPE, linears (dense or IMAGine-engine),
SwiGLU MLP, embeddings.

Every matmul in the zoo goes through :func:`dense`, which dispatches between
a plain matrix and the engine's :class:`~repro.engine.PackedLinear` format —
this is how the paper's GEMV engine becomes a first-class, model-agnostic
serving feature.  Engine dispatch is an :class:`~repro.engine.EnginePlan`
(resolved once from :class:`EngineConfig` by the caller and threaded down);
``eng`` arguments still accept a raw ``EngineConfig`` for back-compat and
are normalized through the memoized ``as_plan``.

Mesh-native dispatch needs no extra threading here: a plan resolved with a
mesh (``resolve_plan(cfg, mesh=...)`` + ``EngineConfig.sharded``) carries
the mesh inside it, so the same ``dense(p, x, eng)`` call sites shard_map
their GEMVs over the model axis (see ``docs/sharding.md``).
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.config.base import EngineConfig
from repro.engine import EnginePlan, as_packed, as_plan, is_packed, plan_for_bits

Engine = Optional[Union[EngineConfig, EnginePlan]]


# ---------------------------------------------------------------------------
# linear: plain or engine-quantized
# ---------------------------------------------------------------------------


def is_quantized(p) -> bool:
    return is_packed(p)


def engine_apply(p, x: jnp.ndarray, eng: Engine) -> jnp.ndarray:
    """IMAGine engine forward for a packed linear (DEPRECATED shim name —
    new code calls ``plan.apply(lin, x)`` directly).

    Accepts ``PackedLinear`` or the legacy ``{"packed", "scale"}`` dict;
    the weight's own ``bits`` is authoritative.  Bytes read from "HBM" are
    ``bits/8`` per weight on every backend — the roofline-relevant property
    of the engine.
    """
    plan = as_plan(eng)
    lin = as_packed(p, bits_hint=plan.bits if plan else None)
    if plan is None:
        # packed weights but no engine config: dispatch at the weight's own
        # precision on the auto backend (no silent bits=8 fallback).
        plan = plan_for_bits(lin.bits)
    return plan.apply(lin, x)


def dense(p, x: jnp.ndarray, eng: Engine = None) -> jnp.ndarray:
    """y = x @ W with optional bias; W may be engine-packed."""
    if is_quantized(p):
        return engine_apply(p, x, eng)  # plan applies the bias itself
    if isinstance(p, dict):
        w, bias = p["w"], p.get("bias")
    else:
        w, bias = p, None
    y = jnp.matmul(x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rms_norm_gated(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    """Mamba2's gated RMSNorm: norm(x) * silu(z)."""
    return rms_norm(x, scale, eps) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)


def swiglu(p: dict, x: jnp.ndarray, eng: Optional[EngineConfig] = None) -> jnp.ndarray:
    if "w_gate" not in p:  # plain GELU MLP (starcoder2-style)
        return dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], x, eng)), eng)
    gate = dense(p["w_gate"], x, eng)
    up = dense(p["w_up"], x, eng)
    return dense(p["w_down"], jax.nn.silu(gate) * up, eng)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)
    if bias:
        return {"w": w, "bias": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def init_embedding(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
