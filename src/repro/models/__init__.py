"""Model zoo: a generic decoder-family LM covering all 10 assigned archs.

Families: dense (gemma3 / mistral-large / starcoder2 / qwen2.5), vlm (llava),
audio (musicgen), moe (llama4-scout / qwen3-moe), ssm (mamba2), hybrid
(zamba2).  All built from the same functional blocks with scan-over-layers
so HLO size is O(1) in depth.
"""

from repro.models.transformer import (
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    prefill_chunk,
    quantize_params,
)

__all__ = [
    "decode_step",
    "decode_step_paged",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
    "prefill_chunk",
    "quantize_params",
]
