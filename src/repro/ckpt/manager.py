"""Checkpoint lifecycle: rotation, async save, auto-resume."""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax

from repro.ckpt.checkpoint import latest_step, load_checkpoint, save_checkpoint


class CheckpointManager:
    """Rotating checkpoints with optional async (background-thread) save.

    Async saves first device_get the tree synchronously (cheap host copy,
    keeps a consistent snapshot) then compress+write off-thread so the step
    loop never blocks on disk — the standard large-run recipe.
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 host_id: int = 0, n_hosts: int = 1):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        snapshot = jax.tree.map(lambda x: jax.device_get(x), tree)

        def work():
            save_checkpoint(self.directory, step, snapshot,
                            host_id=self.host_id, n_hosts=self.n_hosts,
                            extra=extra)
            self._rotate()

        if self.async_save:
            self.wait()
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def restore_latest(self, template):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, extra = load_checkpoint(self.directory, template, step)
        return step, tree, extra

    # ---------------------------------------------------------------- rotate
    def _rotate(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and
            os.path.exists(os.path.join(self.directory, n, "COMMITTED"))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
