from repro.ckpt.checkpoint import load_checkpoint, save_checkpoint
from repro.ckpt.manager import CheckpointManager

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]
