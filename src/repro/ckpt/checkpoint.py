"""Sharded, integrity-checked checkpoints (no orbax dependency).

Format: one directory per step:
    step_000042/
      manifest.json     — tree structure, shapes, dtypes, per-leaf blake2b,
                          shard layout, framework metadata
      shard_<h>.bin     — zstd-compressed concatenation of this host's leaves

On a real multi-host cluster each host writes only the leaves (or leaf
slices) it owns (``host_id``/``n_hosts`` sharding of the leading axis when
``shard_leaves`` is on); here the single-process tests exercise the same
code path with n_hosts=1 and a simulated multi-host roundtrip.

Writes are atomic (tmp dir + rename) so a failure mid-save never corrupts
the latest complete checkpoint — the restart path of the fault-tolerance
drill relies on this.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard
except ImportError:  # container image may not ship zstandard
    zstandard = None


class _NullCompressor:
    """Identity codec used when zstandard is unavailable.

    Shards are written raw (bigger on disk, same manifest/checksum
    integrity); ``codec`` is recorded in the manifest so a zstd-equipped
    reader still decodes both formats.
    """

    def compress(self, data: bytes) -> bytes:
        return data

    def decompress(self, data: bytes) -> bytes:
        return data


def _compressor():
    return (zstandard.ZstdCompressor(level=3) if zstandard is not None
            else _NullCompressor())


def _decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise ImportError(
                "checkpoint was written zstd-compressed but zstandard is "
                "not installed")
        return zstandard.ZstdDecompressor()
    if codec == "raw":
        return _NullCompressor()
    raise ValueError(f"unknown checkpoint codec {codec!r} "
                     "(expected 'zstd' or 'raw')")


Pytree = Any

_MAGIC = "repro-imagine-ckpt-v1"


def _leaf_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(
    directory: str,
    step: int,
    tree: Pytree,
    *,
    host_id: int = 0,
    n_hosts: int = 1,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Write checkpoint; returns final path.  Atomic per host."""
    paths, leaves, _ = _leaf_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{host_id}"
    os.makedirs(tmp, exist_ok=True)

    manifest = {"magic": _MAGIC, "step": step, "n_hosts": n_hosts,
                "codec": "zstd" if zstandard is not None else "raw",
                "extra": extra or {}, "leaves": []}
    cctx = _compressor()
    blob = bytearray()
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        if i % n_hosts != host_id:
            owner = i % n_hosts
            manifest["leaves"].append({"path": p, "owner": owner})
            continue
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        manifest["leaves"].append({
            "path": p,
            "owner": host_id,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "offset": len(blob),
            "nbytes": len(raw),
            "blake2b": hashlib.blake2b(raw, digest_size=16).hexdigest(),
        })
        blob.extend(raw)
    with open(os.path.join(tmp, f"shard_{host_id}.bin"), "wb") as f:
        f.write(cctx.compress(bytes(blob)))
    with open(os.path.join(tmp, f"manifest_{host_id}.json"), "w") as f:
        json.dump(manifest, f)

    # host 0 finalizes: merge per-host tmp dirs into the final directory
    if host_id == 0:
        os.makedirs(final, exist_ok=True)
        for h in range(n_hosts):
            hdir = final + f".tmp-{h}"
            if not os.path.isdir(hdir):
                continue
            for name in os.listdir(hdir):
                shutil.move(os.path.join(hdir, name), os.path.join(final, name))
            os.rmdir(hdir)
        # mark complete
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write(_MAGIC)
    return final


def load_checkpoint(
    directory: str,
    template: Pytree,
    step: Optional[int] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore into the structure of ``template`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    final = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(final, "COMMITTED")):
        raise FileNotFoundError(f"checkpoint {final} not committed")

    manifests = {}
    for name in os.listdir(final):
        if name.startswith("manifest_"):
            with open(os.path.join(final, name)) as f:
                m = json.load(f)
            assert m["magic"] == _MAGIC
            manifests[int(name.split("_")[1].split(".")[0])] = m

    paths, leaves, treedef = _leaf_paths(template)
    by_path: Dict[str, Tuple[int, dict]] = {}
    for h, m in manifests.items():
        for entry in m["leaves"]:
            if "offset" in entry:
                by_path[entry["path"]] = (h, entry)

    blobs = {}
    for h in manifests:
        dctx = _decompressor(manifests[h].get("codec", "zstd"))
        with open(os.path.join(final, f"shard_{h}.bin"), "rb") as f:
            blobs[h] = dctx.decompress(f.read())

    out = []
    for p, leaf in zip(paths, leaves):
        h, entry = by_path[p]
        raw = blobs[h][entry["offset"] : entry["offset"] + entry["nbytes"]]
        digest = hashlib.blake2b(raw, digest_size=16).hexdigest()
        if digest != entry["blake2b"]:
            raise IOError(f"checksum mismatch for {p} in step {step}")
        arr = np.frombuffer(raw, dtype=entry["dtype"]).reshape(entry["shape"])
        tmpl = np.asarray(leaf)
        if tuple(arr.shape) != tmpl.shape:
            raise ValueError(f"{p}: ckpt shape {arr.shape} != template {tmpl.shape}")
        out.append(arr.astype(tmpl.dtype) if str(tmpl.dtype) != entry["dtype"] else arr)
    extra = manifests[min(manifests)]["extra"]
    return jax.tree_util.tree_unflatten(treedef, out), extra


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None
