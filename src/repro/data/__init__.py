from repro.data.pipeline import DataPipeline, synthetic_batch_specs

__all__ = ["DataPipeline", "synthetic_batch_specs"]
