"""Deterministic, shardable data pipeline.

Production posture: each host reads only its shard of the token stream
(``host_id``/``n_hosts``), prefetches ahead of the step loop on a background
thread, and the stream position is part of the checkpoint so restarts are
bit-exact.  Sources: ``synthetic`` (seeded LCG token stream — used by every
example and test) and ``memmap`` (a binary token file).

The pipeline yields the exact batch dict the model's ``forward`` expects per
family (tokens/labels, plus stub modality inputs for vlm/audio).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config.base import ModelConfig


@dataclass
class PipelineState:
    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


class DataPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq_len: int,
        seed: int = 0,
        host_id: int = 0,
        n_hosts: int = 1,
        source: str = "synthetic",
        path: Optional[str] = None,
        prefetch: int = 2,
    ):
        assert batch % n_hosts == 0, (batch, n_hosts)
        self.cfg = cfg
        self.batch = batch // n_hosts      # per-host batch
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = PipelineState()
        self._data = None
        if source == "memmap":
            assert path is not None
            self._data = np.memmap(path, dtype=np.int32, mode="r")
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None

    # ------------------------------------------------------------- batches
    def _tokens_for_step(self, step: int) -> np.ndarray:
        """Deterministic tokens for (step, host): restart-safe."""
        n = self.batch * (self.seq_len + 1)
        if self._data is not None:
            start = (step * self.n_hosts + self.host_id) * n % max(
                1, len(self._data) - n
            )
            flat = np.asarray(self._data[start : start + n])
        else:
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 4096 + self.host_id
            )
            flat = rng.integers(
                0, self.cfg.vocab_size, size=n, dtype=np.int32
            )
        return flat.reshape(self.batch, self.seq_len + 1)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._tokens_for_step(step)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if self.cfg.family == "audio":
            k = self.cfg.n_codebooks
            rng = np.random.default_rng(self.seed * 7 + step)
            full = rng.integers(
                0, self.cfg.vocab_size,
                size=(self.batch, self.seq_len + 1, k), dtype=np.int32)
            batch["tokens"], batch["labels"] = full[:, :-1], full[:, 1:]
        elif self.cfg.family == "vlm":
            rng = np.random.default_rng(self.seed * 13 + step)
            batch["img_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.img_tokens, self.cfg.d_model)
            ).astype(np.float32)
        return batch

    # ------------------------------------------------------------ iterator
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # ----------------------------------------------------------- prefetch
    def start_prefetch(self):
        if self._thread is not None:
            return
        self._exc = None

        def worker():
            try:
                while not self._stop.is_set():
                    # generate exactly once, then retry the *same* batch
                    # while the queue is full — putting next(self) inside
                    # the retry would advance the step counter and drop
                    # the batch on every Full, silently skipping data
                    b = next(self)
                    while not self._stop.is_set():
                        try:
                            self._q.put(b, timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:  # surfaces via get_prefetched
                self._exc = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def get_prefetched(self, timeout: float = 10.0):
        """Next prefetched batch; re-raises anything the worker died on
        (a dead worker would otherwise present as an eternal Empty)."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self._q.get(timeout=0.05)
            except queue.Empty:
                if self._exc is not None:
                    raise RuntimeError(
                        "prefetch worker failed") from self._exc
                if time.monotonic() >= deadline:
                    raise

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
            self._thread = None


def synthetic_batch_specs(cfg: ModelConfig, batch: int, seq_len: int):
    """Shape/dtype dict matching batch_at (for dry-run input_specs)."""
    import jax.numpy as jnp
    import jax

    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if cfg.family == "audio":
        k = cfg.n_codebooks
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq_len, k), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq_len, k), jnp.int32)
    elif cfg.family == "vlm":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.img_tokens, cfg.d_model), jnp.float32)
    return specs
