"""Public wrappers for the fused paged-attention kernels (decode and
chunked prefill).

Accept the model layouts (decode ``q`` as ``(B, 1, Hq, Dh)``, prefill
``q`` as ``(B, C, Hq, Dh)``, pools as ``(P, page, Hkv, Dh)``) plus an
*attention backend name* — models/, serve/ and benchmarks/ never decide
interpret booleans themselves (the EnginePlan hygiene rule); the name →
interpret mapping lives here, next to the kernel.  A ``mesh`` routes the
call through ``repro.engine.sharded``'s shard_map wrapper (KV heads over
the plan's model axis — the pool is already placed that way).

:func:`decode_attn_bytes` / :func:`prefill_attn_bytes` — the bytes-moved
models the attention benchmarks and the micro-bench derived columns
share — are re-exported from :mod:`repro.obs.costs`, the one analytic
cost model the serve-path ledger, the roofline summary and every
benchmark now price against: the fused kernels read each pool page
exactly once per (lane, kv head) while the gather backend pays
pool-read + view-write + view-read for the same logical view.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_pallas,
    paged_prefill_pallas,
)
from repro.obs.costs import (  # noqa: F401  (re-export: THE bytes model)
    decode_attn_bytes,
    prefill_attn_bytes,
)

PREFILL_BLOCK_Q = 128  # cap on query rows per prefill grid step


def paged_attention(
    q: jnp.ndarray,            # (B, 1, Hq, Dh) — model decode layout
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    cur_pos: jnp.ndarray,      # (B,)
    window=0,                  # python int or traced scalar; <= 0 = full
    k_scale: Optional[jnp.ndarray] = None,  # (P, page, Hkv) int8 pools only
    v_scale: Optional[jnp.ndarray] = None,
    *,
    attn_backend: str = "pallas_interpret",
    mesh=None,
    model_axis: str = "model",
) -> jnp.ndarray:
    """Fused in-place paged decode attention; returns ``(B, 1, Hq, Dh)``.

    ``attn_backend`` must be one of the kernel-backed names
    (``pallas_interpret`` / ``pallas_tpu``); the ``gather`` reference path
    lives in ``repro.models.attention.attend_paged_decode``.  ``mesh``
    shard_maps the kernel over ``model_axis`` (per-shard head slices; see
    ``repro.engine.sharded.sharded_paged_attention``).
    """
    if attn_backend not in ("pallas_interpret", "pallas_tpu"):
        raise ValueError(
            f"paged_attention runs the fused kernel only "
            f"(pallas_interpret/pallas_tpu); got {attn_backend!r}")
    b, _, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    interpret = attn_backend == "pallas_interpret"
    if mesh is not None:
        from repro.engine.sharded import sharded_paged_attention

        out = sharded_paged_attention(
            mesh, model_axis, qg, k_pages, v_pages, block_tables,
            cur_pos, win, k_scale, v_scale, interpret=interpret)
    else:
        out = paged_attention_pallas(
            qg, k_pages, v_pages, block_tables, cur_pos, win,
            k_scale, v_scale, interpret=interpret)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def paged_prefill_attention(
    q: jnp.ndarray,            # (B, C, Hq, Dh) — model prefill layout
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    pos0: jnp.ndarray,         # (B,) tokens already resident per lane
    seq_lens: jnp.ndarray,     # (B,) total valid after this chunk
    window=0,                  # python int or traced scalar; <= 0 = full
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    *,
    attn_backend: str = "pallas_interpret",
    mesh=None,
    model_axis: str = "model",
) -> jnp.ndarray:
    """Fused in-place paged chunked-prefill attention; ``(B, C, Hq, Dh)``.

    The chunk's K/V must already be scattered into the pool (the kernel
    only reads).  Lane ``b``'s queries cover logical positions
    ``[pos0[b], pos0[b]+C)``; causal + suffix-validity masking happens in
    the kernel against the scalar-prefetched ``pos0`` / ``seq_lens``, so
    prefix-cache suffix-only prefill (``pos0`` mid-page included) needs no
    gathered view.  The chunk axis is padded to a ``block_q`` multiple
    in here; padded rows are sliced off before returning.
    """
    if attn_backend not in ("pallas_interpret", "pallas_tpu"):
        raise ValueError(
            f"paged_prefill_attention runs the fused kernel only "
            f"(pallas_interpret/pallas_tpu); got {attn_backend!r}")
    b, c, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    block_q = min(c, PREFILL_BLOCK_Q)
    cp = -(-c // block_q) * block_q
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 1, 3, 4)
    if cp != c:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, cp - c), (0, 0), (0, 0)))
    win = jnp.asarray(window, jnp.int32).reshape(1)
    interpret = attn_backend == "pallas_interpret"
    if mesh is not None:
        from repro.engine.sharded import sharded_paged_attention

        out = sharded_paged_attention(
            mesh, model_axis, qg, k_pages, v_pages, block_tables,
            pos0, win, k_scale, v_scale, interpret=interpret,
            prefill=dict(seq_lens=seq_lens, chunk=c, block_q=block_q))
    else:
        out = paged_prefill_pallas(
            qg, k_pages, v_pages, block_tables, pos0, seq_lens, win,
            k_scale, v_scale, chunk=c, block_q=block_q,
            interpret=interpret)
    out = out[:, :, :c].transpose(0, 2, 1, 3, 4)
    return out.reshape(b, c, hq, d).astype(q.dtype)


def synthetic_paged_case(rng, *, batch: int, nblk: int, page: int,
                         hkv: int, group: int, dh: int, kv_bits: int):
    """One synthetic (q, pools, block tables) decode case — the shared
    fixture of ``benchmarks/attn_bench.py`` and the paged rows of
    ``benchmarks/kernel_bench.py``, so both benches measure identical
    inputs.  ``rng``: a ``numpy.random.Generator``.  Returns a dict with
    ``q / k_pages / v_pages / k_scale / v_scale / block_tables``
    (scales None unless ``kv_bits``); block tables are a permutation of
    ``batch * nblk`` distinct non-null pages."""
    import numpy as np

    n_pages = batch * nblk + 1
    if kv_bits:
        kp = jnp.asarray(rng.integers(-127, 128, (n_pages, page, hkv, dh)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (n_pages, page, hkv, dh)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.004, 0.02, (n_pages, page, hkv)),
                         jnp.bfloat16)
        vs = jnp.asarray(rng.uniform(0.004, 0.02, (n_pages, page, hkv)),
                         jnp.bfloat16)
    else:
        kp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, dh))
                         .astype(np.float32))
        vp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, dh))
                         .astype(np.float32))
        ks = vs = None
    return {
        "q": jnp.asarray(rng.standard_normal((batch, 1, hkv * group, dh))
                         .astype(np.float32)),
        "k_pages": kp,
        "v_pages": vp,
        "k_scale": ks,
        "v_scale": vs,
        "block_tables": jnp.asarray(
            1 + rng.permutation(batch * nblk).reshape(batch, nblk),
            jnp.int32),
    }


def synthetic_prefill_case(rng, *, batch: int, nblk: int, page: int,
                           hkv: int, group: int, dh: int, chunk: int,
                           kv_bits: int):
    """A synthetic chunked-prefill case on top of :func:`synthetic_paged_case`
    pools: every lane has ``pos0`` tokens already resident (mid-page — not
    page-aligned — for ragged coverage) and prefills ``chunk`` more, the
    last lane's chunk ending short of the chunk boundary (``seq_lens <
    pos0 + chunk``).  The chunk's K/V is treated as already scattered: the
    pools hold all positions, exactly what both read paths see."""
    import numpy as np

    case = synthetic_paged_case(rng, batch=batch, nblk=nblk, page=page,
                                hkv=hkv, group=group, dh=dh,
                                kv_bits=kv_bits)
    t = nblk * page
    pos0 = np.minimum(np.maximum(t - chunk - 1, 0),
                      rng.integers(1, max(2, t - chunk + 1), (batch,)))
    seq = pos0 + chunk
    if batch > 1:
        seq[-1] = pos0[-1] + max(1, chunk - 1)  # ragged last lane
    case["q"] = jnp.asarray(
        rng.standard_normal((batch, chunk, hkv * group, dh))
        .astype(np.float32))
    case["pos0"] = jnp.asarray(pos0, jnp.int32)
    case["seq_lens"] = jnp.asarray(seq, jnp.int32)
    return case


