"""Public wrapper for the fused paged-attention decode kernel.

Accepts the model's decode layout (``q`` as ``(B, 1, Hq, Dh)``, pools as
``(P, page, Hkv, Dh)``) plus an *attention backend name* — models/, serve/
and benchmarks/ never decide interpret booleans themselves (the EnginePlan
hygiene rule); the name → interpret mapping lives here, next to the kernel.

Also home of :func:`decode_attn_bytes`, the bytes-moved model the attention
benchmarks and the micro-bench derived columns share: the fused kernel
reads each pool page exactly once per (lane, kv head) while the gather
backend pays pool-read + view-write + view-read for the same logical view.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas


def paged_attention(
    q: jnp.ndarray,            # (B, 1, Hq, Dh) — model decode layout
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    cur_pos: jnp.ndarray,      # (B,)
    window=0,                  # python int or traced scalar; <= 0 = full
    k_scale: Optional[jnp.ndarray] = None,  # (P, page, Hkv) int8 pools only
    v_scale: Optional[jnp.ndarray] = None,
    *,
    attn_backend: str = "pallas_interpret",
) -> jnp.ndarray:
    """Fused in-place paged decode attention; returns ``(B, 1, Hq, Dh)``.

    ``attn_backend`` must be one of the kernel-backed names
    (``pallas_interpret`` / ``pallas_tpu``); the ``gather`` reference path
    lives in ``repro.models.attention.attend_paged_decode``.
    """
    if attn_backend not in ("pallas_interpret", "pallas_tpu"):
        raise ValueError(
            f"paged_attention runs the fused kernel only "
            f"(pallas_interpret/pallas_tpu); got {attn_backend!r}")
    b, _, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    out = paged_attention_pallas(
        qg, k_pages, v_pages, block_tables, cur_pos, win,
        k_scale, v_scale,
        interpret=(attn_backend == "pallas_interpret"))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def synthetic_paged_case(rng, *, batch: int, nblk: int, page: int,
                         hkv: int, group: int, dh: int, kv_bits: int):
    """One synthetic (q, pools, block tables) decode case — the shared
    fixture of ``benchmarks/attn_bench.py`` and the paged rows of
    ``benchmarks/kernel_bench.py``, so both benches measure identical
    inputs.  ``rng``: a ``numpy.random.Generator``.  Returns a dict with
    ``q / k_pages / v_pages / k_scale / v_scale / block_tables``
    (scales None unless ``kv_bits``); block tables are a permutation of
    ``batch * nblk`` distinct non-null pages."""
    import numpy as np

    n_pages = batch * nblk + 1
    if kv_bits:
        kp = jnp.asarray(rng.integers(-127, 128, (n_pages, page, hkv, dh)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (n_pages, page, hkv, dh)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.004, 0.02, (n_pages, page, hkv)),
                         jnp.bfloat16)
        vs = jnp.asarray(rng.uniform(0.004, 0.02, (n_pages, page, hkv)),
                         jnp.bfloat16)
    else:
        kp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, dh))
                         .astype(np.float32))
        vp = jnp.asarray(rng.standard_normal((n_pages, page, hkv, dh))
                         .astype(np.float32))
        ks = vs = None
    return {
        "q": jnp.asarray(rng.standard_normal((batch, 1, hkv * group, dh))
                         .astype(np.float32)),
        "k_pages": kp,
        "v_pages": vp,
        "k_scale": ks,
        "v_scale": vs,
        "block_tables": jnp.asarray(
            1 + rng.permutation(batch * nblk).reshape(batch, nblk),
            jnp.int32),
    }


def decode_attn_bytes(
    backend: str,
    *,
    batch: int,
    context: int,
    n_kv_heads: int,
    head_dim: int,
    n_q_heads: int,
    page_size: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> int:
    """Modeled HBM bytes moved by ONE layer's decode-attention read path.

    ``gather`` (the reference backend) materializes the logical KV view
    before attending — per K and per V it pays pool read + view write +
    view read (3× the view), and the int8 path pays the same 3× for each
    scale pool.  The fused kernel (``pallas_interpret`` / ``pallas_tpu``)
    reads each mapped page exactly once per (lane, kv head) and never
    writes an intermediate: 1× the view (+ 1× scales), plus the block
    table itself.  Q read and O write are identical on both paths and
    included for honest totals.
    """
    import math

    kv_isz = 1 if kv_bits else act_itemsize
    n_blocks = max(1, math.ceil(context / page_size))
    view = batch * n_blocks * page_size * n_kv_heads * head_dim * kv_isz
    scale_view = (batch * n_blocks * page_size * n_kv_heads * 2
                  if kv_bits else 0)  # bf16 scales
    qo = 2 * batch * n_q_heads * head_dim * act_itemsize  # Q read + O write
    tables = batch * n_blocks * 4                         # int32 block table
    if backend == "gather":
        return 2 * 3 * view + 2 * 3 * scale_view + qo + tables
    if backend in ("pallas_interpret", "pallas_tpu"):
        return 2 * view + 2 * scale_view + qo + tables
    raise ValueError(f"unknown attention backend {backend!r}")
