"""Paged attention Pallas kernels (decode + chunked prefill): read KV
pages *in place*.

The gather-then-attend read path (``models.attention.gather_kv_pages`` +
``attend_decode``) materializes every lane's full logical KV view —
``(B, n_blocks·page, Hkv, Dh)`` per layer, ×2 for K/V, ×2 again for the
scale pools on the int8 path — before a single score is computed, so HBM
traffic per decode token is ~3× the logical view (pool read + view write +
view read).  This kernel is the compute-in-place fix, the serving-side twin
of the paper's GEMV-at-BRAM-speed argument: the **block table drives the
K/V BlockSpec index maps** (scalar-prefetched, so the page id is known
before the DMA is issued), pages stream VMEM-ward exactly once per
(lane, kv head), and scores / running softmax statistics / the output
accumulator never leave VMEM.

Structure (same online-softmax pattern as ``kernels.flash_attention``):

* grid ``(B, Hkv, n_blocks)`` with the block-table walk innermost; the
  output block is revisited across that sweep and the (m, l) running
  statistics live in VMEM scratch.
* GQA rides in the Q layout: queries arrive as ``(B, Hkv, G, Dh)`` so one
  grid step attends all ``G = Hq // Hkv`` query heads of its KV head
  against one page — the K/V block is ``(1, page, 1, Dh)`` of the pool,
  indexed ``(block_tables[b, i], 0, h, 0)``.
* causal + sliding-window bounds are computed from the block index and the
  scalar-prefetched ``cur_pos`` / ``window`` — no mask tensors exist
  anywhere, and ``window`` stays a *runtime* scalar so one compiled kernel
  serves every layer of a local/global stack under ``lax.scan``.
* ``kv_bits=8`` pools dequantize in VMEM by folding the scale pools into
  the probabilities (``scores·s_k[t]``, ``p·s_v[t]`` — the same math as
  ``attend_decode_quant``), so the pool bytes stay 1 byte/element all the
  way to the MXU.

:func:`paged_prefill_pallas` is the chunked-prefill twin: grid
``(B, Hkv, q_blocks, kv_blocks)`` with the block-table walk innermost, the
whole query chunk riding as ``(block_q, G)`` rows per step, and the
per-lane chunk offsets (``pos0`` — tokens already resident from earlier
chunks or a prefix-cache hit, ``seq_lens`` — total valid after this chunk)
as scalar-prefetch operands, so causal masking over the unmatched suffix
happens against *logical* positions while K/V still stream straight from
pool pages.  This is what lets ``models.transformer.prefill_chunk`` stop
materializing the gathered ``(B, T, Hkv, Dh)`` view per layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _body(bt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
          ks_ref, vs_ref, o_ref, m_ref, l_ref, *,
          scale: float, page: int, n_blocks: int, quant: bool):
    """One (lane, kv-head, logical-block) step of the online softmax.

    ``ks_ref`` / ``vs_ref`` are the scale-pool blocks (None when the pool
    is full precision).  ``o_ref`` is revisited across the innermost grid
    dimension (the block-table walk); the running (m, l) statistics live
    in VMEM scratch and never touch HBM."""
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if quant:
        # int8 page → bf16 is exact (|q| <= 127 fits the 8-bit mantissa);
        # mirrors attend_decode_quant so kv_bits=8 stays one dispatch
        q = q_ref[0, 0].astype(jnp.bfloat16).astype(jnp.float32)   # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.bfloat16).astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.bfloat16).astype(jnp.float32)
    else:
        # mirror the gather path's storage-dtype rounding (attend_decode
        # casts q to the cache dtype before the contraction): exact
        # identity for f32 pools, same-ulp agreement for bf16 pools
        q = q_ref[0, 0].astype(k_ref.dtype).astype(jnp.float32)    # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if quant:
        s = s * ks_ref[0, :, 0].astype(jnp.float32)[None, :]

    # causal + sliding-window bounds from the block index: logical position
    # of pool row t in this block is i*page + t
    kv_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    cur = pos_ref[b]
    win = win_ref[0]
    mask = kv_pos <= cur
    mask = jnp.logical_and(
        mask, jnp.where(win > 0, kv_pos > cur - win, True))
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_ref[0]                                               # (G,)
    l_old = l_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])                                # (G, page)
    l_new = l_old * corr + jnp.sum(p, axis=-1)
    if quant:
        p = p * vs_ref[0, :, 0].astype(jnp.float32)[None, :]
    else:
        # p·v in the pool's storage dtype, as attend_decode (and the
        # pure-jnp attend_flash) cast the probabilities before the dot
        p = p.astype(v_ref.dtype).astype(jnp.float32)
    o_new = o_ref[0, 0] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(i == n_blocks - 1)
    def _final():
        o_ref[0, 0] = o_new / jnp.maximum(l_new, 1e-30)[:, None]

    @pl.when(i < n_blocks - 1)
    def _accum():
        o_ref[0, 0] = o_new


def _kernel_quant(bt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
                  ks_ref, vs_ref, o_ref, m_ref, l_ref, **kw):
    _body(bt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
          ks_ref, vs_ref, o_ref, m_ref, l_ref, quant=True, **kw)


def _kernel_full(bt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
                 o_ref, m_ref, l_ref, **kw):
    _body(bt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref,
          None, None, o_ref, m_ref, l_ref, quant=False, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jnp.ndarray,            # (B, Hkv, G, Dh) — grouped query layout
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh) — one layer's pool
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    cur_pos: jnp.ndarray,      # (B,) int32 position of the newest token
    window: jnp.ndarray,       # (1,) int32 (runtime scalar; <= 0 = full)
    k_scale=None,              # (P, page, Hkv) — int8 pools only
    v_scale=None,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused paged decode attention; returns ``(B, Hkv, G, Dh)`` float32.

    The block table and the masking scalars travel as scalar-prefetch
    operands (``pltpu.PrefetchScalarGridSpec``): index maps see them before
    the grid step's DMAs are issued, which is what lets the K/V BlockSpecs
    address pool pages directly — the gathered copy never exists.
    """
    b, hkv, g, d = q.shape
    page = k_pages.shape[1]
    n_blocks = block_tables.shape[1]
    scale = d ** -0.5
    quant = k_scale is not None

    def _at_page(bb, h, i, bt, pos, win):
        return (bt[bb, i], 0, h, 0)

    def _at_scale(bb, h, i, bt, pos, win):
        return (bt[bb, i], 0, h)

    def _at_q(bb, h, i, bt, pos, win):
        return (bb, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), _at_q),
        pl.BlockSpec((1, page, 1, d), _at_page),
        pl.BlockSpec((1, page, 1, d), _at_page),
    ]
    operands = [q, k_pages, v_pages]
    kernel = _kernel_full
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _at_scale),
                     pl.BlockSpec((1, page, 1), _at_scale)]
        operands += [k_scale, v_scale]
        kernel = _kernel_quant

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), _at_q),
        # running (max, sumexp) stay in VMEM across the block-table walk —
        # they are softmax bookkeeping, not results, and never touch HBM
        scratch_shapes=[
            pltpu.VMEM((1, g), jnp.float32),
            pltpu.VMEM((1, g), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel, scale=scale, page=page,
                          n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), cur_pos.astype(jnp.int32),
      window, *operands)


# ---------------------------------------------------------------------------
# chunked-prefill mode: query blocks × KV blocks
# ---------------------------------------------------------------------------


def _prefill_body(bt_ref, pos0_ref, seq_ref, win_ref, q_ref, k_ref, v_ref,
                  ks_ref, vs_ref, o_ref, m_ref, l_ref, *,
                  scale: float, page: int, n_blocks: int, block_q: int,
                  group: int, chunk: int, quant: bool):
    """One (lane, kv-head, q-block, logical-kv-block) step.

    The query block carries ``block_q`` chunk positions × ``group`` GQA
    heads flattened to ``R = block_q·G`` rows; row ``r`` is chunk offset
    ``r // G``, so its logical position is ``pos0[b] + iq·block_q + r//G``.
    Valid KV for a row is the causal range below that position clipped to
    ``limit = min(seq_lens[b], pos0[b] + chunk)`` — exactly the
    ``kv_valid`` mask of the gather path (padded queries beyond ``limit``
    still attend the lane's valid prefix, matching ``attend_dense``)."""
    bb = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    r = block_q * group

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if quant:
        q = q_ref[0, 0].reshape(r, -1).astype(jnp.bfloat16)
        q = q.astype(jnp.float32)                          # (R, D)
        k = k_ref[0, :, 0, :].astype(jnp.bfloat16).astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.bfloat16).astype(jnp.float32)
    else:
        # attend_dense upcasts q and the gathered K/V straight to f32
        q = q_ref[0, 0].reshape(r, -1).astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if quant:
        s = s * ks_ref[0, :, 0].astype(jnp.float32)[None, :]

    # logical positions: query row r sits at pos0 + iq*block_q + r//G,
    # pool row t of this block at ik*page + t
    qi = jax.lax.broadcasted_iota(jnp.int32, (r, page), 0) // group
    q_pos = pos0_ref[bb] + iq * block_q + qi
    kv_pos = ik * page + jax.lax.broadcasted_iota(jnp.int32, (r, page), 1)
    limit = jnp.minimum(seq_ref[bb], pos0_ref[bb] + chunk)
    win = win_ref[0]
    mask = jnp.logical_and(kv_pos <= q_pos, kv_pos < limit)
    mask = jnp.logical_and(
        mask, jnp.where(win > 0, kv_pos > q_pos - win, True))
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_ref[0]                                       # (R,)
    l_old = l_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])                        # (R, page)
    l_new = l_old * corr + jnp.sum(p, axis=-1)
    if quant:
        p = p * vs_ref[0, :, 0].astype(jnp.float32)[None, :]
        p = p.astype(jnp.bfloat16).astype(jnp.float32)
    else:
        p = p.astype(v_ref.dtype).astype(jnp.float32)
    o_old = o_ref[0, 0].reshape(r, -1)
    o_new = o_old * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(ik == n_blocks - 1)
    def _final():
        o_ref[0, 0] = (o_new / jnp.maximum(l_new, 1e-30)[:, None]).reshape(
            o_ref.shape[2:])

    @pl.when(ik < n_blocks - 1)
    def _accum():
        o_ref[0, 0] = o_new.reshape(o_ref.shape[2:])


def _pf_kernel_quant(bt_ref, pos0_ref, seq_ref, win_ref, q_ref, k_ref,
                     v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref, **kw):
    _prefill_body(bt_ref, pos0_ref, seq_ref, win_ref, q_ref, k_ref, v_ref,
                  ks_ref, vs_ref, o_ref, m_ref, l_ref, quant=True, **kw)


def _pf_kernel_full(bt_ref, pos0_ref, seq_ref, win_ref, q_ref, k_ref,
                    v_ref, o_ref, m_ref, l_ref, **kw):
    _prefill_body(bt_ref, pos0_ref, seq_ref, win_ref, q_ref, k_ref, v_ref,
                  None, None, o_ref, m_ref, l_ref, quant=False, **kw)


@functools.partial(jax.jit, static_argnames=("chunk", "block_q",
                                             "interpret"))
def paged_prefill_pallas(
    q: jnp.ndarray,            # (B, Hkv, Cp, G, Dh) — grouped chunk queries
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh) — one layer's pool
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    pos0: jnp.ndarray,         # (B,) int32 tokens already resident
    seq_lens: jnp.ndarray,     # (B,) int32 total valid after this chunk
    window: jnp.ndarray,       # (1,) int32 (runtime scalar; <= 0 = full)
    k_scale=None,              # (P, page, Hkv) — int8 pools only
    v_scale=None,
    *,
    chunk: int,                # true (unpadded) chunk length C
    block_q: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused paged chunked-prefill attention; ``(B, Hkv, Cp, G, Dh)`` f32.

    ``q``'s chunk axis ``Cp`` must be a ``block_q`` multiple (the ops
    wrapper pads; padded rows attend the lane's valid prefix and are
    sliced off outside).  The chunk's own K/V must already be scattered
    into the pool — the kernel is a pure read path, like decode.
    """
    b, hkv, cp, g, d = q.shape
    page = k_pages.shape[1]
    n_blocks = block_tables.shape[1]
    nq = cp // block_q
    scale = d ** -0.5
    quant = k_scale is not None

    def _at_page(bb, h, iq, ik, bt, pos0, seq, win):
        return (bt[bb, ik], 0, h, 0)

    def _at_scale(bb, h, iq, ik, bt, pos0, seq, win):
        return (bt[bb, ik], 0, h)

    def _at_q(bb, h, iq, ik, bt, pos0, seq, win):
        return (bb, h, iq, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, block_q, g, d), _at_q),
        pl.BlockSpec((1, page, 1, d), _at_page),
        pl.BlockSpec((1, page, 1, d), _at_page),
    ]
    operands = [q, k_pages, v_pages]
    kernel = _pf_kernel_full
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), _at_scale),
                     pl.BlockSpec((1, page, 1), _at_scale)]
        operands += [k_scale, v_scale]
        kernel = _pf_kernel_quant

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, hkv, nq, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, g, d), _at_q),
        scratch_shapes=[
            pltpu.VMEM((1, block_q * g), jnp.float32),
            pltpu.VMEM((1, block_q * g), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel, scale=scale, page=page,
                          n_blocks=n_blocks, block_q=block_q, group=g,
                          chunk=chunk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, cp, g, d), jnp.float32),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos0.astype(jnp.int32),
      seq_lens.astype(jnp.int32), window, *operands)
