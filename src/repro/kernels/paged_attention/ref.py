"""Gather-then-attend reference for the fused paged-attention kernel.

Standalone jnp twin of ``models.attention.attend_paged_decode``'s
``gather`` path (kept import-free of ``repro.models`` so kernel tests and
benches can diff the two without circular imports).  This is exactly the
traffic pattern the kernel exists to kill: ``jnp.take`` materializes the
``(B, n_blocks·page, Hkv, Dh)`` logical view per K/V (and per scale pool
on the int8 path) before a single score is computed.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gather(pages: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    g = jnp.take(pages, block_tables, axis=0)       # (B, nblk, page, ...)
    b, nblk, page = g.shape[:3]
    return g.reshape((b, nblk * page) + g.shape[3:])


def paged_attention_ref(
    q: jnp.ndarray,            # (B, 1, Hq, Dh)
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    cur_pos: jnp.ndarray,      # (B,)
    window=0,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    b, _, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    kg = _gather(k_pages, block_tables)             # (B, T, Hkv, Dh)
    vg = _gather(v_pages, block_tables)
    t = kg.shape[1]
    quant = k_scale is not None
    acc_in = jnp.bfloat16 if quant else kg.dtype
    qg = q.reshape(b, hkv, g, d).astype(acc_in)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg, kg.astype(acc_in),
                    preferred_element_type=jnp.float32) * scale
    if quant:
        ksg = _gather(k_scale, block_tables)        # (B, T, Hkv)
        sc = sc * ksg.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    kv_pos = jnp.arange(t)[None, :]
    valid = kv_pos <= cur_pos[:, None]
    near = kv_pos > cur_pos[:, None] - window
    valid = jnp.logical_and(valid, jnp.where(window > 0, near, True))
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    if quant:
        vsg = _gather(v_scale, block_tables)
        p = p * vsg.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(acc_in),
                     vg.astype(acc_in),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def paged_prefill_ref(
    q: jnp.ndarray,            # (B, C, Hq, Dh) — one prefill chunk
    k_pages: jnp.ndarray,      # (P, page, Hkv, Dh)
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, n_blocks) int32
    pos0: jnp.ndarray,         # (B,) tokens already resident
    seq_lens: jnp.ndarray,     # (B,) total valid after this chunk
    window=0,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Gather-then-attend chunked prefill: the standalone twin of the
    ``attend_paged_prefill`` gather path (causal over logical positions,
    KV clipped to ``min(seq_lens, pos0 + C)``)."""
    b, c, hq, d = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    kg = _gather(k_pages, block_tables)             # (B, T, Hkv, Dh)
    vg = _gather(v_pages, block_tables)
    t = kg.shape[1]
    quant = k_scale is not None
    acc_in = jnp.bfloat16 if quant else jnp.float32
    qg = q.reshape(b, c, hkv, g, d).astype(acc_in)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kg.astype(acc_in),
                    preferred_element_type=jnp.float32) * scale
    if quant:
        ksg = _gather(k_scale, block_tables)
        sc = sc * ksg.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                             None, :]
    q_pos = pos0[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    kv_pos = jnp.arange(t, dtype=jnp.int32)[None, :]
    limit = jnp.minimum(seq_lens, pos0 + c)
    causal = kv_pos[:, None, :] <= q_pos[:, :, None]     # (B, C, T)
    near = kv_pos[:, None, :] > q_pos[:, :, None] - window
    mask = jnp.logical_and(causal, jnp.where(window > 0, near, True))
    mask = jnp.logical_and(mask, (kv_pos < limit[:, None])[:, None, :])
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    if quant:
        vsg = _gather(v_scale, block_tables)
        p = p * vsg.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                           None, :]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(acc_in),
                     vg.astype(acc_in),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, hq, d).astype(q.dtype)
