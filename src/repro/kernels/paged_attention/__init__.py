"""Fused paged-attention decode kernel: K/V read in place from the page
pool through the block table (no gathered logical-view copy)."""

from repro.kernels.paged_attention.ops import (
    decode_attn_bytes,
    paged_attention,
)
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = [
    "decode_attn_bytes",
    "paged_attention",
    "paged_attention_ref",
]
