"""Public jit'd wrapper for the int8 bit-parallel GEMV baseline kernel."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.int8_matvec.kernel import int8_matvec_pallas


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def int8_matvec(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_b: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    from repro.engine.backends import resolve_interpret

    interpret = resolve_interpret(interpret)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    b, k = x2.shape
    _, n = q.shape

    bb = min(block_b, _round_up(b, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(k, 128))
    b_pad, n_pad, k_pad = _round_up(b, bb), _round_up(n, bn), _round_up(k, bk)
    if b_pad != b or k_pad != k:
        x2 = jnp.pad(x2, ((0, b_pad - b), (0, k_pad - k)))
    if k_pad != k or n_pad != n:
        q = jnp.pad(q, ((0, k_pad - k), (0, n_pad - n)))
    if n_pad != n:
        scale = jnp.pad(scale, ((0, 0), (0, n_pad - n)))

    y = int8_matvec_pallas(
        q, scale, x2, block_b=bb, block_n=bn, block_k=bk,
        interpret=interpret, out_dtype=out_dtype,
    )
    y = y[:b, :n].reshape(lead + (n,))
    return y[0] if squeeze else y
