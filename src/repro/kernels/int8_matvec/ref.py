"""Pure-jnp oracle for the int8 bit-parallel GEMV kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matvec_ref(q, scale, x, *, out_dtype=jnp.float32):
    acc = jax.lax.dot_general(
        x.astype(jnp.float32),
        q.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return (acc * scale).astype(out_dtype)
