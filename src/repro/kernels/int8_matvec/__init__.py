from repro.kernels.int8_matvec.ops import int8_matvec

__all__ = ["int8_matvec"]
