"""Bit-parallel int8 GEMV Pallas kernel — the BRAMAC-style baseline.

Same weight-stationary tiling as ``bitplane_gemv`` but each weight retires
in a single MXU pass (no bit-serial digit loop).  This is the comparison
point the paper draws against hybrid bit-parallel designs: identical HBM
traffic at 8-bit, fewer compute passes, no sub-byte storage option.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, scale_ref, x_ref, o_ref, *, n_k_blocks: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == n_k_blocks - 1)
    def _finalize():
        o_ref[...] *= scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_n", "block_k", "interpret", "out_dtype"),
)
def int8_matvec_pallas(
    q: jnp.ndarray,        # (K, N) int8
    scale: jnp.ndarray,    # (1, N) f32
    x: jnp.ndarray,        # (B, K)
    *,
    block_b: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    b, k = x.shape
    _, n = q.shape
    block_b, block_n, block_k = min(block_b, b), min(block_n, n), min(block_k, k)
    assert b % block_b == 0 and n % block_n == 0 and k % block_k == 0
    grid = (b // block_b, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, block_n), lambda bb, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda bb, j, kk: (0, j)),
            pl.BlockSpec((block_b, block_k), lambda bb, j, kk: (bb, kk)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda bb, j, kk: (bb, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(q, scale, x).astype(out_dtype)
