"""Pallas TPU kernels for the engine's compute hot-spots.

``bitplane_gemv``  — the paper's contribution: bit-serial (bit-plane) GEMV
                     over packed b-bit weights, radix 1/2/4 per pass.
``int8_matvec``    — bit-parallel quantized GEMV baseline (the BRAMAC-style
                     comparison point).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).  Kernels target
TPU VMEM tiling and are validated on CPU with ``interpret=True``.
"""
