"""Oracle for the flash-attention kernel: plain masked softmax attention
in (B, H, S, D) layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, window: int = 0):
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sc = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * (d ** -0.5)
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window > 0:
        mask = jnp.logical_and(mask, pos[None, :] > pos[:, None] - window)
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, s, d).astype(q.dtype)
