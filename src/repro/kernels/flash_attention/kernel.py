"""Flash attention Pallas kernel (causal + sliding window, GQA).

The hillclimb profile showed attention score blocks at fusion boundaries
are the dominant HBM traffic of every dense train/prefill cell (and 18% of
zamba2's): a fused kernel keeps scores, the running softmax statistics and
the output accumulator in VMEM — HBM traffic collapses to Q/K/V reads + O
writes.

Grid: ``(B, Hq, nQ, nKV)`` with the KV dimension innermost; the output
block and the (m, l) statistics blocks are revisited across the KV sweep
(same accumulate-in-output pattern as the bitplane GEMV's east->west walk).
GQA is expressed in the K/V BlockSpec index maps (query head h reads KV
head ``h // group``).  Causality and the sliding window are computed from
block indices — no mask tensors are materialized anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_kv: int, n_kv_blocks: int,
            window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    kv_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
    mask = kv_pos <= q_pos
    if window > 0:
        mask = jnp.logical_and(mask, kv_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_ref[0, 0]                               # (bq,)
    l_old = l_ref[0, 0]
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_old, m_blk)
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_old * corr + jnp.sum(p, axis=-1)
    o_new = o_ref[0, 0] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _final():
        o_ref[0, 0] = o_new / jnp.maximum(l_new, 1e-30)[:, None]

    @pl.when(ik < n_kv_blocks - 1)
    def _accum():
        o_ref[0, 0] = o_new


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,          # (B, Hq, S, D)
    k: jnp.ndarray,          # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    nq, nk = s // block_q, s // block_kv
    grid = (b, hq, nq, nk)
    scale = d ** -0.5

    out, m, l = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=block_q, block_kv=block_kv,
            n_kv_blocks=nk, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bb, h, iq, ik: (bb, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda bb, h, iq, ik: (bb, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, s), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    del m, l
    return out.astype(q.dtype)
