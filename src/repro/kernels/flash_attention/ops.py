"""Public wrapper for the flash-attention Pallas kernel.

Accepts the model's (B, S, H, D) layout, handles non-divisible sequence
lengths by padding (padded keys sit at +inf positions via pure causal
masking of indices — the pad region is simply never attended because padded
queries are sliced off and padded keys are above every real query index).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(
    q: jnp.ndarray,          # (B, S, Hq, D) — model layout
    k: jnp.ndarray,          # (B, S, Hkv, D)
    v: jnp.ndarray,
    *,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: "bool | None" = None,
) -> jnp.ndarray:
    from repro.engine.backends import resolve_interpret

    interpret = resolve_interpret(interpret)
    b, s, hq, d = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    blk = max(block_q, block_kv)
    pad = (-s) % blk
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(
        qt, kt, vt, window=window, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
    if pad:
        out = out[:, :, :s]
    return out.transpose(0, 2, 1, 3)
