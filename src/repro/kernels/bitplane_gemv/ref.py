"""Pure-jnp oracle for the bit-plane GEMV kernel.

Walks the same radix-digit decomposition the kernel uses, so any packing,
sign-handling or accumulation bug in the kernel shows up as a mismatch here;
and this reference itself is validated against a plain float matmul of the
dequantized weights in the tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitplane import unpack_weights


def bitplane_gemv_ref(
    packed: jnp.ndarray,   # (K * bits // 8, N) int8
    scale: jnp.ndarray,    # (1, N) f32
    x: jnp.ndarray,        # (B, K)
    *,
    bits: int = 8,
    radix: int = 1,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    q = unpack_weights(packed, bits, axis=0)            # (K, N) int8
    code = q.astype(jnp.int32) & ((1 << bits) - 1)      # two's-complement code
    n_digits = bits // radix
    digit_mask = (1 << radix) - 1
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], packed.shape[1]), jnp.float32)
    for d in range(n_digits):
        digit = (code >> (d * radix)) & digit_mask
        if d == n_digits - 1:
            sign = (digit >> (radix - 1)) & 1
            digit = digit - (sign << radix)
        acc = acc + float(1 << (d * radix)) * jax.lax.dot_general(
            xf, digit.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return (acc * scale).astype(out_dtype)
