from repro.kernels.bitplane_gemv.ops import bitplane_gemv

__all__ = ["bitplane_gemv"]
