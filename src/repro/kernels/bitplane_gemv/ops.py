"""Public jit'd wrapper for the bit-plane GEMV kernel.

Handles arbitrary (B, K, N): pads every axis up to block multiples (zero
padding is exact for GEMV), dispatches the Pallas kernel, and slices the
result back.  ``interpret=None`` (default) asks the engine backend registry
(``repro.engine.default_interpret``): interpret mode off-TPU, compiled on
TPU hardware — so the same call-site works everywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp

from repro.kernels.bitplane_gemv.kernel import bitplane_gemv_pallas


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def bitplane_gemv(
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    x: jnp.ndarray,
    *,
    bits: int = 8,
    radix: int = 1,
    block_b: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    from repro.engine.backends import resolve_interpret

    interpret = resolve_interpret(interpret)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))

    b, k = x2.shape
    per_byte = 8 // bits
    kp, n = packed.shape
    assert kp * per_byte == k, f"packed K {kp}*{per_byte} != x K {k}"

    bb = min(block_b, _round_up(b, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(k, 128))
    b_pad, n_pad, k_pad = _round_up(b, bb), _round_up(n, bn), _round_up(k, bk)

    if b_pad != b or k_pad != k:
        x2 = jnp.pad(x2, ((0, b_pad - b), (0, k_pad - k)))
    if k_pad != k or n_pad != n:
        packed = jnp.pad(
            packed, ((0, (k_pad - k) // per_byte), (0, n_pad - n))
        )
    if n_pad != n:
        scale = jnp.pad(scale, ((0, 0), (0, n_pad - n)))

    y = bitplane_gemv_pallas(
        packed, scale, x2,
        bits=bits, radix=radix,
        block_b=bb, block_n=bn, block_k=bk,
        interpret=interpret, out_dtype=out_dtype,
    )
    y = y[:b, :n].reshape(lead + (n,))
    return y[0] if squeeze else y
