"""Bit-plane GEMV Pallas kernel — the IMAGine engine's TPU hot path.

Mapping from the paper (Fig. 2) to the TPU memory hierarchy:

  GEMV tile (12x2 PIM blocks)   -> one grid cell: a (block_k x block_n)
                                   weight tile resident in VMEM
  BRAM-stationary weights        -> packed int8 words streamed HBM->VMEM
                                   exactly once (b/8 bytes per weight)
  bit-serial PE pass (radix-2)   -> one plane-digit extraction + MXU matmul;
                                   ``radix`` bits retire per pass (radix=2
                                   reproduces the paper's slice4 variant)
  east->west accumulation        -> the minor grid dimension walks K tiles,
                                   accumulating into the same VMEM out block
  column shift-register readout  -> the final out-block writeback

Grid: ``(B_blocks, N_blocks, K_blocks)`` with K minor so the output block
stays VMEM-resident across the whole east->west sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(packed_ref, scale_ref, x_ref, o_ref, *, bits: int, radix: int,
            n_k_blocks: int, block_k: int):
    """One (batch, n, k) grid cell.

    packed_ref : (block_k * bits // 8, block_n) int8   — packed weight tile
    scale_ref  : (1, block_n) f32                      — per-channel scales
    x_ref      : (block_b, block_k) f32/bf16           — activation slice
    o_ref      : (block_b, block_n) f32                — accumulator block
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    per_byte = 8 // bits
    words = packed_ref[...].astype(jnp.uint8)  # (block_k/per_byte, block_n)

    if per_byte > 1:
        # unpack the packed K axis in-register (VREG shift/mask), restoring
        # K-major order: element k = i*per_byte + s lives in word i, digit s.
        mask = (1 << bits) - 1
        digs = [
            ((words >> (s * bits)) & mask).astype(jnp.uint8)
            for s in range(per_byte)
        ]
        stacked = jnp.stack(digs, axis=1)  # (words_k, per_byte, block_n)
        code = stacked.reshape(block_k, words.shape[-1])
    else:
        code = words  # (block_k, block_n) two's-complement codes

    x = x_ref[...].astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], words.shape[-1]), jnp.float32)

    # --- the bit-serial east->west passes (static unroll over digits) ------
    n_digits = bits // radix
    digit_mask = (1 << radix) - 1
    code_i32 = code.astype(jnp.int32)
    for d in range(n_digits):
        digit = (code_i32 >> (d * radix)) & digit_mask
        if d == n_digits - 1:
            # top digit carries the two's-complement sign
            sign = (digit >> (radix - 1)) & 1
            digit = digit - (sign << radix)
        partial = jax.lax.dot_general(
            x,
            digit.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc + float(1 << (d * radix)) * partial

    o_ref[...] += acc

    @pl.when(k_idx == n_k_blocks - 1)
    def _finalize():
        o_ref[...] *= scale_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "radix", "block_b", "block_n", "block_k",
                     "interpret", "out_dtype"),
)
def bitplane_gemv_pallas(
    packed: jnp.ndarray,   # (K * bits // 8, N) int8
    scale: jnp.ndarray,    # (1, N) f32
    x: jnp.ndarray,        # (B, K)
    *,
    bits: int = 8,
    radix: int = 1,
    block_b: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    b, k = x.shape
    kp, n = packed.shape
    per_byte = 8 // bits
    assert kp * per_byte == k, (kp, per_byte, k)
    assert bits % radix == 0, (bits, radix)

    block_b = min(block_b, b)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert b % block_b == 0 and n % block_n == 0 and k % block_k == 0, (
        "caller (ops.py) must pad to block multiples"
    )
    assert block_k % per_byte == 0
    grid = (b // block_b, n // block_n, k // block_k)

    return pl.pallas_call(
        functools.partial(
            _kernel,
            bits=bits,
            radix=radix,
            n_k_blocks=grid[2],
            block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_k // per_byte, block_n), lambda bb, j, kk: (kk, j)
            ),
            pl.BlockSpec((1, block_n), lambda bb, j, kk: (0, j)),
            pl.BlockSpec((block_b, block_k), lambda bb, j, kk: (bb, kk)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda bb, j, kk: (bb, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(packed, scale, x).astype(out_dtype)
