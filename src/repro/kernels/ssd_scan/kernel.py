"""Mamba2 SSD chunked-scan Pallas kernel.

The jnp SSD path materializes the (L, L) decay masks and intra-chunk
attention blocks in HBM (47% of zamba2's training bytes in the dry-run
profile); here each (batch, head) processes its chunks sequentially with
the running state, the decay mask and the chunk-local matmuls resident in
VMEM — HBM traffic collapses to x/dt/B/C reads + y/state writes.

Grid: ``(B, H, nc)`` with the chunk dimension innermost; the (P, N) state
block is revisited across the chunk sweep (east->west accumulation again).
The (L, N) B/C blocks are shared across heads — reread per head (they are
small; a multi-head variant could cache them in VMEM across grid steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int,
            n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)       # (L, P)
    la = la_ref[0, :, 0].astype(jnp.float32)            # (L,)
    bc = b_ref[0].astype(jnp.float32)                   # (L, N)
    cc = c_ref[0].astype(jnp.float32)                   # (L, N)
    h = h_ref[0, 0].astype(jnp.float32)                 # (P, N)

    cum = jnp.cumsum(la)                                # (L,)
    total = cum[-1]

    # intra-chunk: (GB ⊙ decay-mask) @ xdt — all VMEM-resident
    gb = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    dec = cum[:, None] - cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(ik <= iq, jnp.exp(dec), 0.0)
    y_intra = jax.lax.dot_general(gb * m, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: C_i · h_prev, decayed to position i
    ch = jax.lax.dot_general(cc, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y_inter = jnp.exp(cum)[:, None] * ch

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = h·exp(total) + Σ_j exp(total - cum_j)·xdt_j ⊗ B_j
    w = jnp.exp(total - cum)[:, None] * bc               # (L, N)
    upd = jax.lax.dot_general(xdt, w, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_ref[0, 0] = h * jnp.exp(total) + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xdt: jnp.ndarray,        # (B, S, H, P)  dt-premultiplied inputs
    la: jnp.ndarray,         # (B, S, H)     log decay (dt * A)
    b_in: jnp.ndarray,       # (B, S, N)
    c_in: jnp.ndarray,       # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    bsz, s, nh, p = xdt.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (bsz, nh, nc)

    y, h = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, ic: (b, ic, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, ic: (b, ic, hh)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, ic: (b, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, ic: (b, ic, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, ic: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, nh, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nh, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, la, b_in, c_in)
    return y, h
