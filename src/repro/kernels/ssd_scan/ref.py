"""Oracle for the SSD kernel: repro.models.ssm.ssd_chunked re-parameterized
to the kernel's (pre-discretized) inputs."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_scan_ref(xdt, la, b_in, c_in, chunk: int = 128):
    """Naive per-step recurrence on the kernel's inputs (exact)."""
    bsz, s, nh, p = xdt.shape
    n = b_in.shape[-1]
    h = np.zeros((bsz, nh, p, n))
    ys = []
    xdt = np.asarray(xdt, np.float64)
    la = np.asarray(la, np.float64)
    b_in = np.asarray(b_in, np.float64)
    c_in = np.asarray(c_in, np.float64)
    for t in range(s):
        decay = np.exp(la[:, t])                       # (B, H)
        h = h * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xdt[:, t], b_in[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", c_in[:, t], h))
    return np.stack(ys, 1), h
