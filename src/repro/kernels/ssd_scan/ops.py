"""Public wrapper for the SSD Pallas kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


def ssd_scan(xdt, la, b_in, c_in, *, chunk: int = 128,
             interpret: "bool | None" = None):
    """y, h_final = SSD(xdt, exp(la), B, C) — kernel entry point.

    xdt: (B, S, H, P) dt-premultiplied head inputs; la: (B, S, H) log decay;
    b_in/c_in: (B, S, N) state projections.
    """
    from repro.engine.backends import resolve_interpret

    return ssd_scan_pallas(xdt, la, b_in, c_in, chunk=chunk,
                           interpret=resolve_interpret(interpret))
