"""Configuration dataclasses for the whole framework.

Everything that varies between runs — model architecture, input shape cell,
mesh geometry, optimizer, serving and the IMAGine engine itself — is a frozen
dataclass here.  Architecture files in ``repro/configs/`` instantiate
``ModelConfig`` with the exact published dimensions and register themselves
under their ``--arch`` id.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-family model definition, wide enough for all 10 assigned archs.

    Block kinds are derived from ``family``:
      dense / vlm / audio : attention + dense MLP every layer
      moe                 : attention + (shared expert? + routed experts)
      ssm                 : Mamba2 (SSD) blocks, attention-free
      hybrid              : Mamba2 blocks with a *shared-weight* attention
                            block applied every ``attn_every`` layers (zamba2)
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every Nth layer is global, rest local
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0        # llama4 keeps one always-on shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (zamba2) ------------------------------------------------------
    attn_every: int = 0              # shared attention block cadence (0 = never)

    # --- modality frontends (stubs per assignment) ----------------------------
    frontend: str = ""               # "" | "vision" | "audio"
    n_codebooks: int = 1             # musicgen: EnCodec codebooks
    img_tokens: int = 0              # llava: precomputed patch embedding count

    # --- mlp style --------------------------------------------------------------
    mlp_gated: bool = True           # SwiGLU (3 mats); False = GELU MLP (2 mats)

    # --- numerics --------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """May this arch run the 500k-token long-context decode cell?

        True for SSM / hybrid archs (O(1) state) and for mostly-local
        attention stacks (gemma3's 5:1 local:global with a 1k window).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 and self.global_every > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length ``n_layers``.

        dense archs -> ("attn",) * L             (window/global split is a flag)
        moe         -> ("moe",) * L
        ssm         -> ("ssm",) * L
        hybrid      -> ssm blocks, with a shared "attn" applied every
                        ``attn_every`` layers *in addition to* the ssm block.
        """
        if self.family in ("dense", "vlm", "audio"):
            return ("attn",) * self.n_layers
        if self.family == "moe":
            return ("moe",) * self.n_layers
        if self.family in ("ssm", "hybrid"):
            return ("ssm",) * self.n_layers
        raise ValueError(f"unknown family {self.family!r}")

    def is_global_layer(self, i: int) -> bool:
        """Gemma3-style local:global pattern: layer i uses global attention."""
        if self.sliding_window == 0:
            return True
        if self.global_every == 0:
            return False
        return (i % self.global_every) == (self.global_every - 1)

    # --- parameter accounting (used by roofline MODEL_FLOPS and docs) ---------
    def param_count(self) -> int:
        """Total parameter count N (embedding included once)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_layer = 0
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        if self.qkv_bias:
            attn += (n_q + 2 * n_kv) * hd
        mlp_mats = 3 if self.mlp_gated else 2
        mlp_dense = mlp_mats * d * self.d_ff
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + mlp_dense + 2 * d  # 2 RMSNorm scales
        elif self.family == "moe":
            routed = self.n_experts * 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.d_ff
            router = d * self.n_experts
            per_layer = attn + routed + shared + router + 2 * d
        elif self.family in ("ssm", "hybrid"):
            di, st, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            in_proj = d * (2 * di + 2 * st + nh)  # z, x, B, C, dt
            conv = (di + 2 * st) * self.conv_width
            out_proj = di * d
            ssm_misc = 2 * nh + di  # A_log, dt_bias, norm scale on gate
            per_layer = in_proj + conv + out_proj + ssm_misc + 2 * d
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one shared-weight attention+MLP block
            total += attn + mlp_dense + 2 * d
        emb = self.vocab_size * d * self.n_codebooks
        head = 0 if self.tie_embeddings else self.vocab_size * d * self.n_codebooks
        total += emb + head + d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        routed_all = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        routed_active = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return int(self.param_count() - routed_all + routed_active)


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh geometry.

    The dry-run target is a 16x16 single pod (256 chips) and a 2x16x16
    two-pod mesh (512 chips).  The ``pod`` axis defaults to data parallelism
    and can be flipped to pipeline parallelism.
    """

    multi_pod: bool = False
    pod_axis_mode: str = "data"  # "data" | "pipeline"

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        return int(math.prod(self.shape))

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Mesh axes over which the batch is sharded."""
        if self.multi_pod and self.pod_axis_mode == "data":
            return ("pod", "data")
        return ("data",)


# ---------------------------------------------------------------------------
# IMAGine engine (the paper's technique, as a serving feature)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EngineConfig:
    """Configuration of the IMAGine GEMV engine used on the decode path.

    ``weight_bits``: precision of the stationary weights (2/4/8); bf16 = 0
        disables the engine (plain dense path; the dry-run baseline).
    ``radix``: bits retired per bit-serial pass — 1 reproduces IMAGine's
        radix-2 Booth behaviour (one plane per pass), 2 reproduces
        IMAGine-slice4 (radix-4 Booth), 8 collapses to bit-parallel int8.
    ``backend``: engine backend registry name ("auto" selects from
        ``jax.default_backend()``: the compiled Pallas kernel on TPU, the
        exact jnp reference elsewhere).  Resolved once, by
        ``repro.engine.resolve_plan``, into an ``EnginePlan``.
    ``attn_backend``: paged decode-attention read path — "auto" (TPU →
        the fused in-place kernel, else the gather reference), "gather",
        "pallas_interpret" or "pallas_tpu".  Resolved into the plan like
        ``backend``.
    ``sharded``: wrap ``backend`` in the mesh-native ``sharded`` dispatch
        (shard_map over the mesh's model axis; the mesh itself is supplied
        at plan resolution — ``resolve_plan(cfg, mesh=...)``).
    ``psum_bits``: row-parallel partial-GEMV reduction precision for the
        sharded backend (0 = exact fp32 psum, 4/8 = compressed codes).
    """

    weight_bits: int = 0
    radix: int = 1
    kv_bits: int = 0             # beyond-paper: bit-plane the KV cache too
    act_dtype: str = "bfloat16"
    backend: str = "auto"        # engine backend name (see repro.engine)
    attn_backend: str = "auto"   # paged decode-attention read path
    tile_m: int = 256            # engine tile rows   (PE columns per tile)
    tile_k: int = 512            # engine tile depth  (weights streamed E->W)
    sharded: bool = False        # mesh-native dispatch (docs/sharding.md)
    psum_bits: int = 0           # 0 = fp32 psum; 4/8 = compressed_psum_leaf

    def __post_init__(self):
        if self.weight_bits not in (0, 2, 4, 8):
            raise ValueError(f"weight_bits must be 0/2/4/8, got {self.weight_bits}")
        if self.radix not in (1, 2, 4, 8):
            raise ValueError(f"radix must be 1/2/4/8, got {self.radix}")
        if self.kv_bits not in (0, 8):
            raise ValueError(f"kv_bits must be 0/8, got {self.kv_bits}")
        if self.psum_bits not in (0, 4, 8):
            raise ValueError(f"psum_bits must be 0/4/8, got {self.psum_bits}")
        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(f"backend must be a backend name, got "
                             f"{self.backend!r}")
        if not isinstance(self.attn_backend, str) or not self.attn_backend:
            raise ValueError(f"attn_backend must be a backend name, got "
                             f"{self.attn_backend!r}")
        # backend names are validated against the live registry when the
        # config is resolved into a plan (repro.engine.resolve_plan).

    @property
    def enabled(self) -> bool:
        return self.weight_bits > 0


# ---------------------------------------------------------------------------
# Train / serve / run
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1_000
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # adamw | adafactor | sgd
    microbatches: int = 1             # gradient accumulation factor
    remat: str = "block"              # none | block | full
    grad_compress_bits: int = 0       # 0 = off; 8 = int8 error-feedback psum
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    """Serving knobs (see ``docs/serving.md``).

    ``mode``: ``"auto"`` (paged-KV for attention families, fixed slots for
    ssm/hybrid), ``"paged"``, or ``"slots"``.
    ``page_size``: tokens per KV page.
    ``n_pages``: physical pages in the shared pool; 0 sizes the pool to
    the full ``n_slots × max_len`` rectangle (no preemption).
    ``prefill_chunk``: prompt tokens per batched chunked-prefill step.
    ``prefix_cache``: share KV pages across requests through the
    radix-tree prefix cache (``repro.serve.prefix_cache``) — matched
    prompt prefixes skip prefill entirely; paged mode only.
    ``sched``: ``"fcfs"`` (arrival-order admission, unbudgeted prefill)
    or ``"budget"`` (SLA-aware: per-step token budget interleaving
    chunked prefill with decode, priority classes with weighted
    fair-share accounting across tenants; paged mode only).
    ``step_tokens``: per-step token budget for ``sched="budget"``
    (prefill + decode tokens per scheduler step); 0 derives
    ``n_slots + prefill_chunk``.
    ``max_queue``: bounded admission queue — ``submit`` rejects with
    :class:`repro.serve.engine.AdmissionRejected` when this many
    requests are already waiting; 0 = unbounded (never sheds).
    ``audit``: runtime invariant auditing (``docs/robustness.md``) —
    0 = off, 1 = allocator + prefix-cache + scheduler audit after every
    engine step, 2 = additionally after every phase *within* a step
    (admit / prefill / decode / retire; pinpoints which phase corrupted
    state).  An audit failure raises
    :class:`repro.serve.pages.AuditError`; paged mode only.
    ``max_request_retries``: per-request restart budget — a step fault
    or non-finite logit first retries the request recompute-style this
    many times before quarantining it with ``finish_reason="error"``.
    ``retry_reset_steps``: healthy engine steps after which a request's
    restart budget resets (``RestartPolicy.reset_after_steps``);
    0 = never resets.
    """

    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0
    engine: EngineConfig = field(default_factory=EngineConfig)
    mode: str = "auto"                # auto | paged | slots
    page_size: int = 16
    n_pages: int = 0                  # 0 = full capacity (never preempts)
    prefill_chunk: int = 32
    prefix_cache: bool = False        # radix-tree KV reuse (paged only)
    sched: str = "fcfs"               # fcfs | budget (SLA-aware)
    step_tokens: int = 0              # 0 = n_slots + prefill_chunk
    max_queue: int = 0                # 0 = unbounded admission queue
    audit: int = 0                    # 0 = off, 1 = per-step, 2 = per-phase
    max_request_retries: int = 1      # retries before quarantine
    retry_reset_steps: int = 0        # healthy steps to reset the budget

    def __post_init__(self):
        if self.mode not in ("auto", "paged", "slots"):
            raise ValueError(f"mode must be auto/paged/slots, got {self.mode}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.sched not in ("fcfs", "budget"):
            raise ValueError(f"sched must be fcfs/budget, got {self.sched}")
        if self.step_tokens < 0:
            raise ValueError(
                f"step_tokens must be >= 0, got {self.step_tokens}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        if self.audit not in (0, 1, 2):
            raise ValueError(f"audit must be 0/1/2, got {self.audit}")
        if self.max_request_retries < 0:
            raise ValueError(
                f"max_request_retries must be >= 0, "
                f"got {self.max_request_retries}")
        if self.retry_reset_steps < 0:
            raise ValueError(
                f"retry_reset_steps must be >= 0, "
                f"got {self.retry_reset_steps}")


@dataclass(frozen=True)
class RunConfig:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
