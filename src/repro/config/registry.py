"""--arch registry.

Each file in ``repro/configs/`` defines ``CONFIG`` (exact published dims) and
``reduced()`` (a tiny same-family config for CPU smoke tests) and calls
``register_arch``.  ``get_arch("gemma3-27b")`` imports lazily so that simply
importing repro never pulls in every architecture module.
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, "ArchEntry"] = {}


class ArchEntry:
    def __init__(self, arch_id: str, config: ModelConfig, reduced: Callable[[], ModelConfig]):
        self.arch_id = arch_id
        self.config = config
        self.reduced = reduced


def register_arch(arch_id: str, config: ModelConfig, reduced: Callable[[], ModelConfig]) -> None:
    if arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {arch_id!r}")
    _REGISTRY[arch_id] = ArchEntry(arch_id, config, reduced)


# arch-id -> module under repro.configs
_ARCH_MODULES = {
    "gemma3-27b": "gemma3_27b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
    "musicgen-medium": "musicgen_medium",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
}


def _load(arch_id: str) -> ArchEntry:
    if arch_id not in _REGISTRY:
        mod = _ARCH_MODULES.get(arch_id)
        if mod is None:
            raise KeyError(
                f"unknown arch {arch_id!r}; available: {sorted(_ARCH_MODULES)}"
            )
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]


def get_arch(arch_id: str) -> ModelConfig:
    return _load(arch_id).config


def get_reduced(arch_id: str) -> ModelConfig:
    return _load(arch_id).reduced()


def available_archs() -> List[str]:
    return sorted(_ARCH_MODULES)
