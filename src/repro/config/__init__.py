from repro.config.base import (
    EngineConfig,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
    SHAPES,
)
from repro.config.registry import (
    available_archs,
    get_arch,
    get_reduced,
    register_arch,
)

__all__ = [
    "EngineConfig",
    "MeshConfig",
    "ModelConfig",
    "RunConfig",
    "ServeConfig",
    "ShapeConfig",
    "TrainConfig",
    "SHAPES",
    "available_archs",
    "get_arch",
    "get_reduced",
    "register_arch",
]
