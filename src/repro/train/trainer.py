"""Training loop: jit'd train_step factory + a Trainer that wires the data
pipeline, checkpoint manager, failure injection/restart, and straggler
monitoring together.

``make_train_step`` builds the pure step function the dry-run lowers on the
production mesh: microbatched gradient accumulation (scan), global-norm
clipping, cosine-warmup LR, the chosen optimizer, and (optionally) int8
error-feedback gradient compression on the cross-pod reduction.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, TrainConfig
from repro.models import forward
from repro.models.transformer import chunked_ce
from repro.optim import (
    cosine_warmup,
    error_feedback_compress,
    make_optimizer,
)

Pytree = Any


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    donate: bool = True,
) -> Callable:
    """Returns jit'd ``step(params, opt_state, ef_state, batch) ->
    (params, opt_state, ef_state, metrics)``.

    ``ef_state`` is the error-feedback buffer when gradient compression is
    on (pass None/empty dict otherwise).
    """
    init_fn, update_fn = make_optimizer(tcfg.optimizer)
    del init_fn

    def loss_of(params, batch):
        hidden, aux = forward(params, batch, cfg, remat=tcfg.remat,
                              return_hidden=True)
        return chunked_ce(params, hidden, batch["labels"], cfg, aux=aux)

    def step(params, opt_state, ef_state, batch):
        mb = tcfg.microbatches
        if mb > 1:
            def one_micro(carry, micro):
                acc = carry
                l, g = jax.value_and_grad(loss_of)(params, micro)
                acc = (acc[0] + l, jax.tree.map(jnp.add, acc[1], g))
                return acc, None

            micros = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)
            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(one_micro, zero, micros)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if tcfg.grad_compress_bits:
            grads, ef_state = error_feedback_compress(
                grads, ef_state, tcfg.grad_compress_bits)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = cosine_warmup(opt_state.step, tcfg.lr, tcfg.warmup_steps,
                           tcfg.total_steps)
        params, opt_state = update_fn(grads, opt_state, params, tcfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, ef_state, metrics

    if donate:
        return jax.jit(step, donate_argnums=(0, 1, 2))
    return jax.jit(step)


class Trainer:
    """Step-loop driver with checkpoint/restart and straggler hooks."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        params,
        pipeline,
        ckpt_manager=None,
        ckpt_every: int = 50,
        straggler_monitor=None,
        failure_injector=None,
    ):
        from repro.optim import ef_state_init

        self.cfg, self.tcfg = cfg, tcfg
        self.params = params
        init_fn, _ = make_optimizer(tcfg.optimizer)
        self.opt_state = init_fn(params)
        self.ef_state = (
            ef_state_init(params) if tcfg.grad_compress_bits else
            jax.tree.map(lambda p: jnp.zeros((0,)), {}))
        self.pipeline = pipeline
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.straggler = straggler_monitor
        self.injector = failure_injector
        self.step_fn = make_train_step(cfg, tcfg, donate=False)
        self.history: list = []
        self.restarts = 0

    # -------------------------------------------------------------- resume
    def maybe_resume(self) -> int:
        if self.ckpt is None:
            return 0
        tmpl = {"params": self.params, "opt": self.opt_state,
                "ef": self.ef_state}
        step, tree, extra = self.ckpt.restore_latest(tmpl)
        if step is None:
            return 0
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ef_state = tree["ef"]
        self.pipeline.state.step = int(extra.get("data_step", step))
        return step

    # ----------------------------------------------------------------- run
    def run(self, total_steps: int) -> Dict[str, list]:
        from repro.ft.failures import run_with_restarts

        start = self.maybe_resume()

        def do_step(step: int):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch_at(step).items()}
            self.params, self.opt_state, self.ef_state, metrics = self.step_fn(
                self.params, self.opt_state, self.ef_state, batch)
            loss = float(metrics["loss"])
            self.history.append(loss)
            dt = time.perf_counter() - t0
            if self.straggler is not None:
                self.straggler.observe(step, {0: dt})
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1,
                               {"params": self.params, "opt": self.opt_state,
                                "ef": self.ef_state},
                               extra={"data_step": step + 1})

        def restore() -> int:
            step = self.maybe_resume()
            self.restarts += 1
            return step

        run_with_restarts(
            do_step, start_step=start, total_steps=total_steps,
            restore_fn=restore, injector=self.injector)
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"loss": self.history}
