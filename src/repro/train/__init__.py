from repro.train.trainer import Trainer, make_train_step

__all__ = ["Trainer", "make_train_step"]
