"""Batched serving engine: paged-KV continuous batching (default) with the
legacy fixed-slot engine kept as the comparison baseline.

**Paged mode** (``mode="paged"``, the default for attention-KV families):
KV state lives in a shared page pool (:mod:`repro.serve.pages`) addressed
through per-request block tables; a scheduler
(:mod:`repro.serve.scheduler`) admits requests by page capacity, prefills
prompts in batched chunks through ``prefill_chunk`` (one forward per chunk
across all pending lanes), and preempts the longest-running request when
pages run out.  Decode throughput then scales with pool capacity — the
serving analogue of the paper's GEMV-per-memory-capacity argument.

**Fixed-slot mode** (``mode="slots"``; also the fallback for ssm/hybrid
families, whose O(1) recurrent state has nothing to page): the original
engine — a fixed ``(n_slots, max_len)`` cache rectangle, per-token prompt
prefill, one fused ``decode_step`` per token across active slots.

Both modes run every linear through the same resolved
:class:`~repro.engine.EnginePlan`; with ``EngineConfig.kv_bits = 8`` the
paged pools are int8 bit-planed exactly as ``weight_bits`` bit-planes the
stationary weights — cache traffic drops to 1 byte/element through the
same dispatch layer.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import logging
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.config.base import EngineConfig, ModelConfig, ServeConfig
from repro.dist.hints import use_mesh
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    pool_pages_for_mesh,
)
from repro.engine import resolve_attn_backend, resolve_plan
from repro.ft.failures import RestartPolicy
from repro.models import (
    decode_step,
    decode_step_paged,
    init_cache,
    quantize_params,
)
from repro.models import prefill_chunk as _prefill_chunk_fn
from repro.serve.pages import (
    NULL_PAGE,
    PAGED_FAMILIES,
    AuditError,
    KVPages,
    PageAllocator,
    fork_tail_page,
    init_kv_pages,
    pages_for,
)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampler import sample
from repro.serve.scheduler import (
    PRIORITY_WEIGHTS,
    BudgetScheduler,
    PagedScheduler,
)

logger = logging.getLogger(__name__)


class AdmissionRejected(RuntimeError):
    """Load shedding: ``submit`` refused the request outright.

    ``reason``: ``"queue_full"`` (bounded admission queue at capacity) or
    ``"pool_too_small"`` (the prompt can never fit the page pool — waiting
    would deadlock behind eviction+preemption).  Rejecting at the door
    keeps the admitted requests' latency bounded under overload; the
    caller (or :class:`repro.serve.frontend.ServeFrontend`) decides
    whether to retry, degrade, or surface the error.
    """

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


# eq=False: a Request is an identity (queue membership, slot residency and
# cancellation all compare by ``is``); field-wise dataclass equality would
# even crash comparing the ndarray ``last_logits``
@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # logits of the most recent token, fed to the next sampling step.  A
    # real field now (it used to be injected by ``_prefill_slot``).
    last_logits: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)
    # --- paged-scheduler state --------------------------------------
    prefill_tokens: List[int] = dataclasses.field(
        default_factory=list, repr=False)
    prefill_pos: int = 0
    admit_seq: int = -1
    preemptions: int = 0
    # prefill tokens served from the prefix cache at (re-)admission
    cached_tokens: int = 0
    # clock reading at ``submit()`` — the anchor for per-request latency
    submit_t: float = 0.0
    # time-to-first-token measured from ``submit_t`` (per request; the
    # old run()-relative measurement overstated TTFT for every request
    # submitted after the engine started stepping)
    ttft: Optional[float] = None
    # --- SLA / front-end state --------------------------------------
    priority: str = "default"         # interactive | default | batch
    tenant: str = "default"           # fair-share accounting key part
    cancelled: bool = False           # terminal, but not successfully done
    # "length" | "cancelled" | "timed_out" | "error" (None while running)
    finish_reason: Optional[str] = None
    # recompute-style retries after step faults / non-finite logits
    retries: int = 0

    # deprecated alias (pre-paged code set this attribute dynamically)
    @property
    def _last_logits(self):
        return self.last_logits

    @_last_logits.setter
    def _last_logits(self, value):
        self.last_logits = value


class ServeEngine:
    """Continuous-batching serving over a paged or fixed-slot KV cache.

    ``mode``: ``"paged"`` | ``"slots"`` | ``"auto"`` (paged for attention
    families, slots for ssm/hybrid).  ``page_size`` / ``n_pages`` /
    ``prefill_chunk`` configure the paged pool (``n_pages=0`` sizes the
    pool to the full ``n_slots × max_len`` rectangle — no preemption;
    smaller pools trade preemptions for memory, admission is always
    capacity-checked).

    ``attn_backend``: paged-attention read path for decode *and* chunked
    prefill — ``gather`` (the materialize-then-attend reference) or the
    fused in-place Pallas kernel (``pallas_interpret`` / ``pallas_tpu``,
    which also runs the in-kernel prefill grid).  None defers to the
    resolved plan (``EngineConfig.attn_backend``), whose ``"auto"`` picks
    the kernel on TPU — including mesh-carrying engines, where it
    shard_maps over the pool's heads-over-model placement — and
    ``gather`` elsewhere.

    ``prefix_cache``: share KV pages across requests
    (:mod:`repro.serve.prefix_cache`) — prompts are matched against a
    radix tree of resident pages at admission and only the unmatched
    suffix is prefilled; completed prefills are inserted back into the
    tree.  A bool (``None`` defers to ``ServeConfig.prefix_cache``); the
    engine owns its :class:`PrefixCache` — the tree indexes this engine's
    pool, so foreign instances are rejected.  Paged mode only.  Cache
    state (tree, refcounts) is host-side, exactly like block tables — it
    does not change what any jitted step sees.

    ``mesh``: run on a production ``(data, model)`` mesh — params are
    placed by ``dist.sharding.param_shardings`` (TP), the KV page pool by
    ``cache_shardings`` (pages over ``data``, heads over ``model``; the
    pool is padded so the page axis divides), and the plan is resolved
    with the mesh so ``EngineConfig.sharded`` backends shard_map their
    GEMVs.  The allocator, block tables, scheduler and prefix cache stay
    host-side exactly as on one device.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: Optional[ServeConfig] = None,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        mode: Optional[str] = None,
        page_size: Optional[int] = None,
        n_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        prefix_cache=None,
        mesh=None,
        attn_backend: Optional[str] = None,
        clock=None,
        telemetry=None,
        chaos=None,
    ):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        # ``chaos``: optional ft.ChaosInjector — deterministic fault
        # injection at the engine's hook sites (page grants, step faults,
        # NaN logits, preemption storms; see docs/robustness.md).  None
        # (production) costs one attribute check per site.
        self.chaos = chaos
        # ``clock``: injectable timebase for every engine timestamp
        # (``submit_t``, TTFT, telemetry spans) — defaults to the serve
        # clock (repro.obs.clock).  ``telemetry``: an explicit Telemetry /
        # NullTelemetry; None defers to the process-wide repro.obs switch.
        self._clock = clock if clock is not None else obs.clock.now
        self.obs = (telemetry if telemetry is not None
                    else obs.telemetry(clock))
        if chaos is not None:
            # every fired fault self-reports through the engine's
            # telemetry (ChaosInjector.fire) — including sites the engine
            # never sees directly, like the allocator's page_grant
            chaos.obs = self.obs
        # the EngineConfig is resolved into an EnginePlan exactly once, at
        # construction; the plan is the only engine object the decode loop
        # ever sees.  The mesh rides in the plan, so the sharded backend
        # needs no further plumbing.
        self.plan = resolve_plan(self.scfg.engine, mesh=mesh)
        self.eng = self.plan  # back-compat alias
        if self.plan is not None and self.plan.bits:
            params = quantize_params(params, cfg, self.plan.bits)
        if mesh is not None:
            params = jax.device_put(params, param_shardings(mesh, params))
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.kv_bits = self.plan.kv_bits if self.plan is not None else 0
        # the paged-attention read path (gather reference vs the fused
        # in-place kernel, decode and chunked prefill alike): explicit
        # kwarg beats the plan beats the raw EngineConfig (which still
        # carries attn_backend when the engine itself is disabled and the
        # plan resolves to None).  "auto" resolves by host (TPU → fused)
        # with or without a mesh — on a mesh the kernel shard_maps over
        # the pool's heads-over-model placement.
        self.attn_backend = resolve_attn_backend(
            attn_backend
            or (self.plan.attn_backend if self.plan is not None
                else getattr(self.scfg.engine, "attn_backend", None)),
            mesh=mesh)

        mode = mode or self.scfg.mode
        auto_fallback = False
        if mode == "auto":
            if cfg.family in PAGED_FAMILIES:
                mode = "paged"
            else:
                auto_fallback = True
                # the silent fallback hid a capability gap (ROADMAP open
                # item: zamba2's shared-attention sites do have a real KV
                # cache) — name the family so operators see which models
                # run the legacy fixed-slot engine
                logger.warning(
                    "ServeEngine: family %r has no pageable KV cache; "
                    "falling back to mode='slots' (fixed-slot engine, "
                    "no paging, no prefix cache)", cfg.family)
                mode = "slots"
        if mode == "paged" and cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} has no pageable KV cache; "
                "use mode='slots'")
        if mode not in ("paged", "slots"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self.mode = mode

        if prefix_cache is None:
            prefix_cache = self.scfg.prefix_cache
        if not isinstance(prefix_cache, bool):
            # an instance would index a *different* pool's pages — and an
            # empty one would even be falsy; refuse rather than surprise
            raise TypeError(
                f"prefix_cache must be a bool (got "
                f"{type(prefix_cache).__name__}); the engine builds and "
                "owns the PrefixCache over its own page pool")
        if prefix_cache and mode != "paged":
            if auto_fallback:
                # the fallback warning above already names the family;
                # a generic prefix-cache config must not explode on it
                prefix_cache = False
            else:
                raise ValueError(
                    "prefix_cache shares KV *pages* across requests; "
                    "mode='slots' has no page pool to share")
        if self.scfg.sched == "budget" and mode != "paged":
            if not auto_fallback:
                raise ValueError(
                    "sched='budget' interleaves chunked prefill with "
                    "decode under a token budget; mode='slots' prefills "
                    "synchronously and has no scheduler to budget")
            logger.warning(
                "ServeEngine: sched='budget' ignored in mode='slots' "
                "(fixed-slot fallback runs FCFS)")
        if self.scfg.audit and mode != "paged":
            # the invariants audited (refcounts, free list, radix tree)
            # are paged-pool state; slots mode has none of it
            if not auto_fallback:
                raise ValueError(
                    "audit proves page-pool invariants; mode='slots' has "
                    "no page pool to audit")
            logger.warning(
                "ServeEngine: audit ignored in mode='slots' "
                "(no page pool)")

        self.queue: Deque[Request] = collections.deque()
        self._next_rid = 0
        self.shed_count = 0  # AdmissionRejected raises since construction
        self.quarantined = 0  # requests finished with finish_reason="error"
        self.retried = 0  # recompute-style retries granted across requests
        self._engine_step = 0
        # per-request restart budgets (rid -> RestartPolicy), created on
        # first fault, dropped at terminal states
        self._retry: Dict[int, RestartPolicy] = {}
        self._errored_step: List[Request] = []
        self.obs.attach_engine(n_slots, mode)

        cfg_ = self.cfg
        plan_ = self.plan

        if mode == "paged":
            self.page_size = page_size or self.scfg.page_size
            self.prefill_chunk = prefill_chunk or self.scfg.prefill_chunk
            max_blocks = pages_for(max_len, self.page_size)
            self._max_blocks = max_blocks
            if n_pages is None:
                n_pages = self.scfg.n_pages
            if not n_pages:  # full rectangle + null page: never preempts
                n_pages = n_slots * max_blocks + 1
            # pages-over-data needs a divisible page axis; padding only
            # grows spare capacity (the allocator sees more free pages)
            n_pages = pool_pages_for_mesh(n_pages, mesh)
            self.pages = init_kv_pages(cfg, n_pages, self.page_size,
                                       kv_bits=self.kv_bits)
            if mesh is not None:
                self.pages = jax.device_put(
                    self.pages, cache_shardings(mesh, self.pages))
            self.alloc = PageAllocator(n_pages, self.page_size, n_slots,
                                       max_len, obs=self.obs)
            self.alloc.chaos = chaos  # page_grant fault site
            # the prefix cache attaches to the allocator (resident-page
            # ownership + LRU eviction when the free list runs dry)
            self.prefix_cache = None
            if prefix_cache:
                self.prefix_cache = PrefixCache(self.alloc, obs=self.obs)
                self.alloc.attach_cache(self.prefix_cache)
            if self.scfg.sched == "budget":
                # default budget: every lane decodes plus one full prefill
                # chunk per step — decode-first with steady prefill progress
                step_tokens = (self.scfg.step_tokens
                               or n_slots + self.prefill_chunk)
                self.sched = BudgetScheduler(
                    self.alloc, self.prefill_chunk,
                    prefix_cache=self.prefix_cache,
                    step_tokens=step_tokens, obs=self.obs)
            else:
                self.sched = PagedScheduler(
                    self.alloc, self.prefill_chunk,
                    prefix_cache=self.prefix_cache, obs=self.obs)
            # lane-state shardings are computed once: block tables and
            # positions always enter the device under their mesh placement
            self._table_shardings = None
            if mesh is not None:
                bt0, pos0 = self.alloc.device_tables()
                sh = batch_shardings(mesh, {"bt": bt0, "pos": pos0})
                self._table_shardings = (sh["bt"], sh["pos"])

            # the page pool is donated: each step scatters into it and the
            # old value is dropped, so XLA may update the buffers in place
            # instead of copying the whole pool per token/chunk.  The mesh
            # rides in explicitly (the plan may be None on a meshed
            # engine) so the fused kernel can shard_map over it.
            abk_ = self.attn_backend
            mesh_ = mesh

            @functools.partial(jax.jit, donate_argnums=(1,))
            def _dec(params, pages, bt, pos, active, tokens):
                return decode_step_paged(params, pages, bt, pos, active,
                                         tokens, cfg_, plan_,
                                         attn_backend=abk_, mesh=mesh_)

            @functools.partial(jax.jit, donate_argnums=(1,))
            def _pf(params, pages, bt, tokens, pos0, seq_lens):
                return _prefill_chunk_fn(params, pages, bt, tokens, pos0,
                                         seq_lens, cfg_, plan_,
                                         attn_backend=abk_, mesh=mesh_)

            self._decode_paged = _dec
            self._prefill_paged = _pf
            # analytic cost tables (repro.obs.costs): the jitted decode /
            # prefill shapes are fixed at construction, so one memoized
            # table per dispatch kind prices every step.  Built lazily on
            # the first charged step — with obs disabled they never exist.
            self._cost_dims = None
            self._cost_specs = None
            self._decode_cost_table = None
            self._prefill_cost_table = None
            self._fork_cost_table = None
        else:
            self.prefix_cache = None
            if self.kv_bits:
                raise ValueError(
                    "kv_bits is wired through the paged engine "
                    "(int8 KV pages); mode='slots' serves the "
                    "full-precision cache only")
            self.cache = init_cache(cfg, n_slots, max_len)
            if mesh is not None:
                self.cache = jax.device_put(
                    self.cache, cache_shardings(mesh, self.cache))
            self.slot_req: List[Optional[Request]] = [None] * n_slots

            @jax.jit
            def _step(params, cache, tokens):
                return decode_step(params, cache, tokens, cfg_, plan_)

            self._step = _step

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: Optional[int] = None,
               *, priority: str = "default", tenant: str = "default"
               ) -> Request:
        """Enqueue a prompt; returns its :class:`Request` handle.

        Raises ``ValueError`` for malformed prompts (caller bugs) and
        :class:`AdmissionRejected` for load shedding (the bounded queue
        is full, or the prompt can never fit the page pool) — transient,
        retriable conditions a front-end turns into ``shed`` streams.
        """
        prompt = list(prompt)
        if not prompt:
            # an empty prompt leaves nothing to condition on (the old
            # engine crashed with an unbound ``logits`` here): reject at
            # the door — callers that want generation-from-nothing should
            # submit an explicit BOS token.
            raise ValueError(
                "empty prompt: submit at least one token (e.g. BOS)")
        if min(prompt) < 0 or max(prompt) >= self.cfg.vocab_size:
            # out-of-vocab ids embed to an all-zero one-hot, whose norm
            # divides by ~0 and decodes to non-finite logits — which the
            # fault isolation would then quarantine after burning its
            # retry budget.  Invalid input is a caller bug: reject it at
            # the door instead of diagnosing it as a device fault.
            bad = next(t for t in prompt
                       if t < 0 or t >= self.cfg.vocab_size)
            raise ValueError(
                f"prompt token {bad} outside the model vocabulary "
                f"[0, {self.cfg.vocab_size})")
        if len(prompt) > self.max_len - 2:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit max_len="
                f"{self.max_len} with room to generate (limit is "
                f"max_len - 2 = {self.max_len - 2})")
        if priority not in PRIORITY_WEIGHTS:
            raise ValueError(
                f"unknown priority {priority!r}; choose from "
                f"{sorted(PRIORITY_WEIGHTS)}")
        queue = self.sched.queue if self.mode == "paged" else self.queue
        if self.scfg.max_queue and len(queue) >= self.scfg.max_queue:
            self.shed_count += 1
            self.obs.on_shed("queue_full")
            raise AdmissionRejected("queue_full")
        if (self.mode == "paged"
                and pages_for(len(prompt) + 1, self.page_size)
                > self.alloc.n_pages - 1):
            # unreachable via the max_len check for sane pool sizes, but
            # a request that can never be granted must not sit in the
            # queue deadlocking everything behind eviction+preemption
            self.shed_count += 1
            self.obs.on_shed("pool_too_small")
            raise AdmissionRejected("pool_too_small")
        req = Request(self._next_rid, prompt,
                      self.scfg.max_new_tokens if max_new_tokens is None
                      else max_new_tokens,
                      priority=priority, tenant=tenant)
        req.prefill_tokens = list(prompt)
        req.submit_t = self._clock()
        self._next_rid += 1
        queue.append(req)
        self.obs.on_submit(req.rid, len(prompt), req.submit_t)
        return req

    def has_work(self) -> bool:
        """Anything queued or resident?"""
        if self.mode == "paged":
            return self.sched.has_work()
        return bool(self.queue) or any(
            r is not None for r in self.slot_req)

    def step(self) -> List[Request]:
        """One scheduler iteration (admit → prefill chunk → decode token →
        retire); returns the requests that finished this step.  The unit
        the streaming front-end drives — ``run()`` is just this in a
        loop."""
        with self._mesh_ctx():
            return self._step_framed()

    def _step_framed(self) -> List[Request]:
        """One step with its telemetry framing (B/E span on the engine
        track, step counter + duration histogram).  Caller holds the
        mesh context."""
        t0 = self.obs.now()
        self.obs.step_begin()
        try:
            if self.mode == "paged":
                return self._step_paged()
            return self._step_slots()
        finally:
            self.obs.step_end(t0)

    def run(self) -> List[Request]:
        """Drive until queue + slots drain; returns completed requests."""
        # the mesh context makes the model-internal sharding hints live
        # (they are no-ops off-mesh); device placement itself was pinned at
        # construction via param/cache shardings.
        finished: List[Request] = []
        with self._mesh_ctx():
            while self.has_work():
                finished.extend(self._step_framed())
        return finished

    def cancel(self, req: Request, reason: str = "cancelled") -> bool:
        """Terminate a request *now*, wherever it is in its lifecycle.

        Queued: dropped from the queue.  Resident (mid-prefill or
        decoding): its pages are released immediately — including
        prefix-cache pins taken at admission and any partially-filled
        private pages of a chunked prefill — and pending copy-on-write
        forks are discarded before their dst page can be reused.  Tokens
        generated so far stay on ``req.output``.  Returns False if the
        request already reached a terminal state."""
        if req.done or req.cancelled:
            return False
        req.cancelled = True
        req.finish_reason = reason
        self._retry.pop(req.rid, None)
        self.obs.on_cancel(req.rid, reason)
        if self.mode == "paged":
            for slot, r in enumerate(self.sched.slot_req):
                if r is req:
                    self.sched.drop_forks(slot)
                    self.alloc.free_slot(slot)
                    self.sched.slot_req[slot] = None
                    return True
            try:
                self.sched.queue.remove(req)
            except ValueError:
                pass  # between retire bookkeeping and caller: already out
            return True
        for slot, r in enumerate(self.slot_req):
            if r is req:
                self.slot_req[slot] = None
                return True
        try:
            self.queue.remove(req)
        except ValueError:
            pass
        return True

    def request_phase(self, req: Request) -> str:
        """Lifecycle phase: ``queued`` | ``prefilling`` | ``decoding`` |
        ``done`` | ``cancelled`` (the front-end refines ``cancelled``
        into cancelled/timed-out via ``finish_reason``)."""
        if req.done:
            return "done"
        if req.cancelled:
            return "cancelled"
        slots = self.sched.slot_req if self.mode == "paged" else self.slot_req
        for r in slots:
            if r is req:
                if (req.last_logits is None
                        or req.prefill_pos < len(req.prefill_tokens)):
                    return "prefilling"
                return "decoding"
        return "queued"

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        return use_mesh(self.mesh)

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions if self.mode == "paged" else 0

    @property
    def prefill_computed(self) -> int:
        """Prompt tokens actually run through ``prefill_chunk`` (cache
        hits keep this below the total submitted prompt tokens)."""
        return self.sched.prefill_computed if self.mode == "paged" else 0

    def prefix_stats(self) -> Optional[Dict[str, int]]:
        """Prefix-cache counters (thin shim over :meth:`metrics`)."""
        return (self.prefix_cache.stats()
                if self.prefix_cache is not None else None)

    def metrics(self) -> Dict:
        """Unified engine snapshot: lifecycle counters, prefix-cache
        stats when a cache is attached, and — with ``repro.obs`` enabled
        — the full telemetry snapshot (registry + request states) under
        ``"obs"``.  Subsumes ``prefix_stats()`` / ``prefill_computed``
        (both kept as thin shims)."""
        out: Dict = {
            "mode": self.mode,
            "submitted": self._next_rid,
            "shed": self.shed_count,
            "preemptions": self.preemptions,
            "quarantined": self.quarantined,
            "prefill_computed": self.prefill_computed,
        }
        if self.prefix_cache is not None:
            out["prefix"] = self.prefix_cache.stats()
        out["ft"] = {
            "quarantined": self.quarantined,
            "retried": self.retried,
            "chaos": (self.chaos.summary()
                      if self.chaos is not None else {}),
        }
        if self.obs.enabled:
            out["obs"] = self.obs.snapshot()
            out["costs"] = (self.obs.costs.snapshot()
                            if self.obs.costs is not None else {})
        return out

    # ================================================= cost attribution
    def _cost_base(self):
        """Model dims + live linear specs for the ledger tables (the
        specs walk the *actual* param tree, so packed weights price at
        ``bits/8`` bytes per element)."""
        if self._cost_specs is None:
            self._cost_dims = obs.model_dims(self.cfg)
            self._cost_specs = obs.linear_specs(self.params)
        return self._cost_dims, self._cost_specs

    def _charge_decode(self, rids) -> None:
        """Charge one paged decode step to the cost ledger.  The jitted
        step always runs the full ``(n_slots, max_blocks·page_size)``
        shapes regardless of how many lanes are active, so one memoized
        table is exact for every step; attribution splits the step total
        across the lanes that actually decoded."""
        if not self.obs.enabled:
            return
        if self._decode_cost_table is None:
            dims, specs = self._cost_base()
            self._decode_cost_table = obs.decode_step_costs(
                dims, batch=self.n_slots,
                context=self._max_blocks * self.page_size,
                page_size=self.page_size,
                attn_backend=self.attn_backend,
                kv_bits=self.kv_bits, specs=specs)
        self.obs.on_costs(self._decode_cost_table, rids)

    def _charge_prefill(self, rids) -> None:
        """Charge one chunked-prefill dispatch (``(n_slots, chunk)``,
        padded — see :meth:`_charge_decode` for why one table is exact)."""
        if not self.obs.enabled:
            return
        if self._prefill_cost_table is None:
            dims, specs = self._cost_base()
            self._prefill_cost_table = obs.prefill_chunk_costs(
                dims, batch=self.n_slots, chunk=self.prefill_chunk,
                context=self._max_blocks * self.page_size,
                page_size=self.page_size,
                attn_backend=self.attn_backend,
                kv_bits=self.kv_bits, specs=specs)
        self.obs.on_costs(self._prefill_cost_table, rids)

    def _charge_fork(self, rid: int) -> None:
        """Charge one prefix-cache COW tail-page fork (pure page copies)."""
        if not self.obs.enabled:
            return
        if self._fork_cost_table is None:
            dims, _ = self._cost_base()
            self._fork_cost_table = obs.costs.fork_cost(
                dims, page_size=self.page_size, kv_bits=self.kv_bits)
        self.obs.on_costs(self._fork_cost_table, (rid,))

    # ==================================================== invariant audit
    def audit(self) -> None:
        """Prove the engine's host-side bookkeeping invariants; raises
        :class:`~repro.serve.pages.AuditError` naming the first
        violation.  Covers the allocator (refcount conservation, free
        list, block tables), the prefix-cache radix tree, and the
        scheduler (residency/queue consistency, pending forks).  Runs
        automatically per step/phase under ``ServeConfig(audit=...)``;
        callable directly from drills and tests.  Paged mode only."""
        if self.mode != "paged":
            raise ValueError("audit() proves page-pool invariants; "
                             "mode='slots' has no page pool")
        self.alloc.audit()
        if self.prefix_cache is not None:
            self.prefix_cache.audit()
        self._audit_sched()

    def _audit_sched(self) -> None:
        """Scheduler-level invariants: a live request sits in exactly one
        place (one lane, or the queue, never both/twice), no terminal
        request holds a lane, and every pending COW fork's target is a
        page its owner lane actually maps."""
        def fail(msg: str) -> None:
            raise AuditError(f"ServeEngine.audit: {msg}")

        resident: Dict[int, int] = {}
        for slot, req in enumerate(self.sched.slot_req):
            if req is None:
                if self.alloc._mapped[slot]:
                    fail(f"empty lane {slot} still maps "
                         f"{len(self.alloc._mapped[slot])} pages")
                continue
            if id(req) in resident:
                fail(f"rid {req.rid} resident in two lanes")
            resident[id(req)] = slot
            if req.done or req.cancelled:
                fail(f"terminal rid {req.rid} still resident in "
                     f"lane {slot}")
        seen_q = set()
        for req in self.sched.queue:
            if id(req) in resident:
                fail(f"rid {req.rid} both queued and resident")
            if id(req) in seen_q:
                fail(f"rid {req.rid} queued twice")
            seen_q.add(id(req))
            if req.done or req.cancelled:
                fail(f"terminal rid {req.rid} still queued")
        for slot, _src, dst in self.sched.pending_forks:
            req = self.sched.slot_req[slot]
            if req is None:
                fail(f"pending fork owned by empty lane {slot}")
            if dst not in self.alloc._mapped[slot]:
                fail(f"pending fork dst page {dst} not mapped by its "
                     f"owner lane {slot}")

    # ================================================= snapshot / restore
    def snapshot(self) -> Dict:
        """Crash-consistent snapshot of all serving state (paged mode).

        Returns ``{"arrays": {name: np.ndarray}, "host": <JSON-able>}``
        covering the device page pool, the sampling key, allocator
        tables, the prefix-cache radix tree, scheduler queues (including
        fair-share virtual time and pending COW forks), and every
        in-flight request — everything :meth:`restore` needs to resume
        token-identically.  Arrays are materialized to host numpy at
        snapshot time, so later (donating) engine steps cannot mutate a
        taken snapshot.  Terminal requests are the caller's state, not
        the engine's, and are not captured; telemetry state restarts
        fresh.  Persist with :meth:`save_snapshot`.
        """
        if self.mode != "paged":
            raise ValueError("snapshot() covers the paged engine only")
        arrays: Dict[str, np.ndarray] = {
            "pages/k": np.asarray(self.pages.k),
            "pages/v": np.asarray(self.pages.v),
            "key": np.asarray(self.key),
        }
        if self.pages.quantized:
            arrays["pages/k_scale"] = np.asarray(self.pages.k_scale)
            arrays["pages/v_scale"] = np.asarray(self.pages.v_scale)

        live: List[Request] = [r for r in self.sched.slot_req
                               if r is not None]
        live += [r for r in self.sched.queue if r not in live]
        reqs = []
        for r in live:
            if r.last_logits is not None:
                arrays[f"logits/{r.rid}"] = np.asarray(r.last_logits)
            reqs.append({
                "rid": r.rid,
                "prompt": [int(t) for t in r.prompt],
                "max_new_tokens": r.max_new_tokens,
                "output": [int(t) for t in r.output],
                "prefill_tokens": [int(t) for t in r.prefill_tokens],
                "prefill_pos": r.prefill_pos,
                "admit_seq": r.admit_seq,
                "preemptions": r.preemptions,
                "cached_tokens": r.cached_tokens,
                "submit_t": r.submit_t,
                "ttft": r.ttft,
                "priority": r.priority,
                "tenant": r.tenant,
                "retries": r.retries,
            })

        sched: Dict = {
            "queue": [r.rid for r in self.sched.queue],
            "slots": [r.rid if r is not None else None
                      for r in self.sched.slot_req],
            "admit_seq": self.sched._admit_seq,
            "preemptions": self.sched.preemptions,
            "prefill_computed": self.sched.prefill_computed,
            "pending_forks": [list(f) for f in self.sched.pending_forks],
        }
        if isinstance(self.sched, BudgetScheduler):
            sched["vtime"] = [[t, p, vt] for (t, p), vt
                              in self.sched._vtime.items()]

        host: Dict = {
            "geometry": {
                "family": self.cfg.family,
                "n_slots": self.n_slots,
                "max_len": self.max_len,
                "page_size": self.page_size,
                "n_pages": self.alloc.n_pages,
                "prefill_chunk": self.prefill_chunk,
                "kv_bits": self.kv_bits,
                "sched": type(self.sched).__name__,
                "prefix_cache": self.prefix_cache is not None,
            },
            "engine": {
                "next_rid": self._next_rid,
                "shed_count": self.shed_count,
                "quarantined": self.quarantined,
                "retried": self.retried,
                "engine_step": self._engine_step,
            },
            "alloc": {
                "free": [int(p) for p in self.alloc.free],
                "pos": [int(x) for x in self.alloc.pos],
                "mapped": [[int(p) for p in m]
                           for m in self.alloc._mapped],
            },
            "requests": reqs,
            "sched": sched,
            "retry": {str(rid): [pol.restarts, pol.last_failure_step]
                      for rid, pol in self._retry.items()},
        }
        if self.prefix_cache is not None:
            host["cache"] = self.prefix_cache.snapshot_state()
        return {"arrays": arrays, "host": host}

    def restore(self, snap: Dict) -> None:
        """Load a :meth:`snapshot` into this (same-configuration) engine.

        The engine must have been constructed with the same geometry —
        family, slots, lengths, page pool, kv_bits, scheduler class and
        prefix-cache setting (validated; mesh placement may differ: the
        pool is re-placed under this engine's shardings).  After restore,
        stepping resumes exactly where the snapshot was taken: the
        recovery drill pins greedy outputs token-identical to the
        uninterrupted run.
        """
        if self.mode != "paged":
            raise ValueError("restore() covers the paged engine only")
        host = snap["host"]
        geom = host["geometry"]
        mine = {
            "family": self.cfg.family,
            "n_slots": self.n_slots,
            "max_len": self.max_len,
            "page_size": self.page_size,
            "n_pages": self.alloc.n_pages,
            "prefill_chunk": self.prefill_chunk,
            "kv_bits": self.kv_bits,
            "sched": type(self.sched).__name__,
            "prefix_cache": self.prefix_cache is not None,
        }
        diff = {k: (geom.get(k), mine[k]) for k in mine
                if geom.get(k) != mine[k]}
        if diff:
            raise ValueError(
                f"snapshot geometry does not match this engine: {diff}")

        arrays = snap["arrays"]
        pages = KVPages(
            np.asarray(arrays["pages/k"]), np.asarray(arrays["pages/v"]),
            (np.asarray(arrays["pages/k_scale"])
             if "pages/k_scale" in arrays else None),
            (np.asarray(arrays["pages/v_scale"])
             if "pages/v_scale" in arrays else None),
            self.page_size, self.kv_bits)
        if self.mesh is not None:
            self.pages = jax.device_put(
                pages, cache_shardings(self.mesh, pages))
        else:
            self.pages = jax.tree_util.tree_map(jnp.asarray, pages)
        self.key = jnp.asarray(np.asarray(arrays["key"]))

        # allocator first: the cache's blocked recount reads refcounts
        alloc = host["alloc"]
        self.alloc.free = [int(p) for p in alloc["free"]]
        self.alloc._mapped = [[int(p) for p in m]
                              for m in alloc["mapped"]]
        self.alloc.pos[:] = alloc["pos"]
        self.alloc.block_tables[:, :] = NULL_PAGE
        self.alloc.refcount[:] = 0
        for slot, mapped in enumerate(self.alloc._mapped):
            for blk, page in enumerate(mapped):
                self.alloc.block_tables[slot, blk] = page
                self.alloc.refcount[page] += 1
        if self.prefix_cache is not None:
            self.prefix_cache.restore_state(host["cache"])

        by_rid: Dict[int, Request] = {}
        for r in host["requests"]:
            req = Request(r["rid"], list(r["prompt"]),
                          r["max_new_tokens"],
                          priority=r["priority"], tenant=r["tenant"])
            req.output = list(r["output"])
            req.prefill_tokens = list(r["prefill_tokens"])
            req.prefill_pos = r["prefill_pos"]
            req.admit_seq = r["admit_seq"]
            req.preemptions = r["preemptions"]
            req.cached_tokens = r["cached_tokens"]
            req.submit_t = r["submit_t"]
            req.ttft = r["ttft"]
            req.retries = r["retries"]
            lg = arrays.get(f"logits/{req.rid}")
            if lg is not None:
                req.last_logits = np.asarray(lg)
            by_rid[req.rid] = req

        sched = host["sched"]
        self.sched.queue = collections.deque(
            by_rid[rid] for rid in sched["queue"])
        self.sched.slot_req = [
            by_rid[rid] if rid is not None else None
            for rid in sched["slots"]]
        self.sched._admit_seq = sched["admit_seq"]
        self.sched.preemptions = sched["preemptions"]
        self.sched.prefill_computed = sched["prefill_computed"]
        self.sched.pending_forks = [
            (int(s), int(src), int(dst))
            for s, src, dst in sched["pending_forks"]]
        if isinstance(self.sched, BudgetScheduler):
            self.sched._vtime = {(t, p): vt
                                 for t, p, vt in sched.get("vtime", [])}

        eng = host["engine"]
        self._next_rid = eng["next_rid"]
        self.shed_count = eng["shed_count"]
        self.quarantined = eng["quarantined"]
        self.retried = eng.get("retried", 0)  # absent in older snapshots
        self._engine_step = eng["engine_step"]
        self._retry = {}
        for rid, (restarts, last_step) in host["retry"].items():
            self._retry[int(rid)] = RestartPolicy(
                max_restarts=self.scfg.max_request_retries,
                backoff_s=0.0,
                reset_after_steps=self.scfg.retry_reset_steps,
                restarts=restarts, last_failure_step=last_step)
        # in-flight requests resume under *this* engine's telemetry:
        # fresh timelines open for every restored rid (any stale
        # non-terminal timeline from a prior run of this engine is
        # discarded), so their spans terminate cleanly on retire
        self.obs.on_restore(sorted(by_rid))

    def save_snapshot(self, directory: str, step: int) -> str:
        """Persist :meth:`snapshot` through ``repro.ckpt`` (manifest +
        checksummed shards, atomic commit).  Returns the written path."""
        from repro.ckpt import save_checkpoint

        snap = self.snapshot()
        specs = {name: [list(a.shape), str(a.dtype)]
                 for name, a in snap["arrays"].items()}
        return save_checkpoint(
            directory, step, snap["arrays"],
            extra={"kind": "serve-engine-snapshot",
                   "host": snap["host"], "array_specs": specs})

    def load_snapshot(self, directory: str,
                      step: Optional[int] = None) -> int:
        """Restore from a :meth:`save_snapshot` directory (``step=None``
        loads the latest committed snapshot).  Returns the step loaded.

        The array template ``repro.ckpt`` needs is rebuilt from the
        manifest's ``array_specs`` — snapshots are self-describing, so
        restore needs no record of which requests were in flight.
        """
        import json
        import os

        from repro.ckpt import load_checkpoint
        from repro.ckpt.checkpoint import latest_step

        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no committed snapshot in {directory}")
        final = os.path.join(directory, f"step_{step:08d}")
        with open(os.path.join(final, "manifest_0.json")) as f:
            extra = json.load(f)["extra"]
        if extra.get("kind") != "serve-engine-snapshot":
            raise ValueError(
                f"{final} is not a serve-engine snapshot")
        template = {name: np.zeros(shape, dtype=np.dtype(dt))
                    for name, (shape, dt)
                    in extra["array_specs"].items()}
        arrays, _ = load_checkpoint(directory, template, step)
        self.restore({"arrays": arrays, "host": extra["host"]})
        return step

    # ================================================== paged internals
    def _step_paged(self) -> List[Request]:
        self._engine_step += 1
        finished: List[Request] = []
        self._errored_step = []  # quarantines land here (terminal too)
        if self.chaos is not None and self.chaos.fire("preempt_storm"):
            # mass eviction drill: recompute-style, token-preserving
            # (fire() itself reports the fault through chaos.obs)
            self.sched.preempt_storm()
        with self.obs.phase("admit"):
            self.sched.admit()
            self._apply_forks()
        self._maybe_audit(2)
        with self.obs.phase("prefill"):
            self._prefill_once()
        self._maybe_audit(2)
        # pre-decode retire: max_new_tokens=0 must emit no tokens
        finished.extend(self._retire_paged(limit_only=True))
        with self.obs.phase("decode"):
            self._decode_once_paged()
        self._maybe_audit(2)
        finished.extend(self._retire_paged())
        self._maybe_audit(1)
        finished.extend(self._errored_step)
        return finished

    def _maybe_audit(self, level: int) -> None:
        """Run :meth:`audit` when ``ServeConfig.audit`` reaches
        ``level`` (1 = post-step, 2 = also after each phase)."""
        if self.scfg.audit < level:
            return
        try:
            self.audit()
        except AuditError:
            self.obs.on_audit(self.scfg.audit, False)
            raise
        self.obs.on_audit(self.scfg.audit, True)

    def _apply_forks(self) -> None:
        """Run the device copies of pending copy-on-write forks (mid-page
        cache hits recorded at admission) before anything reads or writes
        the forked pages."""
        for slot, src, dst in self.sched.pending_forks:
            self.pages = fork_tail_page(
                self.pages, jnp.int32(src), jnp.int32(dst))
            owner = self.sched.slot_req[slot]
            if owner is not None:
                self._charge_fork(owner.rid)
        self.sched.pending_forks.clear()

    def _prefill_once(self) -> None:
        """Advance every pending prompt by one batched chunk."""
        codebooks = (self.cfg.n_codebooks
                     if self.cfg.family == "audio" else 0)
        batch = self.sched.prefill_batch(audio_codebooks=codebooks)
        if batch is None:
            return
        tokens, pos0, seq_lens, lanes = batch
        t0 = self.obs.now()
        bt, _ = self.alloc.device_tables(self._table_shardings)
        with self.obs.annotate("serve.prefill_chunk"):
            logits, self.pages = self._prefill_paged(
                self.params, self.pages, bt, jnp.asarray(tokens),
                jnp.asarray(pos0), jnp.asarray(seq_lens))
            lg = np.asarray(logits)  # host sync: the chunk has landed
        self.obs.on_prefill(
            [(slot, self.sched.slot_req[slot].rid, n)
             for slot, n in lanes], t0)
        self._charge_prefill(
            [self.sched.slot_req[slot].rid for slot, _ in lanes])
        fault_slot, lg = self._inject_lane_chaos(
            [s for s, _ in lanes], lg)
        for slot, n_real in lanes:
            req = self.sched.slot_req[slot]
            if slot == fault_slot:
                # simulated device error on this lane's chunk: none of
                # its bookkeeping advances — retry or quarantine
                self._fault(slot, req, "step_fault")
                continue
            req.prefill_pos += n_real
            self.alloc.pos[slot] += n_real
            if req.prefill_pos >= len(req.prefill_tokens):
                last = lg[slot, -1]
                if not np.all(np.isfinite(last)):
                    # non-finite logits must be caught *before* the
                    # prefix-cache insert: poisoned KV pages must never
                    # be published for other requests to share
                    self._fault(slot, req, "nan_logits")
                    continue
                req.last_logits = last
                if self.prefix_cache is not None:
                    # the prompt's full pages are write-frozen from here
                    # (decode appends at pos >= len(prefill_tokens)):
                    # publish them for other requests to share
                    self.prefix_cache.insert(req.prefill_tokens,
                                             self.alloc.block_row(slot))

    def _decode_once_paged(self) -> None:
        lanes = self.sched.decode_lanes()
        # page grant first (may preempt): a preempted lane drops out of
        # this step and resumes via re-prefill with identical greedy state
        ready = []
        for slot, req in lanes:
            if len(req.output) >= req.max_new_tokens:
                continue
            if self.sched.slot_req[slot] is not req:
                continue  # preempted by an earlier lane's grant this loop
            if self.sched.grant_decode_page(slot):
                ready.append((slot, req))
        # a later grant may have preempted an earlier-granted lane: keep
        # only lanes still resident
        ready = [(s, r) for s, r in ready if self.sched.slot_req[s] is r]
        if not ready:
            return
        self.sched.charge_decode(ready)
        updates: Dict[int, int] = {}
        tnow = self._clock()
        for slot, req in ready:
            tok = self._sample_next(req)
            if not req.output and req.ttft is None:
                req.ttft = tnow - req.submit_t
                self.obs.on_first_token(req.rid, req.ttft, tnow)
            else:
                self.obs.on_token(req.rid, tnow)
            req.output.append(tok)
            updates[slot] = tok
        tokens = self._lane_tokens(updates)
        active = jnp.asarray(self.sched.lane_mask(updates))
        t0 = self.obs.now()
        bt, pos = self.alloc.device_tables(self._table_shardings)
        with self.obs.annotate("serve.decode_step"):
            logits, self.pages = self._decode_paged(
                self.params, self.pages, bt, pos, active, tokens)
            lg = np.asarray(logits)  # host sync: the step has landed
        self.obs.on_decode([(s, r.rid) for s, r in ready], t0)
        self._charge_decode([r.rid for _, r in ready])
        fault_slot, lg = self._inject_lane_chaos(
            [s for s, _ in ready], lg)
        for slot, req in ready:
            if slot == fault_slot:
                self._fault(slot, req, "step_fault")
                continue
            last = lg[slot, -1]
            if not np.all(np.isfinite(last)):
                # the token appended above was sampled from *valid*
                # logits and its KV write landed; the recompute retry
                # replays it, so greedy output is unchanged — only the
                # poisoned logits are discarded
                self._fault(slot, req, "nan_logits")
                continue
            self.alloc.pos[slot] += 1
            req.last_logits = last

    def _retire_paged(self, limit_only: bool = False) -> List[Request]:
        done = []
        for slot, req in enumerate(self.sched.slot_req):
            if req is None:
                continue
            if self._should_retire(req, limit_only):
                req.done = True
                req.finish_reason = "length"
                done.append(req)
                self.sched.drop_forks(slot)
                self.alloc.free_slot(slot)
                self.sched.slot_req[slot] = None
                self._retry.pop(req.rid, None)
                self.obs.on_retire(req.rid, "length", len(req.output))
        return done

    # ============================================== faults / quarantine
    def _inject_lane_chaos(self, slots: List[int], lg: np.ndarray):
        """Consult the chaos injector after a dispatch landed: returns
        ``(fault_slot, lg)`` where ``fault_slot`` (or None) takes a
        simulated device error, and ``lg`` may have one lane's logits
        overwritten with NaN (a copy — the injected poison then flows
        through the same non-finite detection a real fault would)."""
        if self.chaos is None or not slots:
            return None, lg
        fault_slot = None
        if self.chaos.fire("step_fault"):
            fault_slot = slots[self.chaos.pick("step_fault", len(slots))]
        if self.chaos.fire("nan_logits"):
            victim = slots[self.chaos.pick("nan_logits", len(slots))]
            lg = np.array(lg)  # np.asarray of a jax array may be read-only
            lg[victim] = np.nan
        return fault_slot, lg

    def _scrub_slot_pages(self, slot: int) -> None:
        """Zero a faulted lane's privately-owned pages before they return
        to the free list.

        A non-finite fault has written NaN into the lane's KV pages, and
        the attention paths mask additively (``score + -inf``) — adding
        ``-inf`` to a NaN score is still NaN, so a stale poisoned value
        in the masked tail of a reused page contaminates the *next*
        tenant's softmax.  Pages the prefix cache holds (or other lanes
        share) are skipped: they were write-frozen by a clean prefill
        before this request's fault and other requests still read them.
        """
        cached = (set(self.prefix_cache.pages())
                  if self.prefix_cache is not None else set())
        private = [p for p in self.alloc._mapped[slot]
                   if self.alloc.refcount[p] == 1 and p not in cached]
        if not private:
            return
        idx = jnp.asarray(private, jnp.int32)
        kw = {"k": self.pages.k.at[:, idx].set(0),
              "v": self.pages.v.at[:, idx].set(0)}
        if self.pages.quantized:
            kw["k_scale"] = self.pages.k_scale.at[:, idx].set(0)
            kw["v_scale"] = self.pages.v_scale.at[:, idx].set(0)
        self.pages = self.pages.replace(**kw)

    def _fault(self, slot: int, req: Request, kind: str) -> None:
        """One lane's step failed (simulated device error or non-finite
        logits).  Isolation is per-request: within the restart budget the
        request is requeued recompute-style (identical to preemption —
        greedy output is token-preserved); past it, quarantined with
        ``finish_reason="error"``.  Every other lane is untouched.
        """
        self.obs.on_fault(req.rid, kind)
        pol = self._retry.get(req.rid)
        if pol is None:
            pol = self._retry[req.rid] = RestartPolicy(
                max_restarts=self.scfg.max_request_retries,
                backoff_s=0.0,
                reset_after_steps=self.scfg.retry_reset_steps)
        try:
            pol.on_failure(RuntimeError(kind), self._engine_step)
        except RuntimeError:
            self._quarantine(slot, req, kind)
            return
        # recompute-style retry: exactly the preemption path — pages
        # scrubbed and released, generated tokens become prefill, front
        # of the queue
        self._scrub_slot_pages(slot)
        self.sched.drop_forks(slot)
        self.alloc.free_slot(slot)
        self.sched.slot_req[slot] = None
        req.prefill_tokens = list(req.prompt) + list(req.output)
        req.prefill_pos = 0
        req.cached_tokens = 0
        req.last_logits = None
        req.retries += 1
        self.retried += 1
        self.sched.queue.appendleft(req)
        self.obs.on_retry(req.rid, kind, pol.restarts)

    def _quarantine(self, slot: int, req: Request, kind: str) -> None:
        """Remove a request whose restart budget is spent: pages and
        prefix-cache pins release, pending forks drop, and the request
        terminates with ``finish_reason="error"`` — tokens generated so
        far stay on ``req.output`` for the caller."""
        req.cancelled = True
        req.finish_reason = "error"
        self._scrub_slot_pages(slot)
        self.sched.drop_forks(slot)
        self.alloc.free_slot(slot)
        self.sched.slot_req[slot] = None
        self._retry.pop(req.rid, None)
        self.quarantined += 1
        self._errored_step.append(req)  # step() returns terminals
        self.obs.on_quarantine(req.rid, kind, len(req.output))

    # ================================================== slots internals
    def _step_slots(self) -> List[Request]:
        finished: List[Request] = []
        self._admit()
        # pre-decode retire: max_new_tokens=0 must emit no tokens
        finished.extend(self._retire(limit_only=True))
        self._decode_one()
        finished.extend(self._retire())
        return finished

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[slot] = req
                self.obs.on_admit(req.rid, slot, 0)
                self._reset_slot(slot)
                self._prefill_slot(slot, req)

    def _reset_slot(self, slot: int):
        """Reset one slot's cache state before reuse.

        Without this a request admitted into a retired request's slot
        inherits its predecessor's cache position — the old engine silently
        decoded with the previous request's KV prefix (and, for ssm/hybrid,
        recurrent state) as context.  Only ``pos`` and the read-modify-write
        recurrent leaves (``conv``/``h``) need clearing: stale K/V at
        positions <= cur_pos is always freshly overwritten before it is
        read, and positions beyond cur_pos are masked.
        """

        def reset(path, leaf):
            top = path[0].key if hasattr(path[0], "key") else None
            if top == "pos":
                return leaf.at[slot].set(0)
            if top in ("conv", "h"):
                unstacked = any(
                    isinstance(p, jax.tree_util.SequenceKey) for p in path)
                idx = (slot,) if unstacked else (slice(None), slot)
                return leaf.at[idx].set(0)
            return leaf

        self.cache = jax.tree_util.tree_map_with_path(reset, self.cache)

    def _prefill_slot(self, slot: int, req: Request):
        """Prompt tokens enter the slot's cache via sequential decode (one
        slot at a time — the legacy baseline; the paged engine replaces
        this loop with batched chunked prefill)."""
        logits = None
        t0 = self.obs.now()
        with self.obs.annotate("serve.prefill_slot"):
            for t in req.prompt:
                tok = self._slot_tokens({slot: t})
                logits, self.cache = self._masked_step(tok, only_slot=slot)
            req.last_logits = np.asarray(logits[slot, -1])
        self.obs.on_prefill([(slot, req.rid, len(req.prompt))], t0)

    def _slot_tokens(self, updates: Dict[int, int]) -> jnp.ndarray:
        if self.cfg.family == "audio":
            toks = np.zeros((self.n_slots, 1, self.cfg.n_codebooks), np.int32)
            for s, t in updates.items():
                toks[s, 0, :] = t
        else:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for s, t in updates.items():
                toks[s, 0] = t
        return jnp.asarray(toks)

    _lane_tokens = _slot_tokens  # paged mode: same (B, 1[, K]) layout

    def _masked_step(self, tokens, only_slot: Optional[int] = None):
        """Advance decode; slots other than ``only_slot`` (when given) have
        their cache position frozen by restoring pos afterwards."""
        logits, new_cache = self._step(self.params, self.cache, tokens)
        if only_slot is not None:
            keep = jnp.arange(self.n_slots) == only_slot
            new_cache = self._merge_cache(self.cache, new_cache, keep)
        self.cache = new_cache
        return logits, self.cache

    def _merge_cache(self, old, new, keep: jnp.ndarray):
        def merge(path, o, n):
            if o.ndim == 0 or o.shape == ():
                return n
            # the batch (slot) axis position differs by leaf: ``pos`` and
            # unstacked tuple entries are (B, ...), stacked k/v/conv/h are
            # (L, B, ...).  Decide from the leaf's path, not its shape —
            # shape[0] == n_slots is ambiguous whenever n_layers happens
            # to equal n_slots (the old heuristic then merged along the
            # layer axis and corrupted every slot).
            top = path[0].key if hasattr(path[0], "key") else None
            unstacked = any(
                isinstance(p, jax.tree_util.SequenceKey) for p in path)
            batch_ax = 0 if (top == "pos" or unstacked or o.ndim < 2) else 1
            shape = [1] * o.ndim
            shape[batch_ax] = -1
            return jnp.where(keep.reshape(shape), n, o)

        return jax.tree_util.tree_map_with_path(merge, old, new)

    def _decode_one(self):
        active = {s: r for s, r in enumerate(self.slot_req) if r is not None}
        if not active:
            return
        updates = {}
        tnow = self._clock()
        for slot, req in active.items():
            if req.last_logits is None:
                continue
            if len(req.output) >= req.max_new_tokens:
                continue
            tok = self._sample_next(req)
            if not req.output and req.ttft is None:
                req.ttft = tnow - req.submit_t
                self.obs.on_first_token(req.rid, req.ttft, tnow)
            else:
                self.obs.on_token(req.rid, tnow)
            req.output.append(tok)
            updates[slot] = tok
        if not updates:
            return
        tokens = self._slot_tokens(updates)
        keep = jnp.asarray([s in updates for s in range(self.n_slots)])
        t0 = self.obs.now()
        with self.obs.annotate("serve.decode_step"):
            logits, new_cache = self._step(self.params, self.cache, tokens)
            self.cache = self._merge_cache(self.cache, new_cache, keep)
            lg = np.asarray(logits)  # host sync: the step has landed
        self.obs.on_decode([(s, self.slot_req[s].rid) for s in updates], t0)
        for slot in updates:
            self.slot_req[slot].last_logits = lg[slot, -1]

    def _retire(self, limit_only: bool = False) -> List[Request]:
        done = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self._should_retire(req, limit_only):
                req.done = True
                req.finish_reason = "length"
                done.append(req)
                self.slot_req[slot] = None
                self.obs.on_retire(req.rid, "length", len(req.output))
        return done

    # ------------------------------------------------------------ shared
    def _sample_next(self, req: Request) -> int:
        """Sample the next token from a request's last logits.

        ``last_logits`` is ``(V,)``, or ``(K, V)`` for audio — the engine's
        token stream carries one id broadcast across codebooks, so the
        audio path samples codebook 0 (the seed engine crashed here trying
        to scalar-convert a (K,) sample).
        """
        last = jnp.asarray(req.last_logits)
        if last.ndim == 1:
            last = last[None]
        self.key, sub = jax.random.split(self.key)
        return int(sample(last, sub, self.scfg.temperature,
                          self.scfg.top_k)[0])

    def _should_retire(self, req: Request, limit_only: bool) -> bool:
        limit = len(req.output) >= req.max_new_tokens
        if limit_only:
            return limit
        overflow = len(req.prompt) + len(req.output) >= self.max_len - 1
        return limit or overflow
