"""Batched serving engine with slot-based continuous batching.

The engine holds weight-stationary (optionally IMAGine-quantized) params and
a fixed pool of batch slots.  Requests are admitted into free slots, the
decode loop advances *all* active slots with one fused ``decode_step`` per
token (the GEMV-bound regime the paper targets), and finished requests free
their slots for the admission queue — the standard continuous-batching
serving shape, minus paged KV (cache slots are fixed-length).

With ``EngineConfig.weight_bits > 0`` every linear runs the bit-plane GEMV
path: b/8 bytes of weight traffic per MAC, the paper's memory-capacity
scaling argument applied to TPU HBM.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import EngineConfig, ModelConfig, ServeConfig
from repro.engine import resolve_plan
from repro.models import decode_step, init_cache, quantize_params
from repro.models.transformer import prefill
from repro.serve.sampler import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: Optional[ServeConfig] = None,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        # the EngineConfig is resolved into an EnginePlan exactly once, at
        # construction; the plan is the only engine object the decode loop
        # ever sees.
        self.plan = resolve_plan(self.scfg.engine)
        self.eng = self.plan  # back-compat alias
        if self.plan is not None:
            params = quantize_params(params, cfg, self.plan.bits)
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)

        self.cache = init_cache(cfg, n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: Deque[Request] = collections.deque()
        self._next_rid = 0

        cfg_ = self.cfg
        plan_ = self.plan

        @jax.jit
        def _step(params, cache, tokens):
            return decode_step(params, cache, tokens, cfg_, plan_)

        self._step = _step

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: Optional[int] = None
               ) -> Request:
        req = Request(self._next_rid, list(prompt),
                      max_new_tokens or self.scfg.max_new_tokens)
        self._next_rid += 1
        self.queue.append(req)
        return req

    def run(self) -> List[Request]:
        """Drive until queue + slots drain; returns completed requests."""
        finished: List[Request] = []
        while self.queue or any(r is not None for r in self.slot_req):
            self._admit()
            self._decode_one()
            finished.extend(self._retire())
        return finished

    # ------------------------------------------------------------- internals
    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[slot] = req
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Prompt tokens enter the slot's cache via sequential decode (one
        slot at a time; the batched-prefill path is exercised by the
        prefill_32k dry-run cells)."""
        for t in req.prompt:
            tok = self._slot_tokens({slot: t})
            logits, self.cache = self._masked_step(tok, only_slot=slot)
        req._last_logits = np.asarray(logits[slot, -1])

    def _slot_tokens(self, updates: Dict[int, int]) -> jnp.ndarray:
        if self.cfg.family == "audio":
            toks = np.zeros((self.n_slots, 1, self.cfg.n_codebooks), np.int32)
            for s, t in updates.items():
                toks[s, 0, :] = t
        else:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for s, t in updates.items():
                toks[s, 0] = t
        return jnp.asarray(toks)

    def _masked_step(self, tokens, only_slot: Optional[int] = None):
        """Advance decode; slots other than ``only_slot`` (when given) have
        their cache position frozen by restoring pos afterwards."""
        logits, new_cache = self._step(self.params, self.cache, tokens)
        if only_slot is not None:
            keep = jnp.arange(self.n_slots) == only_slot
            new_cache = self._merge_cache(self.cache, new_cache, keep)
        self.cache = new_cache
        return logits, self.cache

    def _merge_cache(self, old, new, keep: jnp.ndarray):
        def merge(o, n):
            if o.ndim == 0 or o.shape == ():
                return n
            # batch axis position differs by leaf: pos is (B,), k/v are
            # (L, B, ...), conv/h are (L, B, ...)
            if o.shape[0] == self.n_slots:
                k = keep.reshape((-1,) + (1,) * (o.ndim - 1))
            else:
                k = keep.reshape((1, -1) + (1,) * (o.ndim - 2))
            return jnp.where(k, n, o)

        return jax.tree.map(merge, old, new)

    def _decode_one(self):
        active = {s: r for s, r in enumerate(self.slot_req) if r is not None}
        if not active:
            return
        updates = {}
        for slot, req in active.items():
            last = getattr(req, "_last_logits", None)
            if last is None:
                continue
            self.key, sub = jax.random.split(self.key)
            tok = int(sample(jnp.asarray(last[None]), sub,
                             self.scfg.temperature, self.scfg.top_k)[0])
            req.output.append(tok)
            updates[slot] = tok
        if not updates:
            return
        tokens = self._slot_tokens(updates)
        keep = jnp.asarray([s in updates for s in range(self.n_slots)])
        logits, new_cache = self._step(self.params, self.cache, tokens)
        self.cache = self._merge_cache(self.cache, new_cache, keep)
        lg = np.asarray(logits)
        for slot in updates:
            self.slot_req[slot]._last_logits = lg[slot, -1]

    def _retire(self) -> List[Request]:
        done = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            limit = len(req.output) >= req.max_new_tokens
            overflow = len(req.prompt) + len(req.output) >= self.max_len - 1
            if limit or overflow:
                req.done = True
                done.append(req)
                self.slot_req[slot] = None
        return done
