"""Prefix cache: radix-tree KV reuse with ref-counted copy-on-write pages.

IMAGine's premise is that data already resident in memory should be
computed on in place, not re-materialized.  The serving stack violated
that at the *request* level: every request re-prefilled its system prompt
even when thousands of requests share it, re-writing identical KV pages
the pool already holds.  This module makes the page pool shareable across
requests:

* **Radix tree at page granularity.**  A host-side trie over token-id
  prefixes whose nodes own *full* KV pages: a node at depth ``d`` is keyed
  by the ``page_size`` token ids covering logical positions
  ``[d·page_size, (d+1)·page_size)`` and owns the physical page holding
  their KV (for every layer — pages span all layers, so one node is one
  page id).  KV for position ``t`` depends only on tokens ``<= t`` at
  absolute positions, so any request whose prompt walks the same path can
  reference the same physical pages byte-for-byte.

* **Matching** (:meth:`PrefixCache.match`) walks full pages greedily,
  then attempts one **mid-page** partial match: if the next cached page's
  tokens agree with the prompt for ``n < page_size`` leading slots, the
  donor page is cloned (:func:`repro.serve.pages.fork_tail_page` — copy
  on write) into a private page so the request can keep writing its own
  suffix into the remaining slots.  The total match is capped at
  ``len(prompt) - 1`` tokens: at least one suffix token always runs
  through ``prefill_chunk`` so the request has last-token logits to
  sample from.

* **Reference counts** live in the :class:`~repro.serve.pages.PageAllocator`
  (a page may back many block tables); the tree itself holds **no**
  refcount — a cached page whose refcount is 0 is *resident but idle*,
  and is the eviction currency.

* **LRU eviction** (:meth:`PrefixCache.evict`) reclaims refcount-0 cached
  pages leaf-first (an interior node is pinned by its descendants: a
  match must walk a contiguous path from the root) when the free list
  runs dry.  Eviction is wired *into* ``PageAllocator._take_page``, so it
  is always tried before the scheduler falls back to
  preemption-by-recompute — dropping an idle cached page is strictly
  cheaper than recomputing a live request.

All of this is host-side numpy/dict state, exactly like the block tables:
on a production mesh the tree and refcounts do not shard, only the page
pool they index does.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.telemetry import NULL_TELEMETRY
from repro.serve.pages import NULL_PAGE, PageAllocator


class MatchResult:
    """One prompt's cache-hit description (host-side, cheap).

    ``full_pages``: physical page ids whose whole ``page_size`` tokens
    matched, in block order.  ``partial``: ``(donor_page, n_valid)`` when
    the match continues ``n_valid`` tokens into a cached page (the COW
    fork case), else None.  ``matched_tokens``: total prefix length
    served from cache — the request prefills only from there.
    """

    __slots__ = ("full_pages", "partial", "matched_tokens")

    def __init__(self, full_pages: List[int],
                 partial: Optional[Tuple[int, int]], page_size: int):
        self.full_pages = full_pages
        self.partial = partial
        self.matched_tokens = len(full_pages) * page_size + (
            partial[1] if partial else 0)

    def __bool__(self) -> bool:
        return self.matched_tokens > 0


class _Node:
    """One cached full page: key = its page_size token ids, value = the
    physical page id.  Children are the pages that extend this prefix.

    ``blocked_children`` counts children that are *blocked* — pinned
    (refcount > 0) or with blocked descendants of their own.  A node is
    evictable-in-place exactly when it is unblocked (refcount 0 and
    ``blocked_children == 0``): its whole subtree could drain leaf-first.
    The cache maintains these counts incrementally so ``evictable_count``
    is O(1) instead of a full-tree walk per admission check.
    """

    __slots__ = ("children", "parent", "key", "page", "last_used",
                 "blocked_children")

    def __init__(self, parent: Optional["_Node"],
                 key: Optional[Tuple[int, ...]], page: int):
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.key = key
        self.page = page
        self.last_used = 0
        self.blocked_children = 0


class PrefixCache:
    """The radix tree + eviction policy over a :class:`PageAllocator`.

    Construction attaches the cache to the allocator: from then on the
    allocator keeps refcount-0 cached pages resident, counts them as
    allocatable capacity, and evicts through :meth:`evict` when the free
    list runs dry.
    """

    def __init__(self, alloc: PageAllocator, obs=None):
        self.alloc = alloc
        self.obs = obs if obs is not None else NULL_TELEMETRY
        self.page_size = alloc.page_size
        self.root = _Node(None, None, NULL_PAGE)
        self._by_page: Dict[int, _Node] = {}
        self._clock = 0
        # incrementally maintained eviction state: ``_blocked`` counts
        # non-root nodes that are pinned or have blocked descendants (so
        # evictable_count = cached - blocked, O(1)); ``_lru`` is a lazy
        # min-heap of (last_used, page) eviction candidates — entries are
        # validated (and re-queued if merely stale) at pop time instead
        # of being repaired on every touch.
        self._blocked = 0
        self._lru: List[Tuple[int, int]] = []
        # counters (surfaced by ServeEngine.prefix_stats / the bench)
        self.hits = 0            # admissions with matched_tokens > 0
        self.misses = 0          # admissions with no match
        self.hit_tokens = 0      # prefill tokens served from cache
        self.cow_forks = 0       # mid-page matches (one page copy each)
        self.inserted_pages = 0
        self.evicted_pages = 0
        # the allocator must notify refcount 0<->1 transitions of cached
        # pages (attach is idempotent; explicit re-attach stays legal)
        alloc.attach_cache(self)

    # ------------------------------------------------------------- basics
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def holds(self, page: int) -> bool:
        """Is this physical page resident in the tree?"""
        return page in self._by_page

    def pages(self):
        """All resident physical page ids (the allocator's audit uses
        this for page-conservation accounting)."""
        return self._by_page.keys()

    @property
    def cached_pages(self) -> int:
        return len(self._by_page)

    # -------------------------------------------- incremental block state
    def _is_blocked(self, node: _Node) -> bool:
        return (node.blocked_children > 0
                or self.alloc.refcount[node.page] > 0)

    def _mark_blocked(self, node: _Node) -> None:
        """``node`` just transitioned unblocked -> blocked; bubble the
        change up, stopping at the first ancestor whose own status does
        not flip (amortized O(1): the pin/unpin orders the allocator
        guarantees make the very first ancestor the stop in the common
        case)."""
        while True:
            self._blocked += 1
            parent = node.parent
            already = self._is_blocked(parent)
            parent.blocked_children += 1
            if parent is self.root or already:
                return
            node = parent

    def _mark_unblocked(self, node: _Node) -> None:
        """Mirror of :meth:`_mark_blocked` for blocked -> unblocked."""
        while True:
            self._blocked -= 1
            if not node.children:
                heapq.heappush(self._lru, (node.last_used, node.page))
            parent = node.parent
            parent.blocked_children -= 1
            if parent is self.root or self._is_blocked(parent):
                return
            node = parent

    def _on_pin(self, page: int) -> None:
        """Allocator hook: a cached page's refcount went 0 -> 1."""
        node = self._by_page[page]
        if node.blocked_children == 0:  # was unblocked; now pinned
            self._mark_blocked(node)

    def _on_unpin(self, page: int) -> None:
        """Allocator hook: a cached page's refcount went 1 -> 0."""
        node = self._by_page[page]
        if node.blocked_children == 0:  # no blocked subtree: unblocks
            self._mark_unblocked(node)

    # ------------------------------------------------------------ matching
    def match(self, tokens) -> MatchResult:
        """Longest cached prefix of ``tokens``, capped at ``len - 1``.

        Touches the LRU clock of every node on the matched path (and the
        mid-page donor).  Does **not** take references — the scheduler
        maps the result through ``PageAllocator.map_shared`` only once
        admission is certain.
        """
        ps = self.page_size
        limit = len(tokens) - 1  # >= 1 token must remain to prefill
        node, full = self.root, []
        d = 0
        while (d + 1) * ps <= limit:
            child = node.children.get(tuple(tokens[d * ps:(d + 1) * ps]))
            if child is None:
                break
            child.last_used = self._tick()
            full.append(child.page)
            node = child
            d += 1
        partial = None
        rem = limit - d * ps
        if rem > 0:
            best_n, best = 0, None
            for key, child in node.children.items():
                n = 0
                while n < rem and key[n] == tokens[d * ps + n]:
                    n += 1
                if n > best_n:
                    best_n, best = n, child
            if best is not None:
                best.last_used = self._tick()
                partial = (best.page, best_n)
        return MatchResult(full, partial, ps)

    # ----------------------------------------------------------- insertion
    def insert(self, tokens, block_row: np.ndarray) -> int:
        """Cache the full pages of a completed prefill.

        ``tokens``: the request's prefill token ids; ``block_row``: its
        block-table row (block ``d`` holds the page covering tokens
        ``[d·ps, (d+1)·ps)``).  Only *full* pages enter the tree — the
        partially-filled tail page keeps being written by decode and stays
        private.  Pages already cached for the same prefix (the request
        was itself a cache hit, or a cold duplicate raced in) are left in
        place; a cold duplicate's private copy simply never becomes
        shared and is freed at retire.  Returns the number of pages newly
        inserted.  Inserting takes no reference: the tree holds pages
        *resident*, the refcount only counts block-table owners.
        """
        ps = self.page_size
        node, new = self.root, 0
        for d in range(len(tokens) // ps):
            key = tuple(int(t) for t in tokens[d * ps:(d + 1) * ps])
            child = node.children.get(key)
            if child is None:
                page = int(block_row[d])
                if page == NULL_PAGE:
                    break  # block table shorter than the prompt: stop
                if page in self._by_page:
                    # a page id can live at one tree position only; this
                    # can't happen for a consistent allocator (shared
                    # pages match the existing node, private pages are
                    # fresh) — guard rather than corrupt the tree.
                    break
                child = _Node(node, key, page)
                node.children[key] = child
                self._by_page[page] = child
                child.last_used = self._tick()
                if self.alloc.refcount[page] > 0:
                    self._mark_blocked(child)  # pinned by its inserter
                else:
                    heapq.heappush(self._lru,
                                   (child.last_used, child.page))
                new += 1
            child.last_used = self._tick()
            node = child
        self.inserted_pages += new
        if new:
            self.obs.on_cache_insert(new)
        return new

    # ------------------------------------------------------------ eviction
    def evictable_count(self) -> int:
        """Pages reclaimable right now: cached nodes whose whole subtree
        (themselves included) is refcount-0 — exactly the pages a
        leaf-first eviction loop could drain.  Exactness matters: the
        scheduler's capacity-based admission counts these as available.

        O(1): ``cached - blocked``, where the blocked count is maintained
        incrementally on refcount 0<->1 transitions (allocator hooks) and
        insert/evict — this runs on *every* capacity check once the free
        list is short, so a full-tree walk per call melts admission
        throughput at production tree sizes.
        (:meth:`_recount_evictable` keeps the old walk as the
        property-test oracle.)
        """
        return len(self._by_page) - self._blocked

    def _recount_evictable(self) -> int:
        """Recompute :meth:`evictable_count` from scratch — the original
        full-tree walk, kept as the correctness oracle for the
        incremental counter (``tests/test_prefix_cache.py`` asserts they
        agree after random op sequences).

        Iterative post-order (a long prompt is one deep chain — one node
        per page — so recursion would hit Python's stack limit at a few
        thousand cached tokens).
        """
        ref = self.alloc.refcount
        # (evictable_in_subtree, whole_subtree_refcount_free) per node
        results: Dict[int, Tuple[int, bool]] = {}
        stack: List[Tuple[_Node, bool]] = [(self.root, False)]
        while stack:
            node, visited = stack.pop()
            if not visited:
                stack.append((node, True))
                for child in node.children.values():
                    stack.append((child, False))
                continue
            total, subtree_free = 0, True
            for child in node.children.values():
                t, f = results.pop(id(child))
                total += t
                subtree_free &= f
            if node is self.root:
                return total
            if subtree_free and ref[node.page] == 0:
                results[id(node)] = (total + 1, True)
            else:
                results[id(node)] = (total, False)
        return 0  # unreachable: the root always completes the walk

    def evict(self, n_pages: int) -> int:
        """Evict up to ``n_pages`` refcount-0 cached pages, LRU leaf-first,
        returning them to the allocator's free list.  Never touches a page
        with live references and never the null page.  Returns the number
        actually evicted.

        Victims pop off the lazy LRU heap in O(log n) instead of the old
        O(tree) scan per victim: entries are (last_used, page) snapshots,
        so a popped entry is *validated* against live state — gone,
        re-parented under children, or re-pinned means discard; merely
        touched since queueing means re-queue at its new age.  Evicting a
        leaf may expose its parent as the next candidate; it is pushed
        here rather than tracked on every touch.
        """
        ref = self.alloc.refcount
        evicted = 0
        while evicted < n_pages and self._lru:
            last_used, page = heapq.heappop(self._lru)
            node = self._by_page.get(page)
            if node is None or node.children or ref[page] != 0:
                continue  # stale: evicted already / interior / re-pinned
            if node.last_used != last_used:
                heapq.heappush(self._lru, (node.last_used, page))
                continue  # touched since queued: contend at its new age
            parent = node.parent
            del parent.children[node.key]
            del self._by_page[page]
            # victims are unblocked by construction, so the blocked count
            # and every ancestor's blocked_children are already correct
            self.alloc._reclaim_evicted(page)
            evicted += 1
            if (parent is not self.root and not parent.children
                    and ref[parent.page] == 0):
                heapq.heappush(self._lru, (parent.last_used, parent.page))
        self.evicted_pages += evicted
        if evicted:
            self.obs.on_cache_evict(evicted)
        return evicted

    # --------------------------------------------------- snapshot / restore
    def snapshot_state(self) -> Dict:
        """JSON-able tree state for ``ServeEngine.snapshot()``.

        Nodes are listed in DFS preorder (every parent precedes its
        children) keyed by page id — page ids are unique tree positions,
        so ``parent`` page 0 (the null page, the root's id) means the
        root.  The incremental eviction state (``_blocked``,
        ``blocked_children``, the LRU heap) is *not* serialized: it is
        derived state, recomputed from refcounts at restore.
        """
        nodes: List[Dict] = []
        stack: List[_Node] = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root:
                nodes.append({
                    "page": int(node.page),
                    "key": [int(t) for t in node.key],
                    "parent": (int(node.parent.page)
                               if node.parent is not self.root else 0),
                    "last_used": int(node.last_used),
                })
            stack.extend(node.children.values())
        return {
            "nodes": nodes,
            "clock": self._clock,
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "cow_forks": self.cow_forks,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
        }

    def restore_state(self, state: Dict) -> None:
        """Rebuild the tree from :meth:`snapshot_state`.

        The allocator's refcounts must already be restored: the blocked
        counters are recomputed bottom-up from them.  Every node is
        pushed onto the LRU heap — an over-approximation the heap's
        pop-time validation is already built to discard (interior or
        pinned entries are skipped; stale ages re-queue).
        """
        self.root = _Node(None, None, NULL_PAGE)
        self._by_page = {}
        for n in state["nodes"]:  # preorder: parents already rebuilt
            parent = (self.root if n["parent"] == 0
                      else self._by_page[n["parent"]])
            key = tuple(n["key"])
            node = _Node(parent, key, n["page"])
            node.last_used = n["last_used"]
            parent.children[key] = node
            self._by_page[n["page"]] = node
        # recompute blocked state bottom-up (post-order = reversed preorder)
        ref = self.alloc.refcount
        order: List[_Node] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            order.append(node)
            stack.extend(node.children.values())
        blocked: Dict[int, bool] = {}
        self._blocked = 0
        for node in reversed(order):
            node.blocked_children = sum(
                1 for c in node.children.values() if blocked[id(c)])
            if node is self.root:
                continue
            b = node.blocked_children > 0 or ref[node.page] > 0
            blocked[id(node)] = b
            self._blocked += b
        self._lru = [(node.last_used, page)
                     for page, node in self._by_page.items()]
        heapq.heapify(self._lru)
        self._clock = state["clock"]
        self.hits = state["hits"]
        self.misses = state["misses"]
        self.hit_tokens = state["hit_tokens"]
        self.cow_forks = state["cow_forks"]
        self.inserted_pages = state["inserted_pages"]
        self.evicted_pages = state["evicted_pages"]

    # -------------------------------------------------------------- audit
    def audit(self) -> None:
        """Prove the tree's structural and counter invariants; raise
        :class:`~repro.serve.pages.AuditError` naming the first violation.

        Checked:

        * tree structure — parent/child links agree, dict keys match node
          keys, no node owns the null page, ``_by_page`` is exactly the
          set of reachable nodes (no orphans, no strays);
        * the incremental eviction state — every node's
          ``blocked_children`` equals a fresh recount, ``_blocked``
          equals the number of blocked nodes, and the O(1)
          ``evictable_count()`` equals the post-order
          :meth:`_recount_evictable` oracle;
        * evictability liveness — every currently-evictable leaf has an
          entry on the lazy LRU heap (lazy deletion may leave *extra*
          entries, never missing ones — a missing entry is a page that
          could never be reclaimed).
        """
        from repro.serve.pages import AuditError

        def fail(msg: str) -> None:
            raise AuditError(f"PrefixCache.audit: {msg}")

        ref = self.alloc.refcount
        reachable: Dict[int, _Node] = {}
        stack: List[_Node] = [self.root]
        order: List[_Node] = []  # pre-order; reversed = post-order
        while stack:
            node = stack.pop()
            order.append(node)
            for key, child in node.children.items():
                if child.parent is not node:
                    fail(f"page {child.page}: parent link does not match "
                         "its position in the tree")
                if child.key != key:
                    fail(f"page {child.page}: node key {child.key} != dict "
                         f"key {key}")
                if child.page == NULL_PAGE:
                    fail("a tree node owns the null page")
                if child.page in reachable:
                    fail(f"page {child.page} appears at two tree positions")
                reachable[child.page] = child
                stack.append(child)
        if set(reachable) != set(self._by_page):
            orphans = set(self._by_page) - set(reachable)
            strays = set(reachable) - set(self._by_page)
            fail(f"_by_page does not match the reachable tree "
                 f"(orphans={sorted(orphans)[:8]}, "
                 f"strays={sorted(strays)[:8]})")
        for page, node in reachable.items():
            if self._by_page[page] is not node:
                fail(f"_by_page[{page}] points at a different node")

        # post-order recount of the incremental blocked state
        blocked: Dict[int, bool] = {}
        n_blocked = 0
        for node in reversed(order):
            count = sum(1 for c in node.children.values()
                        if blocked[id(c)])
            if node.blocked_children != count:
                fail(f"page {node.page}: blocked_children "
                     f"{node.blocked_children} != recount {count}")
            if node is self.root:
                continue
            is_blocked = count > 0 or ref[node.page] > 0
            blocked[id(node)] = is_blocked
            n_blocked += is_blocked
        if self._blocked != n_blocked:
            fail(f"_blocked {self._blocked} != recount {n_blocked}")
        if self.evictable_count() != self._recount_evictable():
            fail(f"evictable_count() {self.evictable_count()} != "
                 f"post-order recount {self._recount_evictable()}")

        # every evictable leaf must be reclaimable through the heap
        heap_pages = {page for _, page in self._lru}
        for page, node in self._by_page.items():
            if (not node.children and ref[page] == 0
                    and page not in heap_pages):
                fail(f"evictable leaf page {page} has no LRU heap entry")

    # ------------------------------------------------------------- reports
    def stats(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "cow_forks": self.cow_forks,
            "cached_pages": self.cached_pages,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "evictable": self.evictable_count(),
        }
