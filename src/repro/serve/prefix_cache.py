"""Prefix cache: radix-tree KV reuse with ref-counted copy-on-write pages.

IMAGine's premise is that data already resident in memory should be
computed on in place, not re-materialized.  The serving stack violated
that at the *request* level: every request re-prefilled its system prompt
even when thousands of requests share it, re-writing identical KV pages
the pool already holds.  This module makes the page pool shareable across
requests:

* **Radix tree at page granularity.**  A host-side trie over token-id
  prefixes whose nodes own *full* KV pages: a node at depth ``d`` is keyed
  by the ``page_size`` token ids covering logical positions
  ``[d·page_size, (d+1)·page_size)`` and owns the physical page holding
  their KV (for every layer — pages span all layers, so one node is one
  page id).  KV for position ``t`` depends only on tokens ``<= t`` at
  absolute positions, so any request whose prompt walks the same path can
  reference the same physical pages byte-for-byte.

* **Matching** (:meth:`PrefixCache.match`) walks full pages greedily,
  then attempts one **mid-page** partial match: if the next cached page's
  tokens agree with the prompt for ``n < page_size`` leading slots, the
  donor page is cloned (:func:`repro.serve.pages.fork_tail_page` — copy
  on write) into a private page so the request can keep writing its own
  suffix into the remaining slots.  The total match is capped at
  ``len(prompt) - 1`` tokens: at least one suffix token always runs
  through ``prefill_chunk`` so the request has last-token logits to
  sample from.

* **Reference counts** live in the :class:`~repro.serve.pages.PageAllocator`
  (a page may back many block tables); the tree itself holds **no**
  refcount — a cached page whose refcount is 0 is *resident but idle*,
  and is the eviction currency.

* **LRU eviction** (:meth:`PrefixCache.evict`) reclaims refcount-0 cached
  pages leaf-first (an interior node is pinned by its descendants: a
  match must walk a contiguous path from the root) when the free list
  runs dry.  Eviction is wired *into* ``PageAllocator._take_page``, so it
  is always tried before the scheduler falls back to
  preemption-by-recompute — dropping an idle cached page is strictly
  cheaper than recomputing a live request.

All of this is host-side numpy/dict state, exactly like the block tables:
on a production mesh the tree and refcounts do not shard, only the page
pool they index does.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.pages import NULL_PAGE, PageAllocator


class MatchResult:
    """One prompt's cache-hit description (host-side, cheap).

    ``full_pages``: physical page ids whose whole ``page_size`` tokens
    matched, in block order.  ``partial``: ``(donor_page, n_valid)`` when
    the match continues ``n_valid`` tokens into a cached page (the COW
    fork case), else None.  ``matched_tokens``: total prefix length
    served from cache — the request prefills only from there.
    """

    __slots__ = ("full_pages", "partial", "matched_tokens")

    def __init__(self, full_pages: List[int],
                 partial: Optional[Tuple[int, int]], page_size: int):
        self.full_pages = full_pages
        self.partial = partial
        self.matched_tokens = len(full_pages) * page_size + (
            partial[1] if partial else 0)

    def __bool__(self) -> bool:
        return self.matched_tokens > 0


class _Node:
    """One cached full page: key = its page_size token ids, value = the
    physical page id.  Children are the pages that extend this prefix."""

    __slots__ = ("children", "parent", "key", "page", "last_used")

    def __init__(self, parent: Optional["_Node"],
                 key: Optional[Tuple[int, ...]], page: int):
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.key = key
        self.page = page
        self.last_used = 0


class PrefixCache:
    """The radix tree + eviction policy over a :class:`PageAllocator`.

    Construction attaches the cache to the allocator: from then on the
    allocator keeps refcount-0 cached pages resident, counts them as
    allocatable capacity, and evicts through :meth:`evict` when the free
    list runs dry.
    """

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self.page_size = alloc.page_size
        self.root = _Node(None, None, NULL_PAGE)
        self._by_page: Dict[int, _Node] = {}
        self._clock = 0
        # counters (surfaced by ServeEngine.prefix_stats / the bench)
        self.hits = 0            # admissions with matched_tokens > 0
        self.misses = 0          # admissions with no match
        self.hit_tokens = 0      # prefill tokens served from cache
        self.cow_forks = 0       # mid-page matches (one page copy each)
        self.inserted_pages = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------- basics
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def holds(self, page: int) -> bool:
        """Is this physical page resident in the tree?"""
        return page in self._by_page

    @property
    def cached_pages(self) -> int:
        return len(self._by_page)

    # ------------------------------------------------------------ matching
    def match(self, tokens) -> MatchResult:
        """Longest cached prefix of ``tokens``, capped at ``len - 1``.

        Touches the LRU clock of every node on the matched path (and the
        mid-page donor).  Does **not** take references — the scheduler
        maps the result through ``PageAllocator.map_shared`` only once
        admission is certain.
        """
        ps = self.page_size
        limit = len(tokens) - 1  # >= 1 token must remain to prefill
        node, full = self.root, []
        d = 0
        while (d + 1) * ps <= limit:
            child = node.children.get(tuple(tokens[d * ps:(d + 1) * ps]))
            if child is None:
                break
            child.last_used = self._tick()
            full.append(child.page)
            node = child
            d += 1
        partial = None
        rem = limit - d * ps
        if rem > 0:
            best_n, best = 0, None
            for key, child in node.children.items():
                n = 0
                while n < rem and key[n] == tokens[d * ps + n]:
                    n += 1
                if n > best_n:
                    best_n, best = n, child
            if best is not None:
                best.last_used = self._tick()
                partial = (best.page, best_n)
        return MatchResult(full, partial, ps)

    # ----------------------------------------------------------- insertion
    def insert(self, tokens, block_row: np.ndarray) -> int:
        """Cache the full pages of a completed prefill.

        ``tokens``: the request's prefill token ids; ``block_row``: its
        block-table row (block ``d`` holds the page covering tokens
        ``[d·ps, (d+1)·ps)``).  Only *full* pages enter the tree — the
        partially-filled tail page keeps being written by decode and stays
        private.  Pages already cached for the same prefix (the request
        was itself a cache hit, or a cold duplicate raced in) are left in
        place; a cold duplicate's private copy simply never becomes
        shared and is freed at retire.  Returns the number of pages newly
        inserted.  Inserting takes no reference: the tree holds pages
        *resident*, the refcount only counts block-table owners.
        """
        ps = self.page_size
        node, new = self.root, 0
        for d in range(len(tokens) // ps):
            key = tuple(int(t) for t in tokens[d * ps:(d + 1) * ps])
            child = node.children.get(key)
            if child is None:
                page = int(block_row[d])
                if page == NULL_PAGE:
                    break  # block table shorter than the prompt: stop
                if page in self._by_page:
                    # a page id can live at one tree position only; this
                    # can't happen for a consistent allocator (shared
                    # pages match the existing node, private pages are
                    # fresh) — guard rather than corrupt the tree.
                    break
                child = _Node(node, key, page)
                node.children[key] = child
                self._by_page[page] = child
                new += 1
            child.last_used = self._tick()
            node = child
        self.inserted_pages += new
        return new

    # ------------------------------------------------------------ eviction
    def evictable_count(self) -> int:
        """Pages reclaimable right now: cached nodes whose whole subtree
        (themselves included) is refcount-0 — exactly the pages a
        leaf-first eviction loop could drain.  Exactness matters: the
        scheduler's capacity-based admission counts these as available.

        Iterative post-order (a long prompt is one deep chain — one node
        per page — so recursion would hit Python's stack limit at a few
        thousand cached tokens).
        """
        ref = self.alloc.refcount
        # (evictable_in_subtree, whole_subtree_refcount_free) per node
        results: Dict[int, Tuple[int, bool]] = {}
        stack: List[Tuple[_Node, bool]] = [(self.root, False)]
        while stack:
            node, visited = stack.pop()
            if not visited:
                stack.append((node, True))
                for child in node.children.values():
                    stack.append((child, False))
                continue
            total, subtree_free = 0, True
            for child in node.children.values():
                t, f = results.pop(id(child))
                total += t
                subtree_free &= f
            if node is self.root:
                return total
            if subtree_free and ref[node.page] == 0:
                results[id(node)] = (total + 1, True)
            else:
                results[id(node)] = (total, False)
        return 0  # unreachable: the root always completes the walk

    def evict(self, n_pages: int) -> int:
        """Evict up to ``n_pages`` refcount-0 cached pages, LRU leaf-first,
        returning them to the allocator's free list.  Never touches a page
        with live references and never the null page.  Returns the number
        actually evicted."""
        ref = self.alloc.refcount
        evicted = 0
        while evicted < n_pages:
            victim = None
            for node in self._by_page.values():
                if node.children or ref[node.page] != 0:
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.key]
            del self._by_page[victim.page]
            self.alloc._reclaim_evicted(victim.page)
            evicted += 1
        self.evicted_pages += evicted
        return evicted

    # ------------------------------------------------------------- reports
    def stats(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "cow_forks": self.cow_forks,
            "cached_pages": self.cached_pages,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
        }
