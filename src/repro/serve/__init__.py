from repro.serve.engine import ServeEngine, Request
from repro.serve.sampler import sample

__all__ = ["ServeEngine", "Request", "sample"]
