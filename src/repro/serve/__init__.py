"""Serving: paged-KV continuous batching (pages + scheduler + engine)
plus the async streaming front-end.

``ServeEngine`` is the batch-loop core; ``ServeFrontend`` /
``TokenStream`` are the streaming surface over it; ``KVPages`` /
``PageAllocator`` / ``PagedScheduler`` / ``BudgetScheduler`` are the
paged-cache building blocks (see ``docs/serving.md``).
"""

from repro.serve.engine import AdmissionRejected, Request, ServeEngine
from repro.serve.frontend import ServeFrontend, TokenStream
from repro.serve.pages import (
    AuditError,
    KVPages,
    PageAllocator,
    fork_tail_page,
    init_kv_pages,
    pages_for,
)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampler import sample
from repro.serve.scheduler import (
    PRIORITY_WEIGHTS,
    BudgetScheduler,
    PagedScheduler,
)

__all__ = [
    "AdmissionRejected",
    "AuditError",
    "BudgetScheduler",
    "KVPages",
    "PRIORITY_WEIGHTS",
    "PageAllocator",
    "PagedScheduler",
    "PrefixCache",
    "Request",
    "ServeEngine",
    "ServeFrontend",
    "TokenStream",
    "fork_tail_page",
    "init_kv_pages",
    "pages_for",
    "sample",
]
