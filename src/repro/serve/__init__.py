"""Serving: paged-KV continuous batching (pages + scheduler + engine).

``ServeEngine`` is the front door; ``KVPages`` / ``PageAllocator`` /
``PagedScheduler`` are the paged-cache building blocks (see
``docs/serving.md``).
"""

from repro.serve.engine import Request, ServeEngine
from repro.serve.pages import (
    KVPages,
    PageAllocator,
    fork_tail_page,
    init_kv_pages,
    pages_for,
)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampler import sample
from repro.serve.scheduler import PagedScheduler

__all__ = [
    "KVPages",
    "PageAllocator",
    "PagedScheduler",
    "PrefixCache",
    "Request",
    "ServeEngine",
    "fork_tail_page",
    "init_kv_pages",
    "pages_for",
    "sample",
]
