"""Scheduling policy for the paged continuous-batching engine.

The scheduler owns the request queue and the slot (batch-lane) table and
makes three kinds of decisions, all host-side and all against the
:class:`~repro.serve.pages.PageAllocator`:

* **Admission** — FCFS, capacity-based: the head-of-queue request is
  admitted into a free lane only when the page pool can hold its whole
  prefill (prompt, plus any tokens it generated before a preemption) and
  one decode token.  Pages are granted up front, so chunked prefill never
  allocates mid-flight.

* **Chunked batched prefill** — every admitted-but-unfinished request
  contributes its next ≤ ``chunk`` prompt tokens to one batched
  ``prefill_chunk`` call (replacing the old per-token ``_prefill_slot``
  loop: one forward per chunk across all pending lanes instead of one
  decode step per prompt token per request).  Chunks interleave with
  decode steps, so long prompts do not stall running generations for
  their whole prefill.

* **Preemption** — when decode needs a page and the free list is dry, the
  *longest-running* request (earliest admission still resident) is
  evicted: its pages are reclaimed, and it re-enters the queue head with
  ``prompt + generated-so-far`` as its new prefill (recompute-style
  preemption — nothing is swapped out, greedy decode resumes exactly
  where it left off).  With a prefix cache attached, cache *eviction*
  always runs first (inside ``PageAllocator``): dropping an idle cached
  page is strictly cheaper than recomputing a live request.

With a :class:`~repro.serve.prefix_cache.PrefixCache` attached, admission
additionally matches the prompt against the radix tree: matched full
pages are mapped shared (refcounted) into the lane's block table, a
mid-page match records a pending copy-on-write fork (the engine runs the
device copy before the next prefill step), and the lane's prefill offset
starts at the matched length — the batched ``prefill_chunk`` call then
computes **only the unmatched suffix** (its per-request ``pos0`` offsets
have carried arbitrary starts since PR 2).

:class:`BudgetScheduler` layers SLA-aware policy on top: a per-step
**token budget** shared between decode (one token per ready lane, always
funded first) and chunked prefill (sliced into whatever budget remains,
so a 30k-token prompt spreads across steps without ever stalling active
decode lanes), **priority classes** (``interactive``/``default``/
``batch``) with weighted fair-share virtual-time accounting per
``(tenant, priority)`` key, and admission that skips over blocked
higher-vt requests instead of head-of-line blocking.  Both policies
drive the *same* lane-independent chunked-prefill kernel, so greedy
token output is identical under either — scheduling changes latency,
never content.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.telemetry import NULL_TELEMETRY
from repro.serve.pages import PageAllocator, pages_for

PrefillBatch = Tuple[np.ndarray, np.ndarray, np.ndarray,
                     List[Tuple[int, int]]]

# weighted fair-share classes: an active interactive key receives 8x the
# prefill+decode tokens of an active batch key (never starving either —
# virtual time advances for whoever is served, so every key's turn comes)
PRIORITY_WEIGHTS: Dict[str, float] = {
    "interactive": 8.0,
    "default": 4.0,
    "batch": 1.0,
}


class PagedScheduler:
    """Admission + prefill batching + preemption over ``n_slots`` lanes."""

    def __init__(self, alloc: PageAllocator, chunk: int,
                 prefix_cache=None, obs=None):
        self.alloc = alloc
        self.obs = obs if obs is not None else NULL_TELEMETRY
        self.chunk = int(chunk)
        if self.chunk < 1:
            raise ValueError(f"prefill chunk must be >= 1, got {chunk}")
        self.n_slots = alloc.n_slots
        self.queue: Deque = collections.deque()
        self.slot_req: List[Optional[object]] = [None] * self.n_slots
        self.preemptions = 0
        self._admit_seq = 0
        self.prefix_cache = prefix_cache
        # (slot, src_page, dst_page) device copies the engine must run
        # before the next prefill/decode step touches the forked pages.
        # Tagged with the owning slot so cancellation/preemption can drop
        # a freed slot's forks before the dst page is reused (a fork into
        # a page that went back to the free list would corrupt whoever
        # reallocates it).
        self.pending_forks: List[Tuple[int, int, int]] = []
        # prefill tokens actually computed (the bench's ∝-unique-suffix
        # gate reads this; cache hits keep it below total prompt tokens)
        self.prefill_computed = 0

    # ------------------------------------------------------------- queue
    def submit(self, req) -> None:
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            r is not None for r in self.slot_req)

    def drop_forks(self, slot: int) -> None:
        """Discard pending copy-on-write forks owned by ``slot`` (the
        request was cancelled or preempted before the engine ran the
        device copy; its dst page is about to return to the free list)."""
        self.pending_forks = [
            f for f in self.pending_forks if f[0] != slot]

    # --------------------------------------------------------- admission
    def admit(self) -> None:
        """FCFS admission while a lane is free and capacity allows.

        The head-of-queue request blocks the queue when it does not fit
        (arrival order is preserved exactly).
        """
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self.slot_req[slot] is not None:
                continue
            if not self._try_admit(slot, self.queue[0]):
                return  # head-of-line blocks: keep arrival order
            self.queue.popleft()

    def _try_admit(self, slot: int, req) -> bool:
        """Admit ``req`` into free lane ``slot`` if capacity allows;
        returns False (leaving the allocator untouched) otherwise.  The
        caller owns the queue — on success it must remove ``req`` itself.

        With a prefix cache: the prompt is matched against the radix tree
        *before* the capacity check — shared full pages cost nothing, so
        a request whose prefix is resident can be admitted into a pool
        that could not hold its cold prefill.  Pages for the whole
        (suffix) prefill plus one decode token are still granted up
        front, so chunked prefill never allocates mid-flight.
        """
        toks = req.prefill_tokens
        total = pages_for(len(toks) + 1, self.alloc.page_size)
        # hopeless-case prefilter: even a best-case match (every full
        # page shared) cannot fit — skip the tree walk + pin/rollback
        # churn this blocked request would otherwise pay on every
        # scheduler iteration until capacity frees
        if not self.alloc.can_allocate(
                total - len(toks) // self.alloc.page_size):
            return False
        match = None
        if self.prefix_cache is not None:
            match = self.prefix_cache.match(toks)
        n_shared = len(match.full_pages) if match else 0
        if n_shared:
            # pin the matched pages (refcount++) *before* the capacity
            # check: a refcount-0 cached page counts as evictable
            # capacity, and a page about to be shared must not be
            # promised to the eviction path as well
            self.alloc.map_shared(slot, match.full_pages)
        need = total - n_shared
        if not self.alloc.can_allocate(need):
            if n_shared:
                self.alloc.free_slot(slot)  # unpin; pages stay cached
            return False
        # the COW fork target is granted first: a failed grant (pool dry
        # after all, or chaos at the page_grant site) downgrades the
        # mid-page match — the partial tokens simply prefill normally —
        # rather than failing the whole admission
        fork = None
        if match is not None and match.partial is not None:
            dst = self.alloc.alloc_page(slot)
            if dst is None:
                match.partial = None
                match.matched_tokens = n_shared * self.alloc.page_size
            else:
                fork = (slot, match.partial[0], dst)
        matched = match.matched_tokens if match is not None else 0
        self.alloc.pos[slot] = matched
        if not self.alloc.ensure(slot, len(toks) + 1):
            # capacity said yes but the grant still failed mid-loop
            # (chaos-injected, or an evictable page vanished): roll the
            # whole admission back — shared pins and the fork target all
            # release through free_slot, the request stays queued
            self.alloc.free_slot(slot)
            return False
        self.slot_req[slot] = req
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        if match is not None:
            self.prefix_cache.hits += bool(matched)
            self.prefix_cache.misses += not matched
            self.prefix_cache.hit_tokens += matched
            if matched:
                self.obs.on_cache_hit(req.rid, matched,
                                      match.partial is not None)
            else:
                self.obs.on_cache_miss(req.rid)
            if fork is not None:
                self.pending_forks.append(fork)
                self.prefix_cache.cow_forks += 1
        req.prefill_pos = matched
        req.cached_tokens = matched
        self.obs.on_admit(req.rid, slot, matched)
        return True

    # ----------------------------------------------------------- prefill
    def _pick_prefill(self) -> List[Tuple[int, int]]:
        """``(slot, n_tokens)`` prefill work for this step: every pending
        lane advances by up to ``chunk`` tokens (the FCFS policy has no
        budget — subclasses ration here)."""
        picks = []
        for slot, req in enumerate(self.slot_req):
            if req is None or req.prefill_pos >= len(req.prefill_tokens):
                continue
            picks.append(
                (slot, min(self.chunk,
                           len(req.prefill_tokens) - req.prefill_pos)))
        return picks

    def charge_decode(self, ready: List[Tuple[int, object]]) -> None:
        """Account for one decode token per ready lane this step (called
        by the engine right before the decode dispatch).  FCFS keeps no
        accounts; the budget scheduler charges fair-share virtual time."""

    def prefill_batch(self, audio_codebooks: int = 0
                      ) -> Optional[PrefillBatch]:
        """Assemble the next chunked prefill batch across pending lanes.

        Lane selection and per-lane token counts come from
        ``_pick_prefill`` (policy); this method only assembles the padded
        arrays.  Returns ``(tokens, pos0, seq_lens, [(slot, n_real),
        ...])`` with ``tokens`` shaped ``(n_slots, chunk)`` (``(n_slots,
        chunk, K)`` for audio), or ``None`` when nothing is pending.
        """
        lanes: List[Tuple[int, int]] = []
        c = self.chunk
        tokens = np.zeros((self.n_slots, c), np.int32)
        pos0 = np.zeros((self.n_slots,), np.int32)
        seq_lens = np.zeros((self.n_slots,), np.int32)
        for slot, n_real in self._pick_prefill():
            req = self.slot_req[slot]
            tokens[slot, :n_real] = req.prefill_tokens[
                req.prefill_pos:req.prefill_pos + n_real]
            pos0[slot] = req.prefill_pos
            seq_lens[slot] = req.prefill_pos + n_real
            lanes.append((slot, n_real))
            self.prefill_computed += n_real
        if not lanes:
            return None
        if audio_codebooks > 1:  # one EnCodec token broadcast per codebook
            tokens = np.broadcast_to(
                tokens[..., None],
                tokens.shape + (audio_codebooks,)).copy()
        return tokens, pos0, seq_lens, lanes

    def decode_lanes(self) -> List[Tuple[int, object]]:
        """Lanes whose request is fully prefilled and ready to decode."""
        return [
            (s, r) for s, r in enumerate(self.slot_req)
            if r is not None
            and r.prefill_pos >= len(r.prefill_tokens)
            and r.last_logits is not None
        ]

    def lane_mask(self, slots) -> np.ndarray:
        """(n_slots,) bool lane-activity mask for the jitted decode step.

        The mask's batch axis is the one the mesh shards over ``data`` —
        building it here keeps every lane-indexed array the scheduler
        hands the device in one place.
        """
        mask = np.zeros((self.n_slots,), bool)
        for s in slots:
            mask[s] = True
        return mask

    # -------------------------------------------------------- preemption
    def grant_decode_page(self, slot: int) -> bool:
        """Make room for slot's next decode token, preempting the
        longest-running other request if the free list is dry.  Returns
        False only when no victim remains (the lane must then wait)."""
        if self.slot_req[slot] is None:
            return False  # no resident request: never grow an empty slot
        want = int(self.alloc.pos[slot]) + 1
        while not self.alloc.ensure(slot, want):
            victim_slot = self._pick_victim(exclude=slot)
            if victim_slot is None:
                return False
            self._preempt(victim_slot)
        return True

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Longest-running resident request = earliest admission."""
        best, best_seq = None, None
        for slot, req in enumerate(self.slot_req):
            if req is None or slot == exclude:
                continue
            seq = req.admit_seq
            if best_seq is None or seq < best_seq:
                best, best_seq = slot, seq
        return best

    def _preempt(self, slot: int) -> None:
        req = self.slot_req[slot]
        self.obs.on_preempt(req.rid, slot)
        self.alloc.free_slot(slot)
        self.slot_req[slot] = None
        # recompute-style: everything generated so far becomes prefill
        # (a resident prefix in the cache will be re-matched at re-admission)
        req.prefill_tokens = list(req.prompt) + list(req.output)
        req.prefill_pos = 0
        req.cached_tokens = 0
        req.last_logits = None
        req.preemptions += 1
        self.preemptions += 1
        self.drop_forks(slot)
        self.queue.appendleft(req)

    def preempt_storm(self) -> int:
        """Preempt **every** resident request (the chaos injector's
        ``preempt_storm`` site — a mass-eviction drill).  Recompute-style
        preemption is token-preserving, so a storm costs latency and
        prefill compute but can never change greedy output; the drill
        asserts exactly that.  Returns the number of lanes preempted.
        """
        n = 0
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None:
                self._preempt(slot)
                n += 1
        return n


class BudgetScheduler(PagedScheduler):
    """SLA-aware scheduling: per-step token budget + weighted fair share.

    Policy deltas over the FCFS base (the data path — chunked prefill,
    page grants, preemption — is inherited unchanged, so greedy output
    is token-identical under either scheduler):

    * **Per-step token budget** (``step_tokens``): decode is funded
      first — every ready lane advances one token every step, so a long
      prompt's prefill can never stall active generations.  Whatever
      budget remains is rationed to chunked prefill in fair-share order;
      a 30k-token prompt is sliced across as many steps as the budget
      dictates.  Completing a prompt's prefill reserves one extra token
      (its first decode happens the same engine step); if that reserve
      does not fit, the tail is deferred one step so the budget holds as
      a hard per-step invariant.

    * **Weighted fair share** across ``(tenant, priority)`` keys —
      classic virtual-time WFQ: serving ``n`` tokens to a key advances
      its virtual time by ``n / weight`` (weights from
      :data:`PRIORITY_WEIGHTS`), and both admission order and prefill
      rationing serve lowest-virtual-time first.  An idle key's clock is
      floor-bumped to the busiest-behind key on reactivation, so sleeping
      does not bank credit, and an active ``batch`` key keeps receiving
      ``1/(1+Σweights)`` of the tokens no matter how much ``interactive``
      traffic arrives — priority speeds the favored class up, it never
      starves the rest.

    * **Out-of-order admission**: a blocked candidate (pool too full) no
      longer head-of-line blocks — later queued requests that fit are
      admitted (lowest virtual time first).  Arrival order still breaks
      ties within a key via rid.

    Load shedding (bounded admission queue) lives in
    :meth:`ServeEngine.submit` / the front-end, not here — the scheduler
    never refuses work it has already been handed.
    """

    def __init__(self, alloc: PageAllocator, chunk: int,
                 prefix_cache=None, obs=None, *, step_tokens: int,
                 weights: Optional[Dict[str, float]] = None):
        super().__init__(alloc, chunk, prefix_cache=prefix_cache, obs=obs)
        self.step_tokens = int(step_tokens)
        # >= 2: one token of prefill progress plus the completion reserve
        # must fit in an otherwise-idle step, or a 1-token-tail prompt
        # could be deferred forever
        if self.step_tokens < 2:
            raise ValueError(
                f"step_tokens must be >= 2, got {step_tokens}")
        self.weights = dict(weights or PRIORITY_WEIGHTS)
        self._vtime: Dict[Tuple[str, str], float] = {}

    # ------------------------------------------------------- fair share
    def _key(self, req) -> Tuple[str, str]:
        return (getattr(req, "tenant", "default"),
                getattr(req, "priority", "default"))

    def _weight(self, req) -> float:
        return self.weights.get(getattr(req, "priority", "default"), 1.0)

    def _vfloor(self) -> float:
        """Lowest virtual time among currently active keys (queued or
        resident) — the reactivation floor for idle keys."""
        keys = {self._key(r) for r in self.queue}
        keys.update(self._key(r) for r in self.slot_req if r is not None)
        vals = [self._vtime[k] for k in keys if k in self._vtime]
        return min(vals, default=0.0)

    def _charge(self, req, n_tokens: int) -> None:
        """Advance ``req``'s key by ``n_tokens`` of service."""
        k = self._key(req)
        vt = max(self._vtime.get(k, 0.0), self._vfloor())
        self._vtime[k] = vt + n_tokens / self._weight(req)

    def _service_order(self, reqs):
        """Lowest virtual time first; fresh keys start at the floor and
        break ties by weight (heavier class first), then arrival."""
        floor = self._vfloor()
        return sorted(
            reqs, key=lambda r: (self._vtime.get(self._key(r), floor),
                                 -self._weight(r), r.rid))

    # --------------------------------------------------------- admission
    def admit(self) -> None:
        """Admit queued requests in fair-share order, skipping over any
        that don't fit (no head-of-line blocking)."""
        free = [s for s in range(self.n_slots)
                if self.slot_req[s] is None]
        if not free or not self.queue:
            return
        for req in self._service_order(list(self.queue)):
            if not free:
                return
            if self._try_admit(free[0], req):
                self.queue.remove(req)
                free.pop(0)

    # ----------------------------------------------------------- prefill
    def _ready_decoders(self) -> int:
        """Lanes that will consume a decode token this step."""
        return sum(1 for _, r in self.decode_lanes()
                   if len(r.output) < r.max_new_tokens)

    def _pick_prefill(self) -> List[Tuple[int, int]]:
        """Ration the step's remaining token budget to pending prefills,
        lowest virtual time first."""
        budget = self.step_tokens - self._ready_decoders()
        slot_of = {id(r): s for s, r in enumerate(self.slot_req)
                   if r is not None}
        pending = [r for r in self.slot_req
                   if r is not None
                   and r.prefill_pos < len(r.prefill_tokens)]
        picks: List[Tuple[int, int]] = []
        for req in self._service_order(pending):
            if budget <= 0:
                break
            slot = slot_of[id(req)]
            rem = len(req.prefill_tokens) - req.prefill_pos
            n = min(self.chunk, rem, budget)
            if n == rem and n + 1 > budget:
                # completing the prefill costs its first decode token in
                # the same step; defer the tail rather than overshoot
                n -= 1
            if n <= 0:
                continue
            picks.append((slot, n))
            budget -= n + (1 if n == rem else 0)
            self._charge(req, n)
        return picks

    def charge_decode(self, ready: List[Tuple[int, object]]) -> None:
        for _, req in ready:
            self._charge(req, 1)
