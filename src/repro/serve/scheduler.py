"""Scheduling policy for the paged continuous-batching engine.

The scheduler owns the request queue and the slot (batch-lane) table and
makes three kinds of decisions, all host-side and all against the
:class:`~repro.serve.pages.PageAllocator`:

* **Admission** — FCFS, capacity-based: the head-of-queue request is
  admitted into a free lane only when the page pool can hold its whole
  prefill (prompt, plus any tokens it generated before a preemption) and
  one decode token.  Pages are granted up front, so chunked prefill never
  allocates mid-flight.

* **Chunked batched prefill** — every admitted-but-unfinished request
  contributes its next ≤ ``chunk`` prompt tokens to one batched
  ``prefill_chunk`` call (replacing the old per-token ``_prefill_slot``
  loop: one forward per chunk across all pending lanes instead of one
  decode step per prompt token per request).  Chunks interleave with
  decode steps, so long prompts do not stall running generations for
  their whole prefill.

* **Preemption** — when decode needs a page and the free list is dry, the
  *longest-running* request (earliest admission still resident) is
  evicted: its pages are reclaimed, and it re-enters the queue head with
  ``prompt + generated-so-far`` as its new prefill (recompute-style
  preemption — nothing is swapped out, greedy decode resumes exactly
  where it left off).  With a prefix cache attached, cache *eviction*
  always runs first (inside ``PageAllocator``): dropping an idle cached
  page is strictly cheaper than recomputing a live request.

With a :class:`~repro.serve.prefix_cache.PrefixCache` attached, admission
additionally matches the prompt against the radix tree: matched full
pages are mapped shared (refcounted) into the lane's block table, a
mid-page match records a pending copy-on-write fork (the engine runs the
device copy before the next prefill step), and the lane's prefill offset
starts at the matched length — the batched ``prefill_chunk`` call then
computes **only the unmatched suffix** (its per-request ``pos0`` offsets
have carried arbitrary starts since PR 2).
"""

from __future__ import annotations

import collections
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.serve.pages import PageAllocator, pages_for

PrefillBatch = Tuple[np.ndarray, np.ndarray, np.ndarray,
                     List[Tuple[int, int]]]


class PagedScheduler:
    """Admission + prefill batching + preemption over ``n_slots`` lanes."""

    def __init__(self, alloc: PageAllocator, chunk: int,
                 prefix_cache=None):
        self.alloc = alloc
        self.chunk = int(chunk)
        if self.chunk < 1:
            raise ValueError(f"prefill chunk must be >= 1, got {chunk}")
        self.n_slots = alloc.n_slots
        self.queue: Deque = collections.deque()
        self.slot_req: List[Optional[object]] = [None] * self.n_slots
        self.preemptions = 0
        self._admit_seq = 0
        self.prefix_cache = prefix_cache
        # (src_page, dst_page) device copies the engine must run before
        # the next prefill/decode step touches the forked pages
        self.pending_forks: List[Tuple[int, int]] = []
        # prefill tokens actually computed (the bench's ∝-unique-suffix
        # gate reads this; cache hits keep it below total prompt tokens)
        self.prefill_computed = 0

    # ------------------------------------------------------------- queue
    def submit(self, req) -> None:
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            r is not None for r in self.slot_req)

    # --------------------------------------------------------- admission
    def admit(self) -> None:
        """FCFS admission while a lane is free and capacity allows.

        With a prefix cache: the head-of-queue prompt is matched against
        the radix tree *before* the capacity check — shared full pages
        cost nothing, so a request whose prefix is resident can be
        admitted into a pool that could not hold its cold prefill.  Pages
        for the whole (suffix) prefill plus one decode token are still
        granted up front, so chunked prefill never allocates mid-flight.
        """
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self.slot_req[slot] is not None:
                continue
            req = self.queue[0]
            toks = req.prefill_tokens
            total = pages_for(len(toks) + 1, self.alloc.page_size)
            # hopeless-case prefilter: even a best-case match (every full
            # page shared) cannot fit — skip the tree walk + pin/rollback
            # churn this head-of-line-blocked request would otherwise pay
            # on every scheduler iteration until capacity frees
            if not self.alloc.can_allocate(
                    total - len(toks) // self.alloc.page_size):
                return
            match = None
            if self.prefix_cache is not None:
                match = self.prefix_cache.match(toks)
            n_shared = len(match.full_pages) if match else 0
            if n_shared:
                # pin the matched pages (refcount++) *before* the capacity
                # check: a refcount-0 cached page counts as evictable
                # capacity, and a page about to be shared must not be
                # promised to the eviction path as well
                self.alloc.map_shared(slot, match.full_pages)
            need = total - n_shared
            if not self.alloc.can_allocate(need):
                if n_shared:
                    self.alloc.free_slot(slot)  # unpin; pages stay cached
                return  # head-of-line blocks: keep arrival order
            self.queue.popleft()
            self.slot_req[slot] = req
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            matched = 0
            if match is not None:
                matched = match.matched_tokens
                self.prefix_cache.hits += bool(matched)
                self.prefix_cache.misses += not matched
                self.prefix_cache.hit_tokens += matched
                if match.partial is not None:
                    dst = self.alloc.alloc_page(slot)
                    assert dst is not None, \
                        "can_allocate granted but fork allocation failed"
                    self.pending_forks.append((match.partial[0], dst))
                    self.prefix_cache.cow_forks += 1
            req.prefill_pos = matched
            req.cached_tokens = matched
            self.alloc.pos[slot] = matched
            ok = self.alloc.ensure(slot, len(toks) + 1)
            assert ok, "can_allocate granted but ensure failed"

    # ----------------------------------------------------------- prefill
    def prefill_batch(self, audio_codebooks: int = 0
                      ) -> Optional[PrefillBatch]:
        """Assemble the next chunked prefill batch across pending lanes.

        Returns ``(tokens, pos0, seq_lens, [(slot, n_real), ...])`` with
        ``tokens`` shaped ``(n_slots, chunk)`` (``(n_slots, chunk, K)``
        for audio), or ``None`` when nothing is pending.
        """
        lanes: List[Tuple[int, int]] = []
        c = self.chunk
        tokens = np.zeros((self.n_slots, c), np.int32)
        pos0 = np.zeros((self.n_slots,), np.int32)
        seq_lens = np.zeros((self.n_slots,), np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is None or req.prefill_pos >= len(req.prefill_tokens):
                continue
            n_real = min(c, len(req.prefill_tokens) - req.prefill_pos)
            tokens[slot, :n_real] = req.prefill_tokens[
                req.prefill_pos:req.prefill_pos + n_real]
            pos0[slot] = req.prefill_pos
            seq_lens[slot] = req.prefill_pos + n_real
            lanes.append((slot, n_real))
            self.prefill_computed += n_real
        if not lanes:
            return None
        if audio_codebooks > 1:  # one EnCodec token broadcast per codebook
            tokens = np.broadcast_to(
                tokens[..., None],
                tokens.shape + (audio_codebooks,)).copy()
        return tokens, pos0, seq_lens, lanes

    def decode_lanes(self) -> List[Tuple[int, object]]:
        """Lanes whose request is fully prefilled and ready to decode."""
        return [
            (s, r) for s, r in enumerate(self.slot_req)
            if r is not None
            and r.prefill_pos >= len(r.prefill_tokens)
            and r.last_logits is not None
        ]

    def lane_mask(self, slots) -> np.ndarray:
        """(n_slots,) bool lane-activity mask for the jitted decode step.

        The mask's batch axis is the one the mesh shards over ``data`` —
        building it here keeps every lane-indexed array the scheduler
        hands the device in one place.
        """
        mask = np.zeros((self.n_slots,), bool)
        for s in slots:
            mask[s] = True
        return mask

    # -------------------------------------------------------- preemption
    def grant_decode_page(self, slot: int) -> bool:
        """Make room for slot's next decode token, preempting the
        longest-running other request if the free list is dry.  Returns
        False only when no victim remains (the lane must then wait)."""
        if self.slot_req[slot] is None:
            return False  # no resident request: never grow an empty slot
        want = int(self.alloc.pos[slot]) + 1
        while not self.alloc.ensure(slot, want):
            victim_slot = self._pick_victim(exclude=slot)
            if victim_slot is None:
                return False
            self._preempt(victim_slot)
        return True

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Longest-running resident request = earliest admission."""
        best, best_seq = None, None
        for slot, req in enumerate(self.slot_req):
            if req is None or slot == exclude:
                continue
            seq = req.admit_seq
            if best_seq is None or seq < best_seq:
                best, best_seq = slot, seq
        return best

    def _preempt(self, slot: int) -> None:
        req = self.slot_req[slot]
        self.alloc.free_slot(slot)
        self.slot_req[slot] = None
        # recompute-style: everything generated so far becomes prefill
        # (a resident prefix in the cache will be re-matched at re-admission)
        req.prefill_tokens = list(req.prompt) + list(req.output)
        req.prefill_pos = 0
        req.cached_tokens = 0
        req.last_logits = None
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)
