"""Async streaming front-end over :class:`~repro.serve.engine.ServeEngine`.

The engine is a batch loop: ``submit`` everything, ``run()`` to
completion, read ``req.output``.  Production serving is the opposite
shape — requests arrive continuously, every one wants its tokens *as
they are produced*, some carry deadlines, and under overload the system
must shed load instead of letting every request's latency grow without
bound.  This module is that layer:

* :meth:`ServeFrontend.submit` returns a :class:`TokenStream`
  immediately — an iterator that yields token ids as the engine emits
  them.  Iterating a stream drives the *shared* engine (every pending
  request advances together, exactly like the batch loop), so the
  streamed token sequence is identical to what ``run()`` would have
  produced for the same seeds: streaming changes *when* you see tokens,
  never *which* tokens (the load bench gates on this).

* **Deadlines** (``deadline_s``, relative to arrival) and
  :meth:`TokenStream.cancel` both route through ``ServeEngine.cancel``:
  the request's pages and prefix-cache pins are released the moment the
  deadline trips or the caller hangs up — mid-prefill included — and any
  tokens already generated remain on the stream.

* **Load shedding**: when the engine refuses admission
  (:class:`~repro.serve.engine.AdmissionRejected` — bounded queue full,
  or a prompt that can never fit the pool), ``submit`` still returns a
  stream, born terminal in state ``shed`` with the refusal reason.  The
  caller sees one uniform surface; nothing raises on the hot path.

Stream lifecycle (also in ``docs/serving.md``)::

    queued -> prefilling -> decoding -> done
       |           |           |     -> cancelled  (TokenStream.cancel)
       |           +-----------+---- -> timed_out  (deadline_s elapsed)
       |           +-----------+---- -> error      (engine quarantine)
       +---------------------------- -> shed       (admission refused)

The front-end is synchronous-cooperative, not threaded: ``step()`` runs
one engine step and pumps finished tokens into every live stream, and
stream iteration calls ``step()`` on demand.  A ``clock`` injectable
(default the serve-path clock, :func:`repro.obs.clock.now`) keeps
deadline behavior deterministic under test.  Like the scheduler and
allocator, all of this is host-side state — nothing here changes what
the jitted steps see.
"""

from __future__ import annotations

from typing import Iterator, List, Optional

from repro.obs import clock as _obs_clock
from repro.serve.engine import AdmissionRejected, Request, ServeEngine

# terminal stream states
DONE = "done"
CANCELLED = "cancelled"
TIMED_OUT = "timed_out"
SHED = "shed"
# quarantined by the engine after a step fault / non-finite logits
# exhausted its retry budget (finish_reason="error")
ERROR = "error"
# live stream states (mirror ServeEngine.request_phase)
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"

TERMINAL_STATES = (DONE, CANCELLED, TIMED_OUT, SHED, ERROR)


class TokenStream:
    """One request's async handle: iterate for tokens, inspect for SLA.

    ``tokens`` / ``token_times`` grow as the engine emits; ``state`` is
    one of queued/prefilling/decoding/done/cancelled/timed_out/shed.
    ``first_token_t`` / ``finish_t`` are clock readings for TTFT/TPOT
    accounting (``None`` until they happen).  Iteration yields each
    token id exactly once, driving the shared engine while this stream
    is live and ending (``StopIteration``) once the stream is terminal
    and drained — a shed stream simply yields nothing.
    """

    def __init__(self, frontend: "ServeFrontend", req: Optional[Request],
                 arrival_t: float, deadline_s: Optional[float] = None,
                 shed_reason: Optional[str] = None):
        self._fe = frontend
        self.req = req  # None iff shed at the door
        self.arrival_t = arrival_t
        self.deadline_s = deadline_s
        self.shed_reason = shed_reason
        self.tokens: List[int] = []
        self.token_times: List[float] = []
        self.first_token_t: Optional[float] = None
        self.finish_t: Optional[float] = arrival_t if shed_reason else None
        self.state = SHED if shed_reason else QUEUED
        self._cursor = 0

    # ------------------------------------------------------------- views
    @property
    def rid(self) -> Optional[int]:
        return self.req.rid if self.req is not None else None

    @property
    def finished(self) -> bool:
        return self.state in TERMINAL_STATES

    def ttft(self) -> Optional[float]:
        """Arrival -> first token (None until the first token lands)."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrival_t

    def tpot(self) -> Optional[float]:
        """Mean inter-token time after the first (None under 2 tokens)."""
        if len(self.tokens) < 2:
            return None
        span = self.token_times[-1] - self.token_times[0]
        return span / (len(self.tokens) - 1)

    # ---------------------------------------------------------- iteration
    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        while self._cursor >= len(self.tokens):
            if self.finished:
                raise StopIteration
            self._fe.step()
        tok = self.tokens[self._cursor]
        self._cursor += 1
        return tok

    def result(self) -> List[int]:
        """Block (drive the engine) until terminal; returns all tokens."""
        for _ in self:
            pass
        return self.tokens

    def cancel(self) -> bool:
        """Hang up: release the request's pages and prefix-cache pins
        immediately.  Tokens already streamed stay valid."""
        return self._fe.cancel(self)


class ServeFrontend:
    """Streaming request surface over one shared :class:`ServeEngine`.

    ``clock``: injectable monotonic time source (seconds) — deadlines
    and token timestamps read it, so tests drive it manually.
    """

    def __init__(self, engine: ServeEngine, clock=None):
        self.engine = engine
        self._clock = clock if clock is not None else _obs_clock.now
        self.streams: List[TokenStream] = []   # every submission, in order
        self._live: List[TokenStream] = []
        self.shed_count = 0
        self.timeout_count = 0

    # ---------------------------------------------------------- lifecycle
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None, *,
               priority: str = "default", tenant: str = "default",
               deadline_s: Optional[float] = None) -> TokenStream:
        """Enqueue a prompt; returns its stream immediately.

        ``deadline_s``: seconds after arrival by which the request must
        *finish*; past it the request is cancelled (state ``timed_out``)
        and its resources released.  Admission refusals come back as a
        terminal ``shed`` stream, not an exception; malformed prompts
        (empty / over ``max_len``) still raise ``ValueError``.
        """
        now = self._clock()
        try:
            req = self.engine.submit(prompt, max_new_tokens,
                                     priority=priority, tenant=tenant)
        except AdmissionRejected as e:
            self.shed_count += 1
            self.engine.obs.on_frontend_shed(e.reason)
            stream = TokenStream(self, None, now, deadline_s,
                                 shed_reason=e.reason)
            self.streams.append(stream)
            return stream
        stream = TokenStream(self, req, now, deadline_s)
        self.streams.append(stream)
        self._live.append(stream)
        return stream

    def cancel(self, stream: TokenStream, reason: str = "cancelled") -> bool:
        """Cancel a live stream (pages + cache pins released now)."""
        if stream.finished or stream.req is None:
            return False
        self.engine.cancel(stream.req, reason)
        self._pump()
        return True

    # ------------------------------------------------------------ driving
    def has_live(self) -> bool:
        """True while any stream is not yet terminal."""
        return bool(self._live)

    def step(self) -> bool:
        """Expire deadlines, run one engine step, pump new tokens into
        their streams.  Returns True while any live stream remains."""
        now = self._clock()
        chaos = getattr(self.engine, "chaos", None)
        if chaos is not None and self._live:
            # fire() self-reports through chaos.obs — no explicit
            # on_chaos here (it would double-count the site)
            if chaos.fire("cancel"):
                victim = self._live[chaos.pick("cancel", len(self._live))]
                self.cancel(victim)
            if chaos.fire("deadline_skew"):
                # the sweep below sees a skewed clock: deadlines near the
                # boundary trip early, exercising the cancel-on-deadline
                # path against requests mid-prefill/decode
                now = now + chaos.skew_s
        for stream in list(self._live):
            if (stream.deadline_s is not None
                    and now - stream.arrival_t >= stream.deadline_s):
                # cancel() is False if the engine already retired or
                # quarantined the request this step — without the guard a
                # request could be counted timed-out *and* keep its real
                # terminal state, double-counting the sweep
                if self.engine.cancel(stream.req, "timed_out"):
                    self.timeout_count += 1
                    self.engine.obs.on_frontend_timeout()
        if self.engine.has_work():
            self.engine.step()
        self._pump()
        return bool(self._live)

    def drain(self) -> List[TokenStream]:
        """Drive until every stream is terminal; returns all streams."""
        while self.step():
            pass
        return self.streams

    def _pump(self) -> None:
        """Move newly generated tokens and state changes onto streams."""
        now = self._clock()
        still_live = []
        for stream in self._live:
            req = stream.req
            new = req.output[len(stream.tokens):]
            if new:
                if stream.first_token_t is None:
                    stream.first_token_t = now
                stream.tokens.extend(int(t) for t in new)
                stream.token_times.extend([now] * len(new))
            if req.done:
                stream.state = DONE
                stream.finish_t = now
            elif req.cancelled:
                if req.finish_reason == "timed_out":
                    stream.state = TIMED_OUT
                elif req.finish_reason == "error":
                    stream.state = ERROR
                else:
                    stream.state = CANCELLED
                stream.finish_t = now
            else:
                stream.state = self.engine.request_phase(req)
                still_live.append(stream)
        self._live = still_live
