"""Paged KV cache: fixed-size pages, per-request block tables, free-list
allocation — the serving-side analogue of IMAGine's memory-capacity scaling
argument.  Decode throughput scales with how many requests' KV state the
page pool can hold, not with the worst-case ``n_slots * max_len`` rectangle
the fixed-slot engine reserves.

Two pieces:

* :class:`KVPages` — the device-side page pool, a registered JAX pytree.
  Storage is ``(L, P, page_size, Hkv, Dh)`` per K and V: every layer sees
  the same physical page ids, so one ``(B, n_blocks)`` block table per
  request addresses all layers.  With ``kv_bits=8`` the pools are int8
  bit-planed (per-(token, head) scales ride along as ``(L, P, page_size,
  Hkv)`` bf16 pools) — the ``EnginePlan.kv_bits`` knob applied to the
  cache exactly as ``plan.bits`` is applied to the weights.

* :class:`PageAllocator` — the host-side free list and block-table
  bookkeeping: capacity-based admission (``can_admit``), on-demand page
  grants during decode (``ensure``), and whole-request reclaim
  (``free_slot``).  Physical page 0 is reserved as the *null page*: idle
  batch lanes and masked prefill positions scatter there, so the jitted
  model functions never need a dynamic shape or a write-predicate.

  As of the prefix-cache subsystem (``repro.serve.prefix_cache``) pages
  are **reference counted**: one physical page may back many lanes' block
  tables (a shared prompt prefix), and a page only becomes reclaimable
  when its refcount drops to 0.  Refcount-0 pages held by an attached
  prefix cache stay *resident* (cached, LRU-evictable) instead of
  returning to the free list; ``_take_page`` transparently evicts them
  when the free list runs dry.  All page release goes through
  ``free_slot`` / ``_release_page`` — nothing outside this module may
  touch the free list directly (CI greps for bypasses).

* :func:`fork_tail_page` — the device-side copy-on-write primitive: a
  cache hit that ends mid-page clones the donor's partially-filled tail
  page into a freshly-allocated private page, so the new request can keep
  writing without corrupting the shared bytes.

The allocator is deliberately numpy/host-side — the jitted paged decode
and chunked prefill steps (``repro.models.transformer``) only ever see the
``KVPages`` arrays plus ``(block_tables, pos, active)`` index arrays.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.obs.telemetry import NULL_TELEMETRY

NULL_PAGE = 0  # physical page 0 is never allocated; garbage writes land here


class AuditError(AssertionError):
    """A runtime invariant audit failed (allocator or prefix cache).

    Subclasses ``AssertionError`` so test harnesses that assert on
    engine state treat an audit trip as a failed assertion, but keeps
    its own type so production callers can catch *audit* failures
    (state corruption — stop taking traffic) apart from ordinary
    assertion bugs.
    """

# families whose KV state is pageable (ssm/hybrid keep O(1) recurrent
# state and stay on the fixed-slot engine); the single source of truth
# for both init_kv_pages and ServeEngine's mode="auto" selection
PAGED_FAMILIES = ("dense", "vlm", "audio", "moe")


@dataclasses.dataclass(frozen=True)
class KVPages:
    """Device-side paged KV pool for all layers (a registered JAX pytree).

    ``k`` / ``v``: ``(L, P, page_size, Hkv, Dh)`` in the cache storage dtype
    (int8 when ``kv_bits=8``).  ``k_scale`` / ``v_scale``: per-(token, head)
    dequantization scales ``(L, P, page_size, Hkv)``, ``None`` unless the
    pool is quantized.  ``page_size`` and ``kv_bits`` are static aux data.
    """

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]
    v_scale: Optional[jnp.ndarray]
    page_size: int
    kv_bits: int

    # ------------------------------------------------------------- helpers
    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def replace(self, **kw) -> "KVPages":
        return dataclasses.replace(self, **kw)

    def nbytes(self) -> int:
        leaves = [self.k, self.v]
        if self.quantized:
            leaves += [self.k_scale, self.v_scale]
        return int(sum(l.size * l.dtype.itemsize for l in leaves))


def _kvpages_flatten(p: KVPages):
    children = ((jax.tree_util.DictKey("k"), p.k),
                (jax.tree_util.DictKey("v"), p.v),
                (jax.tree_util.DictKey("k_scale"), p.k_scale),
                (jax.tree_util.DictKey("v_scale"), p.v_scale))
    return children, (p.page_size, p.kv_bits)


def _kvpages_unflatten(aux, children) -> KVPages:
    page_size, kv_bits = aux
    k, v, ks, vs = children
    return KVPages(k, v, ks, vs, page_size, kv_bits)


jax.tree_util.register_pytree_with_keys(
    KVPages, _kvpages_flatten,
    lambda aux, children: _kvpages_unflatten(aux, children))


def init_kv_pages(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype=None, kv_bits: int = 0) -> KVPages:
    """Allocate an all-zeros page pool for ``cfg`` (attention families).

    ``kv_bits=8`` allocates int8 pools plus bf16 scale pools — the same
    layout :func:`repro.models.transformer.init_cache` uses for its int8
    cache, paged.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV serves attention-KV families only; {cfg.family!r} "
            "keeps O(1) state and stays on the fixed-slot engine")
    if kv_bits not in (0, 8):
        raise ValueError(f"kv_bits must be 0/8, got {kv_bits}")
    dtype = dtype or jnp.dtype(cfg.dtype)
    if kv_bits:
        dtype = jnp.int8
    dh, hkv, nl = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    shape = (nl, n_pages, page_size, hkv, dh)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    ks = vs = None
    if kv_bits:
        sshape = (nl, n_pages, page_size, hkv)
        ks = jnp.zeros(sshape, jnp.bfloat16)
        vs = jnp.zeros(sshape, jnp.bfloat16)
    return KVPages(k, v, ks, vs, page_size, kv_bits)


def pages_for(n_tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``n_tokens``."""
    return max(0, math.ceil(n_tokens / page_size))


class PageAllocator:
    """Host-side block tables + refcounted free list over a
    :class:`KVPages` pool.

    ``n_slots`` batch lanes each own a ``(max_blocks,)`` block table row
    (logical block i -> physical page id; ``NULL_PAGE`` where unmapped) and
    a token count ``pos``.  Pages come from one shared free list, so total
    physical capacity is ``(n_pages - 1) * page_size`` tokens across all
    lanes instead of ``n_slots * max_len``.

    **Refcount invariants** (property-pinned by
    ``tests/test_prefix_cache.py``): a page mapped by ``k`` block tables
    has ``refcount == k``; refcounts never go negative; page 0 (the null
    page) is never allocated, freed, shared or evicted; a released page
    returns to the free list unless an attached prefix cache holds it
    resident (then it parks as an evictable cached page).
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_len: int, obs=None):
        self.obs = obs if obs is not None else NULL_TELEMETRY
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_blocks = pages_for(max_len, page_size)
        if n_pages < self.max_blocks + 1:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one max_len={max_len} "
                f"request (needs {self.max_blocks} pages + the null page)")
        # page 0 is the null page; everything else starts free (LIFO reuse)
        self.free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self.block_tables = np.full((n_slots, self.max_blocks), NULL_PAGE,
                                    np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self._mapped: List[List[int]] = [[] for _ in range(n_slots)]
        self.refcount = np.zeros((n_pages,), np.int32)
        self._cache = None  # attached PrefixCache (eviction provider)
        self.chaos = None   # optional ft.ChaosInjector (page_grant site)

    # -------------------------------------------------------- prefix cache
    def attach_cache(self, cache) -> None:
        """Register a prefix cache as the resident-page owner + evictor.

        The cache keeps refcount-0 pages resident (``cache.holds``) and
        hands them back through ``cache.evict`` when the free list runs
        dry; the allocator's capacity arithmetic counts those pages as
        available.
        """
        self._cache = cache

    def _emit_pages(self) -> None:
        """Publish pool occupancy (free / cache-resident / evictable) to
        telemetry — the ``C`` counter series on the pages trace track,
        and the per-step "memory" track sample."""
        self.obs.on_pages(
            len(self.free),
            self._cache.cached_pages if self._cache is not None else 0,
            self.evictable_pages)

    # ----------------------------------------------------------- capacity
    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def evictable_pages(self) -> int:
        """Cached refcount-0 pages the attached prefix cache could evict."""
        return self._cache.evictable_count() if self._cache is not None else 0

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self.free)

    def can_allocate(self, n_pages: int) -> bool:
        """Could ``n_pages`` fresh pages be produced right now (free list
        plus evictable cached pages)?  The free list answers first — the
        evictable count is a tree walk and is only consulted when the
        free list alone is short (keeps the per-decode-token ``ensure``
        O(1) while pages remain free)."""
        if n_pages <= len(self.free):
            return True
        return n_pages <= len(self.free) + self.evictable_pages

    def can_admit(self, n_tokens: int) -> bool:
        """Capacity-based admission: is there room for a request whose
        prompt is ``n_tokens`` plus one decode token?"""
        return self.can_allocate(pages_for(n_tokens + 1, self.page_size))

    # --------------------------------------------------------- allocation
    def _take_page(self) -> Optional[int]:
        """Pop a free page, evicting cached refcount-0 pages if needed.

        The chaos hook fires *before* the pop: a fired ``page_grant``
        fault makes this grant fail exactly as a dry pool would, so
        every caller exercises its real out-of-capacity path (admission
        blocks, decode preempts, COW forks drop) on demand.
        """
        if self.chaos is not None and self.chaos.fire("page_grant"):
            return None
        if not self.free and self._cache is not None:
            self._cache.evict(1)
        if not self.free:
            return None
        return self.free.pop()

    def alloc_page(self, slot: int) -> Optional[int]:
        """Allocate one private page as ``slot``'s next block (refcount 1).
        Used for the copy-on-write fork target of a mid-page cache hit."""
        page = self._take_page()
        if page is None:
            return None
        self.refcount[page] = 1
        blk = len(self._mapped[slot])
        self._mapped[slot].append(page)
        self.block_tables[slot, blk] = page
        self._emit_pages()
        return page

    def map_shared(self, slot: int, pages: List[int]) -> None:
        """Map already-resident (cached) pages as ``slot``'s leading
        blocks, taking one reference on each — the prefix-cache hit path.
        Must run before any private allocation for the slot."""
        if self._mapped[slot]:
            raise ValueError(
                f"slot {slot} already holds pages; map the shared prefix "
                "before allocating private pages")
        for blk, page in enumerate(pages):
            if page == NULL_PAGE:
                raise ValueError("cannot share the null page")
            self.refcount[page] += 1
            if (self.refcount[page] == 1 and self._cache is not None
                    and self._cache.holds(page)):
                # 0 -> 1: the cached page leaves the evictable set.  The
                # shared prefix pins root-first, so each node's parent is
                # already pinned and the cache's upward walk is O(1).
                self._cache._on_pin(page)
            self._mapped[slot].append(page)
            self.block_tables[slot, blk] = page
        self._emit_pages()

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s block table to cover ``n_tokens`` logical tokens.
        Returns False (net allocating nothing) if the free list runs dry
        even after evicting cached pages.

        ``can_allocate`` pre-checks capacity, but a grant can still fail
        mid-loop (chaos at the ``page_grant`` site, or a racing evictable
        count); a partial grant is rolled back page-by-page so a False
        return always leaves the slot exactly as it was.
        """
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_blocks:
            raise ValueError(
                f"slot {slot} wants {n_tokens} tokens > max_len capacity")
        have = len(self._mapped[slot])
        if need <= have:
            return True  # nothing to grant (the per-decode-token case)
        if not self.can_allocate(need - have):
            return False
        granted: List[int] = []
        for blk in range(have, need):
            page = self._take_page()
            if page is None:
                for g in reversed(granted):
                    blk_g = len(self._mapped[slot]) - 1
                    self._mapped[slot].pop()
                    self.block_tables[slot, blk_g] = NULL_PAGE
                    self._release_page(g)
                self._emit_pages()
                return False
            granted.append(page)
            self.refcount[page] = 1
            self._mapped[slot].append(page)
            self.block_tables[slot, blk] = page
        self._emit_pages()
        return True

    def _release_page(self, page: int) -> None:
        """Drop one reference; at refcount 0 the page returns to the free
        list unless the prefix cache holds it resident (then it stays as
        an evictable cached page).  The only legal way to free a page."""
        if page == NULL_PAGE:
            raise ValueError("the null page is never freed")
        self.refcount[page] -= 1
        if self.refcount[page] < 0:
            raise AssertionError(f"page {page} refcount went negative")
        if self.refcount[page] == 0:
            if self._cache is not None and self._cache.holds(page):
                # 1 -> 0: stays resident, re-enters the evictable set.
                # ``free_slot`` releases deepest-first, so each node's
                # parent is still pinned and the upward walk is O(1).
                self._cache._on_unpin(page)
            else:
                self.free.append(page)

    def _reclaim_evicted(self, page: int) -> None:
        """Return an evicted cache-resident page (refcount already 0) to
        the free list.  Called by the prefix cache only."""
        assert page != NULL_PAGE and self.refcount[page] == 0
        self.free.append(page)
        self._emit_pages()

    def free_slot(self, slot: int) -> None:
        """Release every page the slot maps (request retired or preempted).
        Shared pages survive under their other owners / the prefix cache."""
        for page in reversed(self._mapped[slot]):
            self._release_page(page)
        self._mapped[slot] = []
        self.block_tables[slot, :] = NULL_PAGE
        self.pos[slot] = 0
        self._emit_pages()

    def block_row(self, slot: int) -> np.ndarray:
        """The slot's block-table row (a copy — safe to hand to the tree)."""
        return self.block_tables[slot].copy()

    # -------------------------------------------------------------- audit
    def audit(self) -> None:
        """Prove the allocator's bookkeeping invariants; raise
        :class:`AuditError` naming the first violation.

        Checked (the refcount contract the prefix cache and schedulers
        build on):

        * the null page is never allocated, freed, or referenced;
        * the free list holds unique, in-range, refcount-0 pages,
          disjoint from every mapped page and every cache-resident page;
        * **refcount conservation** — ``refcount[p]`` equals the number
          of block-table references across all lanes (cache residency
          deliberately takes no refcount: a cached page is *defined* by
          refcount 0 + ``cache.holds``);
        * each block-table row is exactly its ``_mapped`` list followed
          by ``NULL_PAGE`` padding — the jitted steps only ever address
          live pages;
        * ``pos`` never exceeds the slot's mapped token capacity;
        * **page conservation** — every physical page is free, mapped,
          or cache-resident; nothing leaks.
        """
        def fail(msg: str) -> None:
            raise AuditError(f"PageAllocator.audit: {msg}")

        if self.refcount[NULL_PAGE] != 0:
            fail(f"null page has refcount {self.refcount[NULL_PAGE]}")

        # vectorized checks on the hot path; when one trips, the slow
        # per-element sweep below names the exact violation.  The audit
        # runs after every step under ServeConfig(audit=1), so its cost
        # is part of the serving budget (BENCH_chaos.json gates it).
        free_set = set(self.free)
        if len(free_set) != len(self.free):
            fail("free list holds duplicate pages")
        if self.free:
            f = np.asarray(self.free, dtype=np.int64)
            if f.min() <= NULL_PAGE or f.max() >= self.n_pages:
                p = int(f[(f <= NULL_PAGE) | (f >= self.n_pages)][0])
                fail(f"free list holds out-of-range page {p}")
            ref_f = self.refcount[f]
            if ref_f.any():
                p = int(f[np.nonzero(ref_f)[0][0]])
                fail(f"free page {p} has refcount {self.refcount[p]}")
        if self._cache is not None:
            both = free_set & set(self._cache.pages())
            if both:
                fail(f"page {min(both)} is both free and cache-resident")

        # refcount conservation: count block-table references per page
        flat: List[int] = []
        mapped_set = set()
        for slot in range(self.n_slots):
            mapped = self._mapped[slot]
            row = self.block_tables[slot]
            n = len(mapped)
            if n:
                ok = (min(mapped) > NULL_PAGE
                      and max(mapped) < self.n_pages
                      and row[:n].tolist() == mapped)
                if not ok:
                    for blk, page in enumerate(mapped):  # name it
                        if not (NULL_PAGE < page < self.n_pages):
                            fail(f"slot {slot} maps out-of-range "
                                 f"page {page}")
                        if row[blk] != page:
                            fail(f"slot {slot} block {blk}: table says "
                                 f"{row[blk]}, _mapped says {page}")
                flat.extend(mapped)
                mapped_set.update(mapped)
            if row[n:].any():  # NULL_PAGE == 0: padding must be all-zero
                fail(f"slot {slot} block table addresses pages past its "
                     f"{n} mapped blocks")
            cap = n * self.page_size
            if not (0 <= self.pos[slot] <= cap):
                fail(f"slot {slot} pos {self.pos[slot]} outside mapped "
                     f"capacity {cap}")
        expect = (np.bincount(np.asarray(flat, dtype=np.int64),
                              minlength=self.n_pages)
                  if flat else np.zeros((self.n_pages,), np.int64))
        bad = np.nonzero(expect != self.refcount)[0]
        if bad.size:
            p = int(bad[0])
            fail(f"page {p} refcount {self.refcount[p]} != "
                 f"{int(expect[p])} block-table references")
        if free_set & mapped_set:
            p = min(free_set & mapped_set)
            fail(f"page {p} is both free and mapped")

        # page conservation: free + mapped + cache-resident covers the pool
        accounted = free_set | mapped_set
        if self._cache is not None:
            accounted |= set(self._cache.pages())
        leaked = set(range(1, self.n_pages)) - accounted
        if leaked:
            fail(f"pages leaked (not free, mapped, or cached): "
                 f"{sorted(leaked)[:8]}")

    # -------------------------------------------------------------- views
    def device_tables(self, shardings=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(block_tables, pos) as device arrays for the jitted steps.

        ``shardings``: optional ``(bt_sharding, pos_sharding)`` pair (from
        ``dist.sharding.batch_shardings`` — lane axis over the data axes).
        The numpy tables go straight to their mesh placement in one
        transfer — no default-device stop, no per-step reshard inside the
        jitted decode/prefill calls.
        """
        if shardings is not None:
            return (jax.device_put(self.block_tables, shardings[0]),
                    jax.device_put(self.pos, shardings[1]))
        return jnp.asarray(self.block_tables), jnp.asarray(self.pos)


# ---------------------------------------------------------------------------
# copy-on-write: fork a partially-filled tail page
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,))
def fork_tail_page(pages: KVPages, src: jnp.ndarray,
                   dst: jnp.ndarray) -> KVPages:
    """Clone physical page ``src`` into ``dst`` across every layer (and the
    scale pools when quantized) — the copy-on-write step of a mid-page
    prefix-cache hit.

    The whole page is copied: the matched prefix slots are the bytes being
    shared, and every slot past the match point is overwritten by the
    request's own suffix prefill before it can ever be attended (positions
    ``>= pos`` are masked).  ``src``/``dst`` are traced scalars, so one
    compilation serves every fork; the pool is donated so XLA can update
    the buffers in place.
    """
    upd = {
        "k": pages.k.at[:, dst].set(pages.k[:, src]),
        "v": pages.v.at[:, dst].set(pages.v[:, src]),
    }
    if pages.quantized:
        upd["k_scale"] = pages.k_scale.at[:, dst].set(pages.k_scale[:, src])
        upd["v_scale"] = pages.v_scale.at[:, dst].set(pages.v_scale[:, src])
    return pages.replace(**upd)
