"""Paged KV cache: fixed-size pages, per-request block tables, free-list
allocation — the serving-side analogue of IMAGine's memory-capacity scaling
argument.  Decode throughput scales with how many requests' KV state the
page pool can hold, not with the worst-case ``n_slots * max_len`` rectangle
the fixed-slot engine reserves.

Two pieces:

* :class:`KVPages` — the device-side page pool, a registered JAX pytree.
  Storage is ``(L, P, page_size, Hkv, Dh)`` per K and V: every layer sees
  the same physical page ids, so one ``(B, n_blocks)`` block table per
  request addresses all layers.  With ``kv_bits=8`` the pools are int8
  bit-planed (per-(token, head) scales ride along as ``(L, P, page_size,
  Hkv)`` bf16 pools) — the ``EnginePlan.kv_bits`` knob applied to the
  cache exactly as ``plan.bits`` is applied to the weights.

* :class:`PageAllocator` — the host-side free list and block-table
  bookkeeping: capacity-based admission (``can_admit``), on-demand page
  grants during decode (``ensure``), and whole-request reclaim
  (``free_slot``).  Physical page 0 is reserved as the *null page*: idle
  batch lanes and masked prefill positions scatter there, so the jitted
  model functions never need a dynamic shape or a write-predicate.

The allocator is deliberately numpy/host-side — the jitted paged decode
and chunked prefill steps (``repro.models.transformer``) only ever see the
``KVPages`` arrays plus ``(block_tables, pos, active)`` index arrays.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig

NULL_PAGE = 0  # physical page 0 is never allocated; garbage writes land here

# families whose KV state is pageable (ssm/hybrid keep O(1) recurrent
# state and stay on the fixed-slot engine); the single source of truth
# for both init_kv_pages and ServeEngine's mode="auto" selection
PAGED_FAMILIES = ("dense", "vlm", "audio", "moe")


@dataclasses.dataclass(frozen=True)
class KVPages:
    """Device-side paged KV pool for all layers (a registered JAX pytree).

    ``k`` / ``v``: ``(L, P, page_size, Hkv, Dh)`` in the cache storage dtype
    (int8 when ``kv_bits=8``).  ``k_scale`` / ``v_scale``: per-(token, head)
    dequantization scales ``(L, P, page_size, Hkv)``, ``None`` unless the
    pool is quantized.  ``page_size`` and ``kv_bits`` are static aux data.
    """

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]
    v_scale: Optional[jnp.ndarray]
    page_size: int
    kv_bits: int

    # ------------------------------------------------------------- helpers
    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def replace(self, **kw) -> "KVPages":
        return dataclasses.replace(self, **kw)

    def nbytes(self) -> int:
        leaves = [self.k, self.v]
        if self.quantized:
            leaves += [self.k_scale, self.v_scale]
        return int(sum(l.size * l.dtype.itemsize for l in leaves))


def _kvpages_flatten(p: KVPages):
    children = ((jax.tree_util.DictKey("k"), p.k),
                (jax.tree_util.DictKey("v"), p.v),
                (jax.tree_util.DictKey("k_scale"), p.k_scale),
                (jax.tree_util.DictKey("v_scale"), p.v_scale))
    return children, (p.page_size, p.kv_bits)


def _kvpages_unflatten(aux, children) -> KVPages:
    page_size, kv_bits = aux
    k, v, ks, vs = children
    return KVPages(k, v, ks, vs, page_size, kv_bits)


jax.tree_util.register_pytree_with_keys(
    KVPages, _kvpages_flatten,
    lambda aux, children: _kvpages_unflatten(aux, children))


def init_kv_pages(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype=None, kv_bits: int = 0) -> KVPages:
    """Allocate an all-zeros page pool for ``cfg`` (attention families).

    ``kv_bits=8`` allocates int8 pools plus bf16 scale pools — the same
    layout :func:`repro.models.transformer.init_cache` uses for its int8
    cache, paged.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV serves attention-KV families only; {cfg.family!r} "
            "keeps O(1) state and stays on the fixed-slot engine")
    if kv_bits not in (0, 8):
        raise ValueError(f"kv_bits must be 0/8, got {kv_bits}")
    dtype = dtype or jnp.dtype(cfg.dtype)
    if kv_bits:
        dtype = jnp.int8
    dh, hkv, nl = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    shape = (nl, n_pages, page_size, hkv, dh)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    ks = vs = None
    if kv_bits:
        sshape = (nl, n_pages, page_size, hkv)
        ks = jnp.zeros(sshape, jnp.bfloat16)
        vs = jnp.zeros(sshape, jnp.bfloat16)
    return KVPages(k, v, ks, vs, page_size, kv_bits)


def pages_for(n_tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``n_tokens``."""
    return max(0, math.ceil(n_tokens / page_size))


class PageAllocator:
    """Host-side block tables + free list over a :class:`KVPages` pool.

    ``n_slots`` batch lanes each own a ``(max_blocks,)`` block table row
    (logical block i -> physical page id; ``NULL_PAGE`` where unmapped) and
    a token count ``pos``.  Pages come from one shared free list, so total
    physical capacity is ``(n_pages - 1) * page_size`` tokens across all
    lanes instead of ``n_slots * max_len``.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_len: int):
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_blocks = pages_for(max_len, page_size)
        if n_pages < self.max_blocks + 1:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one max_len={max_len} "
                f"request (needs {self.max_blocks} pages + the null page)")
        # page 0 is the null page; everything else starts free (LIFO reuse)
        self.free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self.block_tables = np.full((n_slots, self.max_blocks), NULL_PAGE,
                                    np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]

    # ----------------------------------------------------------- capacity
    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self.free)

    def can_admit(self, n_tokens: int) -> bool:
        """Capacity-based admission: is there room for a request whose
        prompt is ``n_tokens`` plus one decode token?"""
        return pages_for(n_tokens + 1, self.page_size) <= len(self.free)

    # --------------------------------------------------------- allocation
    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s block table to cover ``n_tokens`` logical tokens.
        Returns False (allocating nothing) if the free list runs dry."""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_blocks:
            raise ValueError(
                f"slot {slot} wants {n_tokens} tokens > max_len capacity")
        have = len(self._owned[slot])
        if need - have > len(self.free):
            return False
        for blk in range(have, need):
            page = self.free.pop()
            self._owned[slot].append(page)
            self.block_tables[slot, blk] = page
        return True

    def free_slot(self, slot: int) -> None:
        """Reclaim every page the slot owns (request retired or preempted)."""
        self.free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.block_tables[slot, :] = NULL_PAGE
        self.pos[slot] = 0

    # -------------------------------------------------------------- views
    def device_tables(self, shardings=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(block_tables, pos) as device arrays for the jitted steps.

        ``shardings``: optional ``(bt_sharding, pos_sharding)`` pair (from
        ``dist.sharding.batch_shardings`` — lane axis over the data axes).
        The numpy tables go straight to their mesh placement in one
        transfer — no default-device stop, no per-step reshard inside the
        jitted decode/prefill calls.
        """
        if shardings is not None:
            return (jax.device_put(self.block_tables, shardings[0]),
                    jax.device_put(self.pos, shardings[1]))
        return jnp.asarray(self.block_tables), jnp.asarray(self.pos)
