"""musicgen-medium [audio] — 48L, d_model=1536, 24H (kv=24, MHA), d_ff=6144,
vocab=2048 per codebook, decoder-only over EnCodec tokens with 4 codebooks
(delay pattern).  The EnCodec frontend is a STUB per the assignment:
``input_specs`` provides the 4 codebook token streams directly.
[arXiv:2306.05284; hf]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    n_codebooks=4,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="musicgen-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        n_codebooks=2,
    )


register_arch("musicgen-medium", CONFIG, reduced)
