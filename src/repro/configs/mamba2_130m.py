"""mamba2-130m [ssm] — 24L, d_model=768, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mamba2-smoke",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
    )


register_arch("mamba2-130m", CONFIG, reduced)
