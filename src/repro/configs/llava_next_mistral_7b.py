"""llava-next-mistral-7b [vlm] — 32L, d_model=4096, 32H (GQA kv=8),
d_ff=14336, vocab=32000, anyres tiling.  The vision frontend is a STUB per
the assignment: ``input_specs`` provides precomputed patch embeddings
(anyres -> up to 2880 image tokens) which are prepended to the text stream.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend="vision",
    img_tokens=2880,  # anyres: 5 tiles x 576 patch tokens
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="llava-next-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        img_tokens=16,
    )


register_arch("llava-next-mistral-7b", CONFIG, reduced)
