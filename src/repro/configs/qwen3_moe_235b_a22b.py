"""qwen3-moe-235b-a22b [moe] — 94L, d_model=4096, 64H (GQA kv=4),
expert d_ff=1536, vocab=151936, MoE 128 experts top-8, no shared expert.
[hf:Qwen/Qwen3-30B-A3B family; hf]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,            # = expert width (no shared expert)
    vocab_size=151_936,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    n_shared_experts=0,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-moe-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        n_experts=8,
        top_k=2,
        moe_d_ff=96,
    )


register_arch("qwen3-moe-235b-a22b", CONFIG, reduced)
