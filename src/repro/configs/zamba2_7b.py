"""zamba2-7b [hybrid] — 81L, d_model=3584, 32H (GQA kv=32, i.e. MHA in the
shared block), d_ff=14336, vocab=32000, ssm_state=64.  Mamba2 backbone with a
*shared-weight* attention block applied periodically (every 6 layers here).
[arXiv:2411.15242; unverified]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    attn_every=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="zamba2-smoke",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
        attn_every=3,
    )


register_arch("zamba2-7b", CONFIG, reduced)
