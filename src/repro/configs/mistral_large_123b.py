"""mistral-large-123b [dense] — 88L, d_model=12288, 96H (GQA kv=8),
d_ff=28672, vocab=32768, full attention.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mistral-large-smoke",
        n_layers=3,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
    )


register_arch("mistral-large-123b", CONFIG, reduced)
