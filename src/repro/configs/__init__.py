"""Architecture configs (one module per assigned arch) + the paper's own
FPGA configuration (``imagine_u55``).  Import via ``repro.config.get_arch``.
"""
