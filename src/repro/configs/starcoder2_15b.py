"""starcoder2-15b [dense] — 40L, d_model=6144, 48H (GQA kv=4), d_ff=24576,
vocab=49152, GQA + RoPE.  [arXiv:2402.19173; hf]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100_000.0,
    mlp_gated=False,  # starcoder2 uses a plain GELU MLP (c_fc/c_proj)
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="starcoder2-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )


register_arch("starcoder2-15b", CONFIG, reduced)
