"""llama4-scout-17b-a16e [moe] — 48L, d_model=5120, 40H (GQA kv=8),
expert d_ff=8192, vocab=202048, MoE 16 experts top-1 + 1 shared expert,
early fusion (text backbone here; modality frontend stubbed).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,            # shared-expert width
    vocab_size=202_048,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="llama4-scout-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=4,
        top_k=1,
        moe_d_ff=128,
        n_shared_experts=1,
    )


register_arch("llama4-scout-17b-a16e", CONFIG, reduced)
