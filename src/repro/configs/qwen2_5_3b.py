"""qwen2.5-3b [dense] — 36L, d_model=2048, 16H (GQA kv=2), d_ff=11008,
vocab=151936, GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]
"""

import dataclasses

from repro.config.base import ModelConfig
from repro.config.registry import register_arch

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen2.5-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )


register_arch("qwen2.5-3b", CONFIG, reduced)
