"""Trip-count-aware static cost analysis of post-optimization HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
every ``while`` body ONCE — for scan-over-layers models that undercounts
FLOPs/bytes by ~n_layers x.  This analyzer walks the HLO text with a symbol
table per computation and multiplies each ``while`` body's cost by its trip
count (recovered from the loop condition's comparison constant — exact for
jax-emitted scans, which count 0..L-1 step 1), recursing through nested
scans (layers x flash-attention KV blocks x SSD head groups).

Counted:
  flops        — dot (2·|out|·|contraction|), convolution (approx),
                 arithmetic elementwise (1/elem), reduce, transcendentals
  bytes        — per instruction: operand + output bytes, with fusions
                 counted at their boundary only (internal reuse is free,
                 matching the fusion memory model)
  collectives  — per family: output bytes of all-reduce / all-gather /
                 reduce-scatter / all-to-all / collective-permute, x trips

All values are PER DEVICE: the input is the SPMD-partitioned module.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "floor", "ceil", "round-nearest-afz", "sign",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "atan2", "expm1", "log1p",
                   "cbrt", "erf"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done"}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$")


@dataclass
class Instr:
    name: str
    op: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    raw: str            # attribute tail after the operand parens
    args: str = ""      # literal text inside the operand parens


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, Instr] = field(default_factory=dict)
    root: Optional[Instr] = None


# regions of interest: substring of the HLO op_name metadata -> tag.
# Used to attribute bytes/flops to model sub-systems (attention, SSD, MoE,
# CE) so kernel-substitution analyses can re-price a region's traffic.
REGION_TAGS = {
    "attend_flash": "attention",
    "attend_dense": "attention",
    "attend_local_gather": "attention",
    "attend_decode": "attention",
    "ssd_chunked": "ssd",
    "_ssm_run": "ssd",
    "moe_block": "moe",
    "chunked_ce": "ce",
    "_lm_logits": "ce",
}


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    regions: Dict[str, List[float]] = field(default_factory=dict)

    def add_region(self, tag: str, flops: float, nbytes: float):
        cur = self.regions.setdefault(tag, [0.0, 0.0])
        cur[0] += flops
        cur[1] += nbytes

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.transcendentals += other.transcendentals
        self.bytes += other.bytes
        for k in self.collectives:
            self.collectives[k] += other.collectives[k]
        for tag, (f, b) in other.regions.items():
            self.add_region(tag, f, b)
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.transcendentals * k, self.bytes * k,
                    {c: v * k for c, v in self.collectives.items()},
                    {t: [f * k, b * k] for t, (f, b) in self.regions.items()})


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(shapes) -> float:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if cur is None:
            m = _COMP_START.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operand names: %tokens inside the top-level parens
        depth, args_end = 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    args_end = i
                    break
                depth -= 1
        arg_str = rest[:args_end]
        operands = re.findall(r"%([\w.\-]+)", arg_str)
        instr = Instr(name, op, _shape_list(type_str), operands,
                      rest[args_end + 1:], arg_str)
        cur.instrs.append(instr)
        cur.table[name] = instr
        if line.lstrip().startswith("ROOT"):
            cur.root = instr
    return comps


def _trip_count(cond: Computation) -> int:
    """jax scans: condition is `iter < constant`; take the compare's
    constant operand (fall back to the largest integer constant)."""
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"(-?\d+)", ins.args or "")
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare":
            for opnd in ins.operands:
                if opnd in consts:
                    return max(1, consts[opnd])
    return max([1] + list(consts.values()))


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        entry = None
        for name, comp in self.comps.items():
            if re.match(r"^main", name):
                entry = name
        # ENTRY computation is whichever one the others never call
        if entry is None:
            called = set()
            for comp in self.comps.values():
                for ins in comp.instrs:
                    for ref in re.findall(r"(?:calls|body|condition|"
                                          r"to_apply|branch_computations)="
                                          r"[{]?%?([\w.\-,%\s]+)", ins.raw):
                        for r in re.findall(r"[\w.\-]+", ref):
                            called.add(r)
            for name in self.comps:
                if name not in called:
                    entry = name
        self.entry = entry

    # ------------------------------------------------------------------
    def _called(self, ins: Instr, key: str) -> List[str]:
        # braced list: key={%a, %b} ; single ref: key=%a
        m = re.search(key + r"=\{([^}]*)\}", ins.raw)
        if m:
            return [n.strip().lstrip("%") for n in m.group(1).split(",")
                    if n.strip()]
        m = re.search(key + r"=%?([\w.\-]+)", ins.raw)
        return [m.group(1)] if m else []

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._memo[comp_name] = total  # guards recursion
        for ins in comp.instrs:
            total += self._instr_cost(comp, ins)
        return total

    _PURE_MOVE = ("parameter", "convert", "bitcast", "copy", "transpose")

    def _is_pure_convert_fusion(self, ins: Instr) -> bool:
        """Pure dtype/layout-move fusions (convert/copy/transpose chains on a
        single input) are charged at their consumer: on TPU converts fuse
        into consumers and entry-parameter layouts are assigned to suit
        them, so this traffic does not exist separately."""
        if ins.op != "fusion":
            return False
        for callee in self._called(ins, "calls"):
            comp = self.comps.get(callee)
            if comp is None:
                return False
            for sub in comp.instrs:
                if sub.op not in self._PURE_MOVE:
                    return False
        return True

    def _slice_convert_source(self, comp: Computation, ins: Instr):
        """If ``ins`` is a fusion that only slices + converts one input
        (e.g. per-layer dequantization of a packed int8 weight stack),
        return the effective read: (source_dtype, fusion output dims).
        On TPU the convert fuses into the consuming dot, so the HBM read
        is the SLICED region at the STORAGE dtype."""
        if ins.op != "fusion" or len(ins.operands) != 1:
            return None
        has_slice = False
        for callee in self._called(ins, "calls"):
            cc = self.comps.get(callee)
            if cc is None:
                return None
            for sub in cc.instrs:
                if sub.op in ("slice", "dynamic-slice"):
                    has_slice = True
                elif sub.op not in self._PURE_MOVE:
                    return None
        if not has_slice:
            return None
        src = comp.table.get(ins.operands[0])
        if src is None or not src.out_shapes or not ins.out_shapes:
            return None
        return [(src.out_shapes[0][0], ins.out_shapes[0][1])]

    def _resolve_convert(self, comp: Computation, name: str, depth: int = 4):
        """Walk back through dtype converts/bitcasts/copies (standalone or
        as pure-convert fusions) to the storage tensor: on TPU a convert
        fuses into its consumer, so the consumer's HBM read is the ORIGINAL
        dtype, not the widened one."""
        src = comp.table.get(name)
        while src is not None and depth > 0 and len(src.operands) >= 1:
            if src.op in ("convert", "bitcast", "copy") and \
                    len(src.operands) == 1:
                nxt = comp.table.get(src.operands[0])
            elif self._is_pure_convert_fusion(src) and len(src.operands) == 1:
                nxt = comp.table.get(src.operands[0])
            else:
                break
            if nxt is None:
                break
            src = nxt
            depth -= 1
        return src

    def _operand_shapes(self, comp: Computation, ins: Instr):
        shapes = []
        for o in ins.operands:
            src = self._resolve_convert(comp, o)
            if src is None:
                continue
            synth = self._slice_convert_source(comp, src)
            if synth is not None:
                shapes.extend(synth)
            else:
                shapes.extend(src.out_shapes)
        return shapes

    @staticmethod
    def _region_of(ins: Instr) -> Optional[str]:
        m = re.search(r'op_name="([^"]*)"', ins.raw)
        if not m:
            return None
        for pat, tag in REGION_TAGS.items():
            if pat in m.group(1):
                return tag
        return None

    def _instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        c = self._instr_cost_inner(comp, ins)
        # attribute to a region.  Container ops (while/fusion/call) carry
        # the named_scope in their own metadata even when XLA clones the
        # inner instructions away from theirs, so containers "top up"
        # whatever their inner instructions did not already attribute.
        tag = self._region_of(ins)
        if tag is not None:
            if ins.op in ("while", "call", "conditional", "fusion"):
                prev_f, prev_b = c.regions.get(tag, [0.0, 0.0])
                c.add_region(tag, max(0.0, c.flops - prev_f),
                             max(0.0, c.bytes - prev_b))
            else:
                c.add_region(tag, c.flops, c.bytes)
        return c

    def _fusion_operand_bytes(self, comp: Computation, ins: Instr) -> List[float]:
        """Bytes each fusion operand actually contributes: if the fused
        computation only ever slices a parameter, the accessed region is the
        slice (a fusion reading layer i of a stacked weight does not read
        the whole stack)."""
        callee = None
        for cname in self._called(ins, "calls"):
            callee = self.comps.get(cname)
        out = []
        for idx, o in enumerate(ins.operands):
            src = self._resolve_convert(comp, o)
            if src is None:
                continue
            full = _nbytes(src.out_shapes)
            if callee is not None:
                # find parameter(idx) in the fused computation
                pname = None
                for sub in callee.instrs:
                    if sub.op == "parameter" and sub.args.strip() == str(idx):
                        pname = sub.name
                        break
                if pname is not None:
                    acc = self._accessed_elems(callee, pname)
                    if acc is not None and src.out_shapes:
                        dt_bytes = _DTYPE_BYTES.get(src.out_shapes[0][0], 4)
                        full = min(full, acc * dt_bytes)
            out.append(full)
        return out

    @staticmethod
    def _accessed_elems(callee: Computation, pname: str):
        """Elements of parameter ``pname`` the fused computation actually
        touches, walking through convert/bitcast/copy chains to slices.
        None = whole parameter (or unknown)."""
        frontier = [pname]
        elems = 0.0
        seen = set(frontier)
        sliced = False
        while frontier:
            cur = frontier.pop()
            for s in callee.instrs:
                if cur not in s.operands:
                    continue
                if s.op in ("convert", "bitcast", "copy") and s.name not in seen:
                    frontier.append(s.name)
                    seen.add(s.name)
                elif s.op in ("slice", "dynamic-slice"):
                    elems += _nelems(s.out_shapes)
                    sliced = True
                else:
                    return None
        return elems if sliced else None

    def _slice_cost(self, comp: Computation, ins: Instr) -> Cost:
        """Slicing/scatter: XLA aliases buffers (in-place in while loops) —
        traffic is the touched region, not the whole operand buffer."""
        c = Cost()
        op = ins.op
        if op in ("dynamic-slice", "slice"):
            c.bytes += 2.0 * _nbytes(ins.out_shapes)
        elif op == "dynamic-update-slice":
            upd = (comp.table.get(ins.operands[1])
                   if len(ins.operands) > 1 else None)
            touched = _nbytes(upd.out_shapes) if upd else _nbytes(ins.out_shapes)
            c.bytes += 2.0 * touched
        elif op == "gather":
            c.bytes += 2.0 * _nbytes(ins.out_shapes)
            if len(ins.operands) > 1:
                idx = comp.table.get(ins.operands[1])
                if idx:
                    c.bytes += _nbytes(idx.out_shapes)
        elif op == "scatter":
            upd = (comp.table.get(ins.operands[2])
                   if len(ins.operands) > 2 else None)
            if upd:
                c.bytes += 2.0 * _nbytes(upd.out_shapes)
                c.flops += _nelems(upd.out_shapes)  # combining fn
        return c

    def _instr_cost_inner(self, comp: Computation, ins: Instr) -> Cost:
        c = Cost()
        op = ins.op
        if op in _FREE_OPS:
            return c

        if op == "while":
            bodies = self._called(ins, "body")
            conds = self._called(ins, "condition")
            # XLA annotates jax scans with the exact trip count
            m = re.search(r'known_trip_count.*?"n"\s*:\s*"(\d+)"', ins.raw)
            if m:
                trips = int(m.group(1))
            elif conds and conds[0] in self.comps:
                trips = _trip_count(self.comps[conds[0]])
            else:
                trips = 1
            if bodies and bodies[0] in self.comps:
                c += self.cost_of(bodies[0]).scaled(trips)
            if conds and conds[0] in self.comps:
                c += self.cost_of(conds[0]).scaled(trips)
            return c

        if op == "fusion":
            if self._is_pure_convert_fusion(ins) and len(ins.operands) == 1:
                return c  # conversion traffic; charged at the consumer
            if self._slice_convert_source(comp, ins) is not None:
                return c  # slice+convert (dequant) fuses into the consumer
            aliased_root = False
            for callee in self._called(ins, "calls"):
                inner = self.cost_of(callee)
                # fusion boundary: memory = operands + outputs only
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                for k in c.collectives:
                    c.collectives[k] += inner.collectives[k]
                for tag, (f, _) in inner.regions.items():
                    c.add_region(tag, f, 0.0)  # inner bytes are fused away
                callee_comp = self.comps.get(callee)
                if callee_comp is not None:
                    out_elems = _nelems(ins.out_shapes)
                    for sub in callee_comp.instrs:
                        # in-place update of a buffer the size of the fusion
                        # output (possibly re-converted at the root)
                        if sub.op in ("dynamic-update-slice", "scatter") and \
                                _nelems(sub.out_shapes) == out_elems:
                            aliased_root = True
                            break
            opnd_bytes = self._fusion_operand_bytes(comp, ins)
            if aliased_root and opnd_bytes:
                # in-place update fusion: the big buffer operand is aliased
                # with the output — traffic is only the non-aliased operands
                # (the update + indices), twice (read + write of the slice).
                big = max(opnd_bytes)
                c.bytes += 2.0 * (sum(opnd_bytes) - big)
            else:
                c.bytes += _nbytes(ins.out_shapes) + sum(opnd_bytes)
            return c

        if op in ("dynamic-slice", "slice", "dynamic-update-slice", "gather",
                  "scatter"):
            return self._slice_cost(comp, ins)

        if op in ("call", "conditional", "map", "reduce", "reduce-window",
                  "sort", "select-and-scatter"):
            for key in ("to_apply", "calls", "branch_computations"):
                for callee in self._called(ins, key):
                    if callee in self.comps:
                        sub = self.cost_of(callee)
                        n = _nelems(self._operand_shapes(comp, ins)) if op in (
                            "reduce", "reduce-window", "map") else 1
                        c += sub.scaled(max(1, n))
            c.bytes += _nbytes(ins.out_shapes) + _nbytes(
                self._operand_shapes(comp, ins))
            return c

        # collectives
        for coll in _COLLECTIVES:
            if op == coll or op.startswith(coll + "-"):
                if not op.endswith("-done"):
                    nb = _nbytes(ins.out_shapes)
                    c.collectives[coll] += nb
                    c.bytes += nb + _nbytes(self._operand_shapes(comp, ins))
                return c

        if op == "convert":
            # dtype conversion fuses into its consumer on TPU: the only HBM
            # traffic is one read of the source tensor (already charged at
            # the consumer via _resolve_convert), so a standalone convert
            # contributes nothing extra.
            return c

        out_bytes = _nbytes(ins.out_shapes)
        in_bytes = _nbytes(self._operand_shapes(comp, ins))
        c.bytes += out_bytes + in_bytes

        if op == "dot":
            lhs = comp.table.get(ins.operands[0]) if ins.operands else None
            contract = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
            if lhs is not None and m and lhs.out_shapes:
                dims = lhs.out_shapes[0][1]
                for idx in m.group(1).split(","):
                    if idx:
                        contract *= dims[int(idx)]
            out_elems = _nelems(ins.out_shapes)
            c.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems = _nelems(ins.out_shapes)
            lhs = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
            kernel = 1
            if lhs is not None and lhs.out_shapes:
                for d in lhs.out_shapes[0][1][:-1]:
                    kernel *= d
            c.flops += 2.0 * out_elems * kernel
        elif op in _ELEMENTWISE_1FLOP:
            c.flops += _nelems(ins.out_shapes)
        elif op in _TRANSCENDENTAL:
            n = _nelems(ins.out_shapes)
            c.transcendentals += n
            c.flops += n
        return c

    # ------------------------------------------------------------------
    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def top_contributors(text: str, n: int = 20):
    """Debug/profile view: the n instructions with the largest TOTAL bytes
    (cost x trip multiplier).  This is the dry-run's answer to a profiler
    trace — §Perf iterations read this to find what to attack."""
    hc = HloCost(text)
    total = hc.total()  # populate memo
    del total
    # compute per-computation multiplicity by walking from the entry
    mult: Dict[str, float] = {hc.entry: 1.0}
    order = [hc.entry]
    seen = {hc.entry}
    while order:
        name = order.pop(0)
        comp = hc.comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            trips = 1.0
            if ins.op == "while":
                m = re.search(r'known_trip_count.*?"n"\s*:\s*"(\d+)"', ins.raw)
                trips = float(m.group(1)) if m else 1.0
            for key in ("calls", "body", "condition", "to_apply",
                        "branch_computations"):
                for callee in hc._called(ins, key):
                    if callee in hc.comps:
                        mult[callee] = mult.get(callee, 0.0) + \
                            mult.get(name, 1.0) * trips
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
    rows = []
    for cname, comp in hc.comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        for ins in comp.instrs:
            c = hc._instr_cost(comp, ins)
            if ins.op in ("while",):
                continue  # children accounted separately
            if c.bytes <= 0 and c.flops <= 0:
                continue
            meta = re.search(r'op_name="([^"]*)"', ins.raw)
            rows.append((c.bytes * k, c.flops * k, ins.op,
                         f"{cname}/{ins.name}",
                         meta.group(1)[-80:] if meta else ""))
    rows.sort(reverse=True)
    return rows[:n]


def analyze_hlo_text(text: str) -> Dict[str, float]:
    cost = HloCost(text).total()
    out = {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "bytes": cost.bytes,
        "collectives": dict(cost.collectives),
        "regions": {t: {"flops": f, "bytes": b}
                    for t, (f, b) in cost.regions.items()},
    }
    out["collectives"]["total"] = sum(cost.collectives.values())
    return out
