"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``cost_analysis`` supplies per-device FLOPs/bytes of the SPMD-partitioned
module.  Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO text and sum the (per-device) output bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional

HW_V5E = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape token: dtype[d0,d1,...]   (layout suffix {…} optional)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective family.

    For each collective instruction we count the *output* tensor bytes
    (tuple outputs summed) — the per-device payload of that op.  ``fusion``
    and ``async`` wrappers (``all-gather-start`` etc.) are matched by
    prefix; ``-done`` ops carry no new bytes.
    """
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+([\w-]+)", rhs)
        if not m:
            continue
        opname = m.group(3)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                base = c
                break
        if base is None or opname.endswith("-done"):
            continue
        shapes_src = m.group(1) if m.group(1) is not None else m.group(2)
        nbytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(shapes_src)
        )
        out[base] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _cost_value(cost: Any, key: str) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, dict):
        return float(cost.get(key, 0.0))
    if isinstance(cost, (list, tuple)) and cost:
        return float(cost[0].get(key, 0.0))
    return 0.0


def roofline_report(
    compiled,
    n_devices: int,
    *,
    model_flops: Optional[float] = None,
    model_bytes: Optional[float] = None,
    hw: Dict[str, float] = HW_V5E,
) -> Dict[str, Any]:
    """Build the §Roofline record for one compiled cell.

    Primary numbers come from the trip-count-aware HLO analyzer
    (repro/roofline/hlo_cost.py) — XLA's own cost_analysis visits while
    bodies once and undercounts scan-over-layers models by ~n_layers x;
    its numbers are kept in the record for reference.
    """
    from repro.roofline.hlo_cost import analyze_hlo_text

    cost = compiled.cost_analysis()
    xla_flops = _cost_value(cost, "flops")
    xla_bytes = _cost_value(cost, "bytes accessed")
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    own = analyze_hlo_text(hlo) if hlo else {
        "flops": xla_flops, "bytes": xla_bytes,
        "collectives": {"total": 0.0}}
    flops_dev = max(own["flops"], xla_flops)
    bytes_dev = own["bytes"]
    coll = own["collectives"]

    t_compute = flops_dev / hw["peak_flops"]
    t_memory = bytes_dev / hw["hbm_bw"]
    t_coll = coll["total"] / hw["link_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    report = {
        "n_devices": n_devices,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "xla_flops_per_device": xla_flops,       # reference (body-once)
        "xla_bytes_per_device": xla_bytes,
        "regions": own.get("regions", {}),
        **terms,
        "dominant": dominant,
        "bound_seconds": max(terms.values()),
        "memory_analysis": mem,
    }
    if model_flops is not None:
        report["model_flops_total"] = model_flops
        hlo_total = flops_dev * n_devices
        report["useful_flops_ratio"] = (
            model_flops / hlo_total if hlo_total else 0.0)
        # classic roofline: an IDEAL implementation takes
        # max(useful_flops at peak, minimal bytes at HBM bw) — decode is
        # legitimately memory-bound, training compute-bound.
        ideal_c = model_flops / (n_devices * hw["peak_flops"])
        ideal_m = (model_bytes or 0.0) / (n_devices * hw["hbm_bw"])
        ideal = max(ideal_c, ideal_m)
        bound = max(terms.values())
        report["ideal_compute_s"] = ideal_c
        report["ideal_memory_s"] = ideal_m
        report["roofline_fraction"] = ideal / bound if bound else 0.0
    return report


def compiled_costs(compiled) -> Dict[str, float]:
    """Trip-count-corrected FLOPs/bytes of one compiled executable.

    The cross-validation channel for the ``repro.obs.costs`` ledger
    (``tests/test_costs.py``): XLA's own ``cost_analysis()`` visits every
    ``while`` body once — scan-over-layers models undercount by
    ~n_layers× — so the primary numbers come from the trip-count-aware
    HLO analyzer, with the raw XLA values kept for reference (and as a
    floor, matching :func:`roofline_report`).
    """
    from repro.roofline.hlo_cost import analyze_hlo_text

    cost = compiled.cost_analysis()
    xla_flops = _cost_value(cost, "flops")
    xla_bytes = _cost_value(cost, "bytes accessed")
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    own = analyze_hlo_text(hlo) if hlo else {
        "flops": xla_flops, "bytes": xla_bytes, "transcendentals": 0.0}
    return {
        "flops": max(own["flops"], xla_flops),
        "bytes": own["bytes"],
        "transcendentals": own.get("transcendentals", 0.0),
        "xla_flops": xla_flops,
        "xla_bytes": xla_bytes,
    }


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D for training (N = params,
    D = tokens), 2·N_active·D for inference steps."""
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def model_bytes_for_cell(cfg, shape, weight_bits: int = 0,
                         cache_bytes: float = 0.0) -> float:
    """Minimal global HBM bytes an ideal implementation must move.

    decode : active weights once per step (b/8 bytes each with the IMAGine
             engine, else 2 bf16) + one read of the KV/state cache
    prefill: weights once + one cache write + one activation pass
    train  : params fwd+bwd reads, grad write, AdamW m/v read+write
             (≈ 26 bytes/param with bf16 params + fp32 moments)
    """
    wb = (weight_bits / 8.0) if weight_bits else 2.0
    n_active = cfg.active_param_count()
    if shape.kind == "decode":
        return n_active * wb + cache_bytes
    if shape.kind == "prefill":
        act = 2.0 * shape.global_batch * shape.seq_len * cfg.d_model * cfg.n_layers
        return n_active * wb + cache_bytes + act
    return cfg.param_count() * 26.0
