from repro.roofline.analysis import (
    HW_V5E,
    collective_bytes_from_hlo,
    roofline_report,
)

__all__ = ["HW_V5E", "collective_bytes_from_hlo", "roofline_report"]
