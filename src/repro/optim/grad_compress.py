"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 512+ chips the cross-pod gradient all-reduce is the dominant collective
for data-parallel training.  Compressing gradients to int8 before the
``pod``-axis psum cuts those bytes 4x (bf16->int8 ... 2x; fp32->int8 ... 4x);
the quantization error is carried in an error-feedback buffer so the
*accumulated* update stays unbiased (Karimireddy et al., 2019 — SignSGD-EF
family).

Implementation notes: the compress -> psum -> decompress sequence lives
inside ``shard_map`` over the pod axis (see repro/dist/collectives.py); the
scale is the per-leaf absmax, itself psum-maxed so every pod uses the same
dequantization scale.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def ef_state_init(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(g: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Quantize/dequantize roundtrip (what the wire would carry)."""
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def error_feedback_compress(
    grads: Pytree, ef: Pytree, bits: int = 8
) -> Tuple[Pytree, Pytree]:
    """Returns (compressed_grads, new_ef).  compressed + ef' == grads + ef."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        sent = compress_decompress(target, bits)
        return sent, target - sent

    out = jax.tree.map(one, grads, ef)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    sent = treedef.unflatten([l[0] for l in leaves])
    new_ef = treedef.unflatten([l[1] for l in leaves])
    return sent, new_ef
