from repro.optim.optimizers import (
    OptState,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    make_optimizer,
    sgd_init,
    sgd_update,
)
from repro.optim.schedule import cosine_warmup
from repro.optim.grad_compress import (
    compress_decompress,
    ef_state_init,
    error_feedback_compress,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "sgd_init",
    "sgd_update",
    "make_optimizer",
    "cosine_warmup",
    "compress_decompress",
    "ef_state_init",
    "error_feedback_compress",
]
