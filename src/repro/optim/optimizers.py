"""Optimizers, written against plain pytrees (no optax dependency).

AdamW keeps fp32 moments regardless of param dtype (the standard bf16-param
+ fp32-state large-model recipe); Adafactor offers the memory-lean
alternative (factored second moment) for the 100B+ configs; SGD exists as
the trivial baseline and for tests.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig

Pytree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Pytree


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Pytree) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        inner={"m": jax.tree.map(zeros, params),
               "v": jax.tree.map(zeros, params)},
    )


def adamw_update(grads: Pytree, state: OptState, params: Pytree,
                 cfg: TrainConfig, lr: jnp.ndarray) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / c1, v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.inner["m"], state.inner["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, OptState(step, {"m": new_m, "v": new_v})


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-lean for 100B+ params)
# ---------------------------------------------------------------------------


def adafactor_init(params: Pytree) -> OptState:
    def make(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return OptState(
        step=jnp.zeros((), jnp.int32),
        inner=jax.tree.map(make, params, is_leaf=lambda x: hasattr(x, "shape")),
    )


def adafactor_update(grads: Pytree, state: OptState, params: Pytree,
                     cfg: TrainConfig, lr: jnp.ndarray) -> Tuple[Pytree, OptState]:
    step = state.step + 1
    decay = 1.0 - step.astype(jnp.float32) ** -0.8
    eps = 1e-30

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps)
            )
            upd_ = g / jnp.maximum(denom, eps)
            news = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            upd_ = g / (jnp.sqrt(v) + 1e-8)
            news = {"v": v}
        # update clipping (RMS <= 1) as in the Adafactor paper
        rms = jnp.sqrt(jnp.mean(upd_ * upd_) + eps)
        upd_ = upd_ / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32)
                 - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), news

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state.inner)
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_s = treedef.unflatten([o[1] for o in outs])
    return new_p, OptState(step, new_s)


# ---------------------------------------------------------------------------
# SGD (+momentum)
# ---------------------------------------------------------------------------


def sgd_init(params: Pytree) -> OptState:
    return OptState(
        step=jnp.zeros((), jnp.int32),
        inner=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def sgd_update(grads: Pytree, state: OptState, params: Pytree,
               cfg: TrainConfig, lr: jnp.ndarray) -> Tuple[Pytree, OptState]:
    step = state.step + 1

    def upd(p, g, m):
        m = cfg.beta1 * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    out = jax.tree.map(upd, params, grads, state.inner)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    return new_p, OptState(step, new_m)


_OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "sgd": (sgd_init, sgd_update),
}


def make_optimizer(name: str) -> Tuple[Callable, Callable]:
    if name not in _OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}: {sorted(_OPTIMIZERS)}")
    return _OPTIMIZERS[name]
