"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The two-pod production mesh can flip its ``pod`` axis from data to
pipeline parallelism (``MeshConfig.pod_axis_mode``): layers are split into
``n_stages`` contiguous stages, one stage per pod, and microbatches stream
through with ``lax.ppermute`` handing activations to the next stage each
tick — the standard fill/drain schedule (bubble fraction
``(S-1)/(M+S-1)``).

``pipeline_apply`` is exact (bitwise-equal math to running the stages
sequentially) and differentiable: the ppermute transposes to the reverse
permute, so gradients pipeline backwards through the same schedule.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.hints import active_mesh

Pytree = Any


def microbatch(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    """(B, ...) -> (n_micro, B // n_micro, ...)."""
    b = x.shape[0]
    if b % n_micro != 0:
        raise ValueError(f"batch {b} not divisible by n_micro {n_micro}")
    return x.reshape((n_micro, b // n_micro) + x.shape[1:])


def stack_stages(tree: Pytree, n_stages: int) -> Pytree:
    """Reshape each (L, ...) leaf to (n_stages, L // n_stages, ...) so the
    leading axis can be sharded one-stage-per-pod."""

    def one(a):
        l = a.shape[0]
        if l % n_stages != 0:
            raise ValueError(
                f"layer count {l} not divisible by n_stages {n_stages}")
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])

    return jax.tree.map(one, tree)


def _sequential(staged_params: Pytree, micros: jnp.ndarray,
                stage_fn: Callable, n_stages: int) -> jnp.ndarray:
    h = micros
    for s in range(n_stages):
        w = jax.tree.map(lambda a: a[s], staged_params)
        h = stage_fn(w, h)
    return h


def pipeline_apply(
    staged_params: Pytree,
    micros: jnp.ndarray,
    stage_fn: Callable[[Pytree, jnp.ndarray], jnp.ndarray],
    *,
    n_stages: int,
    axis_name: str = "pod",
) -> jnp.ndarray:
    """Run ``micros`` (n_micro, mb, ...) through ``n_stages`` pipeline
    stages whose stacked params live one-per-device along ``axis_name``.

    ``stage_fn(stage_params, h) -> h`` applies one stage's layer slice.
    Returns (n_micro, mb, ...) outputs, replicated over the mesh.  Falls
    back to an exact sequential sweep when no mesh with ``axis_name`` (of
    the right size) is active — same numerics, no collectives.
    """
    mesh = active_mesh()
    if (mesh is None or axis_name not in mesh.axis_names
            or dict(mesh.shape)[axis_name] != n_stages):
        return _sequential(staged_params, micros, stage_fn, n_stages)

    n_micro = micros.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def ranked(w_local, micros):
        # w_local: (1, L/S, ...) — this rank's stage slice
        w = jax.tree.map(lambda a: a[0], w_local)
        sidx = jax.lax.axis_index(axis_name)
        state = jnp.zeros(micros.shape[1:], micros.dtype)
        outputs = jnp.zeros_like(micros)
        for t in range(n_micro + n_stages - 1):
            # stage 0 injects microbatch t (junk past the last microbatch
            # never reaches the collection window)
            x_in = jnp.where(sidx == 0, micros[min(t, n_micro - 1)], state)
            y = stage_fn(w, x_in)
            if t >= n_stages - 1:
                done = jnp.where(sidx == n_stages - 1, y, 0.0)
                outputs = outputs.at[t - (n_stages - 1)].set(
                    done.astype(outputs.dtype))
            state = jax.lax.ppermute(y, axis_name, perm)
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(outputs, axis_name)

    return shard_map(
        ranked,
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_rep=False,
    )(staged_params, micros)
