"""Distribution layer: sharding rules, activation hints, compressed
collectives, pipeline parallelism.

  ``hints``        best-effort with_sharding_constraint wrappers model code
                   calls unconditionally (no-ops off-mesh)
  ``sharding``     name-based TP/FSDP param specs + batch/opt/cache specs
  ``collectives``  int8-wire psum for the cross-pod gradient reduction
  ``pipeline``     GPipe over the pod axis (microbatch/stack/apply)
"""

from repro.dist.collectives import compressed_psum_leaf
from repro.dist.hints import (
    active_mesh,
    make_mesh,
    shard_batch_seq,
    shard_experts,
    use_mesh,
    with_hint,
)
from repro.dist.pipeline import microbatch, pipeline_apply, stack_stages
from repro.dist.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    param_spec,
)

__all__ = [
    "active_mesh",
    "batch_shardings",
    "cache_shardings",
    "compressed_psum_leaf",
    "make_mesh",
    "microbatch",
    "opt_state_shardings",
    "param_shardings",
    "param_spec",
    "pipeline_apply",
    "shard_batch_seq",
    "shard_experts",
    "stack_stages",
    "use_mesh",
    "with_hint",
]
