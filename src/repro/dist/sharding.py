"""Sharding rules: name-based tensor-parallel specs for every param tree
in the zoo, plus batch / optimizer-state / KV-cache shardings.

The rules are *name-and-shape* driven, not architecture driven: a leaf's
key path decides the candidate axis (column-parallel QKV/up projections
shard their output axis, row-parallel out/down projections shard their
input axis, stacked MoE experts shard the expert axis), and a divisibility
check against the mesh decides whether the shard actually happens —
non-divisible dimensions degrade to replication, never error.

All functions accept any object with ``axis_names`` and a ``shape``
name->size mapping (a real ``jax.sharding.Mesh`` or a test stand-in).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any

# column-parallel (shard the output-feature axis): activations stay
# replicated, outputs become model-sharded.
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "lm_head"}
# row-parallel (shard the input-feature axis): consumes model-sharded
# activations, XLA inserts the reduce.
_ROW = {"wo", "w_down", "out_proj"}
_SPECIAL = {"embed"}
# engine PackedLinear leaves ride the rules of their owning linear.
_ENGINE_LEAVES = {"packed", "scale", "bias", "w"}

# decode-cache / page-pool leaves, by key name.  The stacked slot cache
# (L, B, T, Hkv, Dh) and the KVPages pool (L, P, page, Hkv, Dh) share one
# rule set: axis 1 (batch lanes or physical pages) shards over the data
# axes, the KV-head axis over ``model``.
_ATTN_KV_KEYS = {"k", "v", "k_global", "v_global", "k_local", "v_local"}
# int8-cache / quantized-page scale pools: trailing axis is the KV-head
# axis and must follow its K/V pool's head sharding.
_KV_SCALE_KEYS = {"k_scale", "v_scale"}
# host-built paged-serving index state (block tables, per-lane positions,
# lane-activity masks): lane axis over the data axes, never ``model``.
_PAGE_STATE_KEYS = {"block_tables", "pos", "active"}

_STACKED_CACHE_KEYS = (
    _ATTN_KV_KEYS | _KV_SCALE_KEYS | {"conv", "h"} | _PAGE_STATE_KEYS
)


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    return str(k)


def _mesh_sizes(mesh) -> dict:
    shape = mesh.shape
    return dict(shape)


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _divisible_prefix(dim: int, axes: Tuple[str, ...], sizes: dict):
    """Longest prefix of ``axes`` whose size product divides ``dim``."""
    kept, prod = [], 1
    for a in axes:
        if dim <= 0 or dim % (prod * sizes[a]) != 0:
            break
        kept.append(a)
        prod *= sizes[a]
    return tuple(kept)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def param_spec(path, leaf, mesh, model_axis: str = "model") -> P:
    """Tensor-parallel PartitionSpec for one param leaf, by key path."""
    ndim = getattr(leaf, "ndim", 0)
    spec = [None] * ndim
    sizes = _mesh_sizes(mesh)
    msize = sizes.get(model_axis)
    if not msize or ndim == 0:
        return P(*spec)

    names = [_key_str(k) for k in path]
    leafname = names[-1] if names else ""
    owner = next(
        (n for n in reversed(names) if n in _COL | _ROW | _SPECIAL), None)
    if owner is None:
        return P(*spec)

    def put(ax: int):
        ax %= ndim
        if leaf.shape[ax] > 0 and leaf.shape[ax] % msize == 0:
            spec[ax] = model_axis

    stacked_experts = (
        "moe" in names
        and "shared" not in names
        and owner in _COL | _ROW
        and leafname in (owner, "packed", "scale")
        and ndim >= 3
    )
    if owner == "embed":
        if ndim >= 2:
            put(-2)  # vocab axis: (vocab, d) or audio (K, vocab, d)
    elif stacked_experts:
        put(ndim - 3)  # the expert axis of (..., E, D_in, D_out)
    elif owner in _COL:
        put(-1)
    elif owner in _ROW:
        if leafname == "bias":
            pass  # row-parallel bias spans the full output axis
        elif ndim >= 2:
            put(-2)
    return P(*spec)


def _with_fsdp(spec: P, leaf, mesh) -> P:
    """Layer ZeRO/FSDP on top of TP: shard the first still-replicated,
    divisible axis over the data axes (params + optimizer state of 100B+
    configs cannot fit TP-only)."""
    data_axes = _data_axes(mesh)
    if not data_axes:
        return spec
    sizes = _mesh_sizes(mesh)
    prod = 1
    for a in data_axes:
        prod *= sizes[a]
    if prod == 1:
        return spec
    entries = list(spec) + [None] * (leaf.ndim - len(spec))
    for ax in range(leaf.ndim):
        if entries[ax] is None and leaf.shape[ax] > 0 \
                and leaf.shape[ax] % prod == 0:
            entries[ax] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*entries)
    return spec


def param_shardings(mesh, params: Pytree, mode: str = "tp") -> Pytree:
    """NamedSharding tree for a param tree.  ``mode``: "tp" | "fsdp"."""

    def one(path, leaf):
        spec = param_spec(path, leaf, mesh)
        if mode == "fsdp":
            spec = _with_fsdp(spec, leaf, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(mesh, opt_state: Pytree, mode: str = "tp") -> Pytree:
    """Optimizer/EF state shardings: moment trees mirror the param tree's
    key names, so the same name-based rules apply; scalars replicate."""
    return param_shardings(mesh, opt_state, mode=mode)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_shardings(mesh, batch: Pytree) -> Pytree:
    """Batch-axis sharding over the data axes (``("pod", "data")`` when the
    pod axis carries data parallelism)."""
    data_axes = _data_axes(mesh)
    sizes = _mesh_sizes(mesh)

    def one(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim == 0 or not data_axes:
            return NamedSharding(mesh, P(*([None] * ndim)))
        kept = _divisible_prefix(leaf.shape[0], data_axes, sizes)
        spec = [kept if kept else None] + [None] * (ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch)


def cache_shardings(mesh, cache: Pytree) -> Pytree:
    """Decode-cache shardings: the batch (slot) axis over the data axes and
    KV heads over the model axis when divisible.

    Handles the stacked ``(L, B, ...)`` layout, the unstacked
    tuple-of-``(B, ...)`` production layout, *and* the paged-serving
    :class:`~repro.serve.pages.KVPages` pytree: its ``(L, P, page, Hkv,
    Dh)`` pools shard pages-over-data and heads-over-``model``, its scale
    pools follow their K/V pool's head sharding on the trailing axis, and
    block tables / positions / activity masks shard their lane axis over
    the data axes only (they are host-built index state).
    """
    data_axes = _data_axes(mesh)
    sizes = _mesh_sizes(mesh)
    msize = sizes.get("model", 0)

    def one(path, leaf):
        ndim = getattr(leaf, "ndim", 0)
        spec = [None] * ndim
        if ndim == 0:
            return NamedSharding(mesh, P())
        names = [_key_str(k) for k in path]
        # innermost cache-key name wins, so a KVPages (or cache dict)
        # nested inside a bigger serve-state tree keeps its rules.
        name = next(
            (n for n in reversed(names) if n in _STACKED_CACHE_KEYS),
            names[-1] if names else "")
        unstacked = any(
            isinstance(k, jax.tree_util.SequenceKey) for k in path)
        batch_ax = 0 if (name in _PAGE_STATE_KEYS or unstacked
                         or ndim < 2) else 1
        kept = _divisible_prefix(leaf.shape[batch_ax], data_axes, sizes)
        if kept:
            spec[batch_ax] = kept
        head_ax = None
        if name in _ATTN_KV_KEYS and ndim >= 4:
            head_ax = -2                  # (..., T/page, Hkv, Dh)
        elif name in _KV_SCALE_KEYS and ndim >= 3:
            head_ax = -1                  # (..., T/page, Hkv)
        if (head_ax is not None and msize and leaf.shape[head_ax] > 0
                and leaf.shape[head_ax] % msize == 0):
            spec[head_ax] = "model"       # KV-head axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)


def paged_attn_partition(mesh, model_axis: str, n_kv_heads: int,
                         batch: int) -> Tuple[Any, Any]:
    """Spec entries for shard_mapping the fused paged-attention kernel.

    Returns ``(head_entry, lane_entry)`` — the PartitionSpec entries for
    the pool's KV-head axis and the per-lane axes (queries, block tables,
    positions).  Heads shard over ``model_axis`` exactly when it divides
    (matching :func:`cache_shardings`' heads-over-model placement, so the
    per-shard kernel sees the head slice its pool shard already holds);
    lanes shard over the data axes when the batch divides.  Anything
    non-divisible degrades to replication (None entry), mirroring the
    degrade discipline of the param specs — never an error.
    """
    sizes = _mesh_sizes(mesh)
    msize = sizes.get(model_axis, 1)
    head = (model_axis if msize > 1 and n_kv_heads > 0
            and n_kv_heads % msize == 0 else None)
    daxes = tuple(a for a in _data_axes(mesh) if a != model_axis)
    prod = 1
    for a in daxes:
        prod *= sizes[a]
    lane = None
    if prod > 1 and batch > 0 and batch % prod == 0:
        lane = daxes if len(daxes) > 1 else daxes[0]
    return head, lane


def pool_pages_for_mesh(n_pages: int, mesh) -> int:
    """Round a page-pool size up so the physical page axis shards evenly
    over the data axes.

    Pages-over-data needs ``n_pages`` divisible by the data-axes product
    (the null page makes natural pool sizes odd); padding only adds spare
    capacity — the allocator simply has more free pages.  ``mesh=None``
    (or no data axes) returns ``n_pages`` unchanged.
    """
    if mesh is None or n_pages <= 0:
        return n_pages
    sizes = _mesh_sizes(mesh)
    prod = 1
    for a in _data_axes(mesh):
        prod *= sizes[a]
    if prod <= 1:
        return n_pages
    return -(-n_pages // prod) * prod
