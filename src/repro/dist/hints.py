"""Sharding hints: best-effort ``with_sharding_constraint`` wrappers.

Model code calls these unconditionally; they are no-ops unless a mesh
context is active (``with mesh:``), and they silently drop any axis name
the active mesh does not have or that does not divide the corresponding
array dimension.  That lets one model implementation run unchanged on a
single CPU device, an 8-device host test mesh, and the 512-chip dry-run.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AxisEntry = Union[None, str, Sequence[str]]


def active_mesh():
    """The mesh entered via :func:`use_mesh` / ``with mesh:``, or None.

    Checks both mesh-context mechanisms: ``jax.sharding.set_mesh`` (newer
    jax — :func:`use_mesh` prefers it when present, and it does NOT
    populate the legacy thread-resources slot) and the legacy ``with
    mesh:`` context.  Missing either would silently turn every sharding
    hint into a no-op on one side of the version boundary.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
            if m is not None and getattr(m, "axis_names", ()):
                return m
        except Exception:
            pass
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def use_mesh(mesh):
    """Version-portable mesh context manager.

    Newer jax spells this ``jax.sharding.set_mesh``; on older releases the
    ``Mesh`` object itself is the context manager.  Model-internal sharding
    hints (:func:`with_hint`) only fire inside this context.
    """
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_mesh(shape, axis_names):
    """Version-portable ``jax.make_mesh`` with Auto axis types when the
    installed jax supports explicit axis typing."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(shape, axis_names)


def _filter_entry(entry: AxisEntry, dim: int, axes: dict) -> AxisEntry:
    """Keep only axis names that exist and whose product divides ``dim``."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    kept = []
    prod = 1
    for n in names:
        size = axes.get(n)
        if size is None:
            continue
        if dim % (prod * size) != 0:
            continue
        kept.append(n)
        prod *= size
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def with_hint(x: jnp.ndarray, *entries: AxisEntry) -> jnp.ndarray:
    """Constrain ``x``'s sharding to ``P(*entries)`` where possible.

    Each positional entry maps to one leading dimension of ``x`` (missing
    trailing entries mean replicated).  Unknown axes and non-divisible
    dimensions degrade to replication instead of erroring.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    axes = dict(mesh.shape)  # name -> size; works for Mesh and AbstractMesh
    spec = [
        _filter_entry(e, x.shape[i], axes)
        for i, e in enumerate(entries[: x.ndim])
    ]
    spec += [None] * (x.ndim - len(spec))
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_batch_seq(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) activations: batch over the data axes, rest replicated."""
    return with_hint(x, ("pod", "data"))


def shard_experts(x: jnp.ndarray) -> jnp.ndarray:
    """Expert-stacked tensor: the E axis over the ``model`` mesh axis.

    Accepts ``(E, C, D)`` or batched ``(B, E, C, D)`` dispatch buffers.
    """
    if x.ndim >= 4:
        return with_hint(x, None, "model")
    return with_hint(x, "model")
