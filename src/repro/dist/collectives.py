"""Compressed cross-pod collectives.

``compressed_psum_leaf`` is the wire-level half of the int8 error-feedback
gradient compression in ``repro.optim.grad_compress``: inside a
``shard_map`` over the ``pod`` axis it quantizes the local shard to int8
with a *shared* scale (the absmax is itself pmax-reduced so every pod
dequantizes identically), all-reduces the integer codes, and dequantizes —
4x fewer bytes over the DCI than an fp32 psum.

``psum_partial`` is the reduction used by the mesh-native ``sharded``
engine backend for row-parallel partial GEMVs: exact fp32 by default,
compressed codes when the plan asks for them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum_leaf(
    x: jnp.ndarray, axis_name: str, bits: int = 8
) -> jnp.ndarray:
    """psum over ``axis_name`` carrying ``bits``-bit integer codes.

    Must be called inside ``shard_map`` (needs a bound mesh axis name).
    The integer accumulation is exact (|q| <= 127 per participant, int32
    accumulator); the only loss is the per-participant rounding, bounded
    by ``scale/2`` each.
    """
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    qmax = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    absmax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)


def psum_partial(x: jnp.ndarray, axis_name: str,
                 bits: int = 0) -> jnp.ndarray:
    """Reduce row-parallel partial GEMVs over ``axis_name``.

    ``bits=0`` is an exact fp32 ``psum`` — bit-identical to a
    single-device accumulation whenever the per-shard partials are exact
    in fp32.  ``bits=4/8`` route through :func:`compressed_psum_leaf`
    (UPMEM-style reduce-close-to-the-data with a narrow wire format),
    trading the per-participant ``scale/2`` rounding for 4-8x fewer
    bytes on the interconnect.  Must run inside ``shard_map``.
    """
    if bits:
        return compressed_psum_leaf(x, axis_name, bits)
    return jax.lax.psum(x, axis_name)
