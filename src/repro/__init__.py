"""repro: production-grade JAX reproduction of IMAGine (FPL 2024).

IMAGine is an FPGA Processor-in-Memory GEMV engine overlay.  This package
re-expresses its architectural contribution — weight-stationary, bit-serial
(bit-plane) GEMV that scales with memory capacity — as a TPU-native JAX
training/serving framework, together with an executable, cycle-accurate
model of the original FPGA engine (ISA, tile controller, latency models)
used to validate every number the paper reports.
"""

__version__ = "1.0.0"
