"""GEMV tile-array geometry (paper Fig. 2, Table III/IV).

Hierarchy on the FPGA:
  device = grid of GEMV tiles;  tile = 12 x 2 PIM blocks (+controller+fanout);
  PIM block = one BRAM18 = 16 bit-serial PEs  =>  32 PEs per BRAM36,
  12 BRAM36 per tile => 384 PEs per tile.
U55: 2016 BRAM36 -> 168 tiles -> 64512 PEs ("64K", Table IV).

The same geometry drives the TPU engine's logical tiling: an engine "tile"
is one Pallas grid cell; the east->west chain is the K-tile grid dimension.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.latency_model import Device, PE_PER_BRAM, TABLE_IV, U55

BRAMS_PER_TILE = 12       # Table III: one GEMV tile consumes 12 BRAM36
BLOCK_GRID = (12, 2)      # PIM blocks per tile (Fig. 2b)
PES_PER_BLOCK = 16        # one BRAM18 column group
PES_PER_TILE = BRAMS_PER_TILE * PE_PER_BRAM  # 384
PE_REGFILE_BITS = 1024    # usable bit-column depth per PE


@dataclass(frozen=True)
class TileArrayGeometry:
    device: Device

    @property
    def n_tiles(self) -> int:
        return self.device.brams // BRAMS_PER_TILE

    @property
    def n_pes(self) -> int:
        return self.n_tiles * PES_PER_TILE

    @property
    def pe_rows(self) -> int:
        # tiles stack vertically (column shift-register readout), PE rows
        # per tile = block-grid rows.
        return BLOCK_GRID[0] * max(1, int(math.sqrt(self.n_tiles)))

    @property
    def pe_cols(self) -> int:
        return self.n_pes // self.pe_rows

    def max_square_gemv(self, bits: int = 8) -> int:
        """Largest D for a D x D GEMV with weights resident (100% BRAM-as-PIM).

        Each PE stores its slice of weights + activations + workspace in a
        PE_REGFILE_BITS bit column.
        """
        workspace = 2 * (2 * bits + 8)
        elems_per_pe = (PE_REGFILE_BITS - workspace) // (2 * bits)
        capacity = self.n_pes * elems_per_pe
        return int(math.floor(math.sqrt(capacity)))

    def occupancy(self, m: int, k: int, bits: int = 8) -> float:
        """Fraction of PE weight capacity used by an m x k matrix."""
        workspace = 2 * (2 * bits + 8)
        elems_per_pe = (PE_REGFILE_BITS - workspace) // (2 * bits)
        return min(1.0, (m * k) / (self.n_pes * elems_per_pe))


def u55_geometry() -> TileArrayGeometry:
    return TileArrayGeometry(U55)


def all_geometries():
    return {d.short_id: TileArrayGeometry(d) for d in TABLE_IV}
