"""Executable model of the IMAGine GEMV tile (paper Fig. 2b / Fig. 3).

This is a *block-level, cycle-counted* functional simulator:

  * the PE array state is held as numpy arrays (each PE = one bit-serial
    column of a PiCaSO-IM block; the simulator applies whole-array SIMD
    semantics, which is exactly what the broadcast fanout tree does);
  * the controller FSM walks an :mod:`repro.core.isa` program, dispatching
    each instruction to the single-cycle or the multicycle driver and
    charging cycles from :class:`CycleModel` — the same model
    ``latency_model`` uses analytically, so the two are cross-validated in
    tests;
  * results are exact integer GEMV values, compared bit-for-bit against
    ``W @ x`` and against the JAX engine.

The cycle constants model a radix-2 bit-serial PE with read-modify-write
BRAM access (4 cycles per bit-op during multiply, 2 per bit during adds),
calibrated so the engine's implied peak throughput on the U55 (64K PEs @
737 MHz) reproduces the paper's "up to 0.33 TOPS at 8-bit" within a few
per-cent.  Radix 2 retires two multiplier bits per pass (the paper's
"slice4" / Booth radix-4 variant).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.isa import (
    Instr,
    MAX_ELEMS,
    Op,
    REG_ACC,
    REG_TMP,
    REG_W_BASE,
    REG_X_BASE,
    SINGLE_CYCLE,
    assemble_gemv,
)


@dataclass(frozen=True)
class CycleModel:
    """Cycle cost of each multicycle operation (radix-2 defaults).

    ``radix_bits``: multiplier bits retired per pass (1 = radix-2 bit-serial,
    2 = radix-4 Booth = the paper's "slice4" variant).
    """

    precision: int = 8          # operand bit width p
    acc_width: int = 24         # accumulator width (2p + headroom)
    radix_bits: int = 1
    rmw_mult: int = 4           # BRAM read-modify-write cycles per mult bit-op
    rmw_add: int = 2            # cycles per bit during add/sub/mov
    issue: int = 2              # multicycle driver: param load + dispatch

    def mult(self) -> int:
        p = self.precision
        passes = (p + self.radix_bits - 1) // self.radix_bits
        return self.rmw_mult * passes * p + self.issue

    def add(self, width: Optional[int] = None) -> int:
        w = width or self.acc_width
        return self.rmw_add * w + self.issue

    def mac(self) -> int:
        # multiply + accumulate into [ptr]; data movement overlapped via the
        # third (pointer) address, so only the 2p-bit product add is exposed
        # (the carry into the high accumulator bits is overlapped with the
        # next multiply's first pass).
        return self.mult() + self.add(2 * self.precision)

    def mov(self) -> int:
        return self.rmw_add * self.precision + self.issue

    def accum(self, n_cols: int) -> int:
        # pipelined east->west systolic sweep: one hop per column plus the
        # bit-serial drain of the accumulator word.
        return (n_cols - 1) + self.rmw_add * self.acc_width + self.issue

    def single(self) -> int:
        return 1

    def for_instr(self, instr: Instr, n_cols: int) -> int:
        if instr.op in SINGLE_CYCLE:
            return self.single()
        if instr.op == Op.MULT:
            return self.mult()
        if instr.op == Op.MAC:
            return self.mac()
        if instr.op in (Op.ADD, Op.SUB):
            return self.add()
        if instr.op == Op.MOV:
            return self.mov()
        if instr.op == Op.ACCUM:
            return self.accum(n_cols)
        raise ValueError(f"no timing for {instr.op}")


@dataclass
class TileState:
    """PE-array architectural state: (rows, cols) PEs x 64-word regfile."""

    rows: int
    cols: int
    regs: np.ndarray = field(init=False)      # (rows, cols, 64) int64
    ptr: int = 0
    shift_out: List[np.ndarray] = field(default_factory=list)

    def __post_init__(self):
        self.regs = np.zeros((self.rows, self.cols, 64), dtype=np.int64)


class GemvTileController:
    """FSM model: 2-state driver selection + single/multicycle drivers."""

    def __init__(self, rows: int, cols: int, model: Optional[CycleModel] = None):
        self.state = TileState(rows, cols)
        self.model = model or CycleModel()
        self.cycles = 0
        self.instr_count: Dict[Op, int] = {}
        self.halted = False

    # -- host-side data load (through the input registers / fanout tree) ----
    def load_weights(self, w_elems: np.ndarray) -> None:
        """w_elems: (rows, cols, n_elems) integer weight slices."""
        n = w_elems.shape[-1]
        if n > MAX_ELEMS:
            raise ValueError(f"{n} elements exceed PE capacity")
        self.state.regs[:, :, REG_W_BASE : REG_W_BASE + n] = w_elems
        # one LOADV per element row, broadcast by the fanout tree
        self.cycles += n

    def load_activations(self, x_elems: np.ndarray) -> None:
        """x_elems: (cols, n_elems), broadcast down each PE column."""
        n = x_elems.shape[-1]
        self.state.regs[:, :, REG_X_BASE : REG_X_BASE + n] = x_elems[None]
        self.cycles += n

    # -- execution -----------------------------------------------------------
    def execute(self, program: List[Instr]) -> None:
        for instr in program:
            if self.halted:
                raise RuntimeError("execute after HALT")
            self._dispatch(instr)
            self.cycles += self.model.for_instr(instr, self.state.cols)
            self.instr_count[instr.op] = self.instr_count.get(instr.op, 0) + 1

    def _dispatch(self, instr: Instr) -> None:
        regs, ptr = self.state.regs, self.state.ptr
        op = instr.op
        if op == Op.NOP:
            pass
        elif op == Op.SETPTR:
            self.state.ptr = instr.imm
        elif op == Op.LOADV:
            pass  # data path modeled by load_weights/load_activations
        elif op == Op.MOV:
            regs[:, :, instr.rd] = regs[:, :, instr.rs1]
        elif op == Op.ADD:
            regs[:, :, instr.rd] = regs[:, :, instr.rs1] + regs[:, :, instr.rs2]
        elif op == Op.SUB:
            regs[:, :, instr.rd] = regs[:, :, instr.rs1] - regs[:, :, instr.rs2]
        elif op == Op.MULT:
            regs[:, :, instr.rd] = regs[:, :, instr.rs1] * regs[:, :, instr.rs2]
        elif op == Op.MAC:
            regs[:, :, ptr] = regs[:, :, ptr] + (
                regs[:, :, instr.rs1] * regs[:, :, instr.rs2]
            )
        elif op == Op.ACCUM:
            # east->west: partials accumulate into the west-most PE column
            total = regs[:, :, instr.rd].sum(axis=1)
            regs[:, :, instr.rd] = 0
            regs[:, 0, instr.rd] = total
        elif op == Op.SHIFT:
            # column shift register: emit the current west-column word of the
            # oldest pending fold result (modeled as FIFO append).
            self.state.shift_out.append(regs[:, 0, REG_ACC].copy())
        elif op == Op.HALT:
            self.halted = True
        else:
            raise ValueError(f"unknown op {op}")


def run_gemv(
    w: np.ndarray,
    x: np.ndarray,
    rows: int = 16,
    cols: int = 8,
    model: Optional[CycleModel] = None,
) -> "GemvResult":
    """Run an exact integer GEMV ``y = w @ x`` on the tile model.

    ``w``: (M, K) integers, ``x``: (K,) integers.  The matrix is folded over
    the PE grid: matrix row ``i`` lives on PE row ``i % rows`` of fold
    ``i // rows``; row elements are split contiguously across PE columns.
    """
    m, k = w.shape
    ctrl = GemvTileController(rows, cols, model)
    elems = -(-k // cols)  # per-PE slice length
    if elems > MAX_ELEMS:
        raise ValueError(
            f"K={k} over {cols} columns needs {elems} elems/PE > {MAX_ELEMS}"
        )
    folds = -(-m // rows)
    xp = np.zeros((cols, elems), dtype=np.int64)
    for c in range(cols):
        seg = x[c * elems : (c + 1) * elems]
        xp[c, : len(seg)] = seg
    ctrl.load_activations(xp)

    y = np.zeros(m, dtype=np.int64)
    total_instrs = 0
    for f in range(folds):
        wp = np.zeros((rows, cols, elems), dtype=np.int64)
        for r in range(rows):
            i = f * rows + r
            if i >= m:
                break
            for c in range(cols):
                seg = w[i, c * elems : (c + 1) * elems]
                wp[r, c, : len(seg)] = seg
        ctrl.load_weights(wp)
        prog = assemble_gemv(elems, 1, rows)
        ctrl.execute(prog[:-1])  # defer HALT until all folds are done
        total_instrs += len(prog) - 1
        out = np.stack(ctrl.state.shift_out, axis=0)  # (rows, rows) shifts
        ctrl.state.shift_out.clear()
        take = min(rows, m - f * rows)
        y[f * rows : f * rows + take] = out[-1][:take]
    ctrl.execute([Instr(Op.HALT)])
    return GemvResult(y=y, cycles=ctrl.cycles, instrs=total_instrs + 1, ctrl=ctrl)


@dataclass
class GemvResult:
    y: np.ndarray
    cycles: int
    instrs: int
    ctrl: GemvTileController
