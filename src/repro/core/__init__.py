"""The paper's contribution: the IMAGine GEMV engine.

Two halves:
  * paper-faithful FPGA model — ``isa``, ``controller``, ``tile_array``,
    ``latency_model`` reproduce the 30-bit ISA, the tile-controller FSM and
    the analytical clock/latency/scaling results of the paper;
  * TPU-native engine — ``quantize``, ``bitplane``, ``gemv_engine`` implement
    the same bit-serial GEMV semantics as a JAX/Pallas engine used on the
    decode path of every assigned architecture.
"""

from repro.core.bitplane import pack_weights, to_bitplanes, unpack_weights
from repro.core.gemv_engine import QuantizedLinear, gemv, quantize_linear
from repro.core.quantize import dequantize, quantize_symmetric

__all__ = [
    "pack_weights",
    "unpack_weights",
    "to_bitplanes",
    "QuantizedLinear",
    "gemv",
    "quantize_linear",
    "dequantize",
    "quantize_symmetric",
]
