"""Symmetric per-output-channel integer quantization.

The IMAGine engine stores stationary weights as b-bit signed integers
(two's complement) — exactly what the FPGA overlay keeps in BRAM.  Scales
are per output channel (one per PE column in paper terms).
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_symmetric(w: jnp.ndarray, bits: int, axis: int = 0):
    """Quantize ``w`` to signed ``bits``-bit integers, symmetric, per-channel.

    Args:
      w: float weight matrix.
      bits: 2, 4 or 8.
      axis: the *reduction* axis (input features); scales are computed over
        it so each output channel owns one scale.

    Returns:
      (q, scale): ``q`` int8 holding values in [-(2^{b-1}-1), 2^{b-1}-1]
      (note: the most negative code is unused, keeping the range symmetric,
      which is what bit-serial sign handling on the overlay assumes), and
      ``scale`` float32 broadcastable against ``w``.
    """
    if bits not in (2, 4, 8):
        raise ValueError(f"bits must be 2/4/8, got {bits}")
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
