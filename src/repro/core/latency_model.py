"""Analytical frequency / scaling / latency models (paper Tables I, IV, V;
Figs. 4 and 6).

Everything the paper *measures* is encoded here as data + closed-form cycle
models so the benchmark scripts can regenerate each table/figure and the
test-suite can assert the paper's headline claims:

  * Table I   — Fmax of prior FPGA-PIM designs vs BRAM Fmax
  * Table IV  — representative devices, 100%-BRAM PE counts (Fig. 4)
  * Table V   — system frequency + utilization of GEMV/GEMM engines
  * Fig. 6    — GEMV cycle latency & execution time vs matrix dimension
  * §V-C      — 737 MHz, 64K PEs, 0.33 TOPS @ 8-bit, faster than TPU v1/v2

Cycle models follow the modeling approach of BRAMAC [12] (which the paper
itself adopts for CCB/CoMeFa/SPAR-2): per-design MAC and reduction costs as
functions of operand precision and matrix dimension.  Constants are chosen
from the cited papers' descriptions; they are modeling assumptions, recorded
here once and used consistently by benchmarks and tests.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List

from repro.core.controller import CycleModel

# ---------------------------------------------------------------------------
# Table I — maximum frequency (MHz) of existing FPGA-PIM designs
# ---------------------------------------------------------------------------

TABLE_I = {
    # name: (type, device, f_bram, f_pim, f_sys)  (None = not reported)
    "CCB": ("custom", "Stratix 10", 1000, 624, 455),
    "CoMeFa-A": ("custom", "Arria 10", 730, 294, 288),
    "CoMeFa-D": ("custom", "Arria 10", 730, 588, 292),
    "BRAMAC-2SA": ("custom", "Arria 10", 730, 586, None),
    "BRAMAC-1DA": ("custom", "Arria 10", 730, 500, None),
    "M4BRAM": ("custom", "Arria 10", 730, 553, None),
    "SPAR-2": ("overlay", "UltraScale+", 737, 445, 200),
    "PiCaSO": ("overlay", "UltraScale+", 737, 737, None),
}

# ---------------------------------------------------------------------------
# Table IV — representative Virtex-7 / UltraScale+ devices
# ---------------------------------------------------------------------------

PE_PER_BRAM = 32  # PiCaSO-IM: 16 bit-serial PEs per BRAM18 = 32 per BRAM36


@dataclass(frozen=True)
class Device:
    part: str
    tech: str          # "V7" | "US+"
    brams: int         # BRAM36 count
    lut_bram_ratio: int
    short_id: str

    @property
    def max_pes(self) -> int:
        """PE count at 100% BRAM-as-PIM utilization (Table IV 'Max PE#')."""
        return self.brams * PE_PER_BRAM


TABLE_IV: List[Device] = [
    Device("xcu55c-fsvh-2", "US+", 2016, 646, "U55"),
    Device("xc7vx330tffg-2", "V7", 750, 272, "V7-a"),
    Device("xc7vx485tffg-2", "V7", 1030, 295, "V7-b"),
    Device("xc7v2000tfhg-2", "V7", 1292, 946, "V7-c"),
    Device("xc7vx1140tflg-2", "V7", 1880, 379, "V7-d"),
    Device("xcvu3p-ffvc-3", "US+", 720, 547, "US-a"),
    Device("xcvu23p-vsva-3", "US+", 2112, 488, "US-b"),
    Device("xcvu19p-fsvb-2", "US+", 2160, 1892, "US-c"),
    Device("xcvu29p-figd-3", "US+", 2688, 643, "US-d"),
]

U55 = TABLE_IV[0]

# ---------------------------------------------------------------------------
# Table V — utilization and system frequency of PIM GEMV/GEMM engines
# ---------------------------------------------------------------------------

TABLE_V = {
    # name: (lut%, ff%, dsp%, bram%, f_sys MHz)
    "RIMA-Fast": (60.0, None, 50.0, 55.0, 455),
    "RIMA-Large": (89.0, None, 50.0, 93.0, 278),
    "CCB GEMV": (27.9, None, 90.1, 91.8, 231),
    "CoMeFa-A GEMV": (27.9, None, 90.1, 91.8, 242),
    "CoMeFa-D GEMM": (25.5, None, 92.4, 86.7, 267),
    "SPAR-2 (US+)": (11.3, 2.4, 0.0, 14.5, 200),
    "SPAR-2 (V7)": (28.5, 7.0, 0.0, 30.4, 130),
    "IMAGine": (35.6, 24.8, 0.0, 100.0, 737),
    "IMAGine-CB": (10.1, 7.2, 0.0, 100.0, 737),
}

IMAGINE_FSYS_MHZ = 737.0
TPU_V1_MHZ = 700.0
TPU_V1_PES = 65536  # 256x256 systolic MACs
TPU_V2_PES = 16384  # 128x128 per MXU
HANGUANG800_MHZ = 700.0

# Table III — GEMV tile component utilization (for benchmarks/table3)
TABLE_III = {
    # component: (lut, ff, dsp, bram, freq MHz)
    "controller": (167, 155, 0, 0.0, 890),
    "fanout": (0, 615, 0, 0.0, 890),
    "pim_array": (2736, 3096, 0, 12.0, 737),
    "tile": (2903, 3866, 0, 12.0, 737),
}


# ---------------------------------------------------------------------------
# Fig. 6 — GEMV cycle-latency models
# ---------------------------------------------------------------------------
# All models give cycles for y = W @ x with W of shape (dim, dim) at operand
# precision p, on a full-device PE array of the design's evaluation platform.


def _fold_geometry(dim: int, n_pes: int, elems_per_pe: int):
    """Shared helper: rows x cols PE grid covering a dim x dim matrix."""
    cols = max(1, math.ceil(dim / elems_per_pe))
    rows = max(1, n_pes // cols)
    folds = math.ceil(dim / rows)
    return rows, cols, folds


def imagine_cycles(dim: int, p: int = 8, n_pes: int = U55.max_pes,
                   radix_bits: int = 1) -> int:
    """IMAGine (radix_bits=1) / IMAGine-slice4 (radix_bits=2, plus a 4-bit
    sliced accumulation network halving the ACCUM drain)."""
    cm = CycleModel(precision=p, acc_width=2 * p + 8, radix_bits=radix_bits)
    elems = MAX_ELEMS_FIG6
    rows, cols, folds = _fold_geometry(dim, n_pes, elems)
    per_pe_elems = math.ceil(dim / cols)
    accum = cm.accum(cols)
    if radix_bits >= 2:  # slice4: 4-bit sliced accumulation network
        accum = (cols - 1) + cm.rmw_add * cm.acc_width // 4 + cm.issue
    per_fold = 2 + per_pe_elems * cm.mac() + accum
    readout = min(dim, rows)
    return folds * per_fold + readout + dim  # + activation broadcast


MAX_ELEMS_FIG6 = 30


# CCB/CoMeFa GEMV engines were evaluated on an Arria 10 GX900 (Table V:
# 91.8% of 2423 M20K blocks, 40 bitline-PEs per block).
CCB_GEMV_PES = int(0.918 * 2423 * 40)


def ccb_cycles(dim: int, p: int = 8, n_pes: int = CCB_GEMV_PES) -> int:
    """CCB/CoMeFa-style: dual-port operand fetch (2 cycles/bit-op) and a
    popcount-based pipelined adder-tree reduction (log-depth, amortized)."""
    mult = 2 * p * p + p
    rows, cols, folds = _fold_geometry(dim, n_pes, MAX_ELEMS_FIG6)
    per_pe_elems = math.ceil(dim / cols)
    reduce_tree = (2 * p + math.ceil(math.log2(max(cols, 2)))) * 2
    per_fold = per_pe_elems * (mult + 2 * p) + reduce_tree
    return folds * per_fold + dim


def comefa_cycles(dim: int, p: int = 8) -> int:
    return ccb_cycles(dim, p)  # same family; frequency differs (Table V)


def spar2_cycles(dim: int, p: int = 8, n_pes: int = 10_000) -> int:
    """SPAR-2: same bit-serial MAC family but a NEWS-grid reduction whose
    latency grows ~linearly with matrix dimension (paper §V-E)."""
    cm = CycleModel(precision=p, acc_width=2 * p + 8, radix_bits=1)
    rows, cols, folds = _fold_geometry(dim, n_pes, MAX_ELEMS_FIG6)
    per_pe_elems = math.ceil(dim / cols)
    news = cols * (2 * p + 4)  # hop-by-hop, not pipelined
    per_fold = per_pe_elems * cm.mac() + news
    return folds * per_fold + dim


def bramac_cycles(dim: int, p: int = 8, n_pes: int = CCB_GEMV_PES) -> int:
    """BRAMAC MAC2: hybrid bit-serial/bit-parallel — MAC latency linear in p
    (the paper: 'BRAMAC's MAC latency grows linearly with operand bit-width')."""
    mac = 6 * p + 8
    rows, cols, folds = _fold_geometry(dim, n_pes, MAX_ELEMS_FIG6)
    per_pe_elems = math.ceil(dim / cols)
    reduce_tree = (2 * p + math.ceil(math.log2(max(cols, 2)))) * 2
    per_fold = per_pe_elems * mac + reduce_tree
    return folds * per_fold + dim


# design name -> (cycles_fn, f_sys MHz or None)
FIG6_DESIGNS: Dict[str, tuple] = {
    "IMAGine": (lambda d, p: imagine_cycles(d, p, radix_bits=1), 737.0),
    "IMAGine-slice4": (lambda d, p: imagine_cycles(d, p, radix_bits=2), 737.0),
    "CCB": (ccb_cycles, 231.0),
    "CoMeFa": (comefa_cycles, 242.0),
    "SPAR-2": (spar2_cycles, 200.0),
    "BRAMAC": (bramac_cycles, None),  # no system frequency reported
}


def execution_time_us(design: str, dim: int, p: int = 8) -> float:
    fn, f_mhz = FIG6_DESIGNS[design]
    if f_mhz is None:
        raise ValueError(f"{design} reported no system frequency")
    return fn(dim, p) / f_mhz  # cycles / (MHz) = microseconds


# ---------------------------------------------------------------------------
# §V-C headline numbers
# ---------------------------------------------------------------------------


def peak_tops(p: int = 8, n_pes: int = U55.max_pes, f_mhz: float = IMAGINE_FSYS_MHZ,
              radix_bits: int = 1) -> float:
    """Peak 2*MAC/s in TOPS at precision p (TPU convention: 1 MAC = 2 ops)."""
    cm = CycleModel(precision=p, radix_bits=radix_bits)
    return 2.0 * n_pes * f_mhz * 1e6 / cm.mac() / 1e12


def clock_speedup_range() -> tuple:
    """IMAGine system clock vs prior *at-scale custom-PIM* GEMV/GEMM engines
    (RIMA-Large, CCB, CoMeFa-A/D — the designs using >85% of BRAMs).  This is
    the comparison set that yields the paper's '2.65x - 3.2x faster clock'
    claim: 737/278 = 2.65 (RIMA-Large) up to 737/231 = 3.19 (CCB GEMV).
    SPAR-2 is beaten by even more (3.7x/5.7x) and RIMA-Fast trades scale
    (55% BRAM) for clock, so neither bounds the quoted range."""
    at_scale = ["RIMA-Large", "CCB GEMV", "CoMeFa-A GEMV", "CoMeFa-D GEMM"]
    ratios = [IMAGINE_FSYS_MHZ / TABLE_V[k][4] for k in at_scale]
    return min(ratios), max(ratios)
