"""IMAGine's 30-bit instruction set (paper §IV-C, Fig. 3a).

The paper specifies a 30-bit instruction executed by a 2-driver tile
controller (single-cycle + multicycle) but does not publish the bit-level
encoding; the encoding below is our documented model, chosen to fit the
described fields: an opcode, up to two BRAM word addresses (PiCaSO-F exposes
two simultaneous addresses), and an immediate.  The *third* address required
by the accumulation algorithm lives in the pointer register (``SETPTR``),
exactly as §IV-D describes ("we added a pointer register for the third
address").

Layout (30 bits):  ``[opcode:5 | rd:6 | rs1:6 | rs2:6 | imm:7]``

Word addresses index a 64-entry logical register file per PE (one BRAM
column sliced into 16-bit words).
"""

from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum
from typing import List, Tuple

INSTR_BITS = 30
_OPC_BITS, _RD_BITS, _RS_BITS, _IMM_BITS = 5, 6, 6, 7


class Op(IntEnum):
    NOP = 0
    SETPTR = 1   # pointer register <- imm          (single-cycle)
    LOADV = 2    # host writes a vector word        (single-cycle per word)
    MOV = 3      # rd <- rs1                        (multicycle: p bits)
    ADD = 4      # rd <- rs1 + rs2                  (multicycle)
    SUB = 5      # rd <- rs1 - rs2                  (multicycle)
    MULT = 6     # rd <- rs1 * rs2  (bit-serial)    (multicycle)
    MAC = 7      # [ptr] <- [ptr] + rs1 * rs2       (multicycle, 3rd addr via ptr)
    ACCUM = 8    # east->west array accumulation    (multicycle)
    SHIFT = 9    # shift result column up one slot  (single-cycle)
    HALT = 31


SINGLE_CYCLE = {Op.NOP, Op.SETPTR, Op.LOADV, Op.SHIFT, Op.HALT}


@dataclass(frozen=True)
class Instr:
    op: Op
    rd: int = 0
    rs1: int = 0
    rs2: int = 0
    imm: int = 0

    def encode(self) -> int:
        for name, val, bits in (
            ("rd", self.rd, _RD_BITS),
            ("rs1", self.rs1, _RS_BITS),
            ("rs2", self.rs2, _RS_BITS),
            ("imm", self.imm, _IMM_BITS),
        ):
            if not 0 <= val < (1 << bits):
                raise ValueError(f"{name}={val} out of range for {bits} bits")
        word = (
            (int(self.op) << (INSTR_BITS - _OPC_BITS))
            | (self.rd << (_RS_BITS * 2 + _IMM_BITS))
            | (self.rs1 << (_RS_BITS + _IMM_BITS))
            | (self.rs2 << _IMM_BITS)
            | self.imm
        )
        assert word < (1 << INSTR_BITS)
        return word


def decode(word: int) -> Instr:
    if not 0 <= word < (1 << INSTR_BITS):
        raise ValueError(f"not a {INSTR_BITS}-bit word: {word}")
    op = Op((word >> (INSTR_BITS - _OPC_BITS)) & ((1 << _OPC_BITS) - 1))
    rd = (word >> (_RS_BITS * 2 + _IMM_BITS)) & ((1 << _RD_BITS) - 1)
    rs1 = (word >> (_RS_BITS + _IMM_BITS)) & ((1 << _RS_BITS) - 1)
    rs2 = (word >> _IMM_BITS) & ((1 << _RS_BITS) - 1)
    imm = word & ((1 << _IMM_BITS) - 1)
    return Instr(op, rd, rs1, rs2, imm)


# ---------------------------------------------------------------------------
# Register-file convention used by the GEMV program
# ---------------------------------------------------------------------------
# word 0            : accumulator (2p + log2(K) bits wide logically)
# word 1            : multiply scratch
# words 2..2+E      : weight elements (this PE's slice of a matrix row)
# words 34..34+E    : activation elements (broadcast down the PE column)
REG_ACC = 0
REG_TMP = 1
REG_W_BASE = 2
REG_X_BASE = 34
MAX_ELEMS = 30  # per-PE element capacity with this register map


def assemble_gemv(n_elems: int, n_folds: int, out_rows: int) -> List[Instr]:
    """Emit the instruction stream for one tiled GEMV.

    Per fold: clear the accumulator, MAC across the PE's ``n_elems``
    elements (bit-serial multiply-accumulate, third address = accumulator
    via the pointer register), then an east->west ACCUM sweep; finally the
    result column is shifted out one element per cycle.
    """
    if n_elems > MAX_ELEMS:
        raise ValueError(f"n_elems={n_elems} exceeds PE capacity {MAX_ELEMS}")
    prog: List[Instr] = []
    for _ in range(n_folds):
        prog.append(Instr(Op.SETPTR, imm=REG_ACC))
        prog.append(Instr(Op.SUB, rd=REG_ACC, rs1=REG_ACC, rs2=REG_ACC))  # acc = 0
        for e in range(n_elems):
            prog.append(Instr(Op.MAC, rs1=REG_W_BASE + e, rs2=REG_X_BASE + e))
        prog.append(Instr(Op.ACCUM, rd=REG_ACC))
    for _ in range(out_rows):
        prog.append(Instr(Op.SHIFT))
    prog.append(Instr(Op.HALT))
    return prog


def roundtrip(prog: List[Instr]) -> Tuple[List[int], List[Instr]]:
    words = [i.encode() for i in prog]
    return words, [decode(w) for w in words]
