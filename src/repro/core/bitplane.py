"""Bit-plane storage format for the IMAGine engine.

On the FPGA, a b-bit weight lives as b one-bit rows of a BRAM column and the
PE retires one (radix-2) or two (radix-4 Booth, "slice4") bits per pass.  On
TPU the dense analogue is: weights stored as signed b-bit integers packed
into int8 words (b=8: one weight per byte; b=4: two; b=2: four) so the HBM
footprint is exactly b/8 bytes per weight, and the kernel extracts bit-planes
in-register (VREG) with shift/mask — the HBM→VMEM→VREG path mirrors the
paper's BRAM→PE path.

Packing is along the *input-feature* (K) axis, which is the axis the engine
streams east→west.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_weights(q: jnp.ndarray, bits: int, axis: int = 0) -> jnp.ndarray:
    """Pack signed ``bits``-bit integer weights (held in int8) along ``axis``.

    For bits=8 this is the identity.  For bits=4 (2), consecutive pairs
    (quads) along ``axis`` share one int8 byte, low bits first.
    """
    if bits == 8:
        return q.astype(jnp.int8)
    per_byte = 8 // bits
    mask = (1 << bits) - 1
    if q.shape[axis] % per_byte != 0:
        raise ValueError(
            f"axis {axis} size {q.shape[axis]} not divisible by {per_byte}"
        )
    q = jnp.moveaxis(q, axis, 0)
    u = q.astype(jnp.uint8) & mask  # two's-complement truncation to b bits
    u = u.reshape((q.shape[0] // per_byte, per_byte) + q.shape[1:])
    shifts = (jnp.arange(per_byte, dtype=jnp.uint8) * bits).reshape(
        (1, per_byte) + (1,) * (q.ndim - 1)
    )
    word = jnp.sum(
        (u.astype(jnp.uint8) << shifts).astype(jnp.uint8), axis=1, dtype=jnp.uint8
    )
    return jnp.moveaxis(word.astype(jnp.int8), 0, axis)


def unpack_weights(packed: jnp.ndarray, bits: int, axis: int = 0) -> jnp.ndarray:
    """Inverse of :func:`pack_weights`; returns sign-extended int8 values."""
    if bits == 8:
        return packed.astype(jnp.int8)
    per_byte = 8 // bits
    mask = (1 << bits) - 1
    sign_bit = 1 << (bits - 1)
    p = jnp.moveaxis(packed, axis, 0).astype(jnp.uint8)
    shifts = (jnp.arange(per_byte, dtype=jnp.uint8) * bits).reshape(
        (1, per_byte) + (1,) * (p.ndim - 1)
    )
    u = (p[:, None] >> shifts) & mask
    # sign extend: v = (u ^ sign) - sign
    v = (u.astype(jnp.int16) ^ sign_bit) - sign_bit
    v = v.reshape((p.shape[0] * per_byte,) + p.shape[1:])
    return jnp.moveaxis(v.astype(jnp.int8), 0, axis)


def to_bitplanes(q: np.ndarray, bits: int) -> np.ndarray:
    """Explicit bit-plane view (paper Fig. 2 storage): plane b of the two's
    complement code, shape ``(bits,) + q.shape`` with 0/1 entries.

    Used by the FPGA executable model and as the oracle for the bit-serial
    kernels: ``value = -2^{b-1}·plane[b-1] + Σ_{i<b-1} 2^i·plane[i]``.
    """
    q = np.asarray(q)
    u = q.astype(np.int64) & ((1 << bits) - 1)
    planes = np.stack([(u >> b) & 1 for b in range(bits)], axis=0)
    return planes.astype(np.uint8)


def from_bitplanes(planes: np.ndarray, bits: int) -> np.ndarray:
    """Reassemble signed integers from bit-planes (numpy oracle)."""
    weights = np.array([1 << b for b in range(bits - 1)] + [-(1 << (bits - 1))])
    shape = (bits,) + (1,) * (planes.ndim - 1)
    return np.sum(planes.astype(np.int64) * weights.reshape(shape), axis=0)
