"""The IMAGine GEMV engine, TPU-native.

``QuantizedLinear`` is the weight-stationary, bit-packed linear layer used on
the decode (serving) path: weights live as signed b-bit integers packed into
int8 (b/8 bytes per weight in HBM — the memory-roofline win that mirrors the
paper's "PEs scale with memory capacity"), with per-output-channel float
scales.

``gemv`` dispatches between:
  * the Pallas kernel (``repro.kernels.bitplane_gemv``) — the TPU hot path,
    bit-serial over planes with radix 1/2/4 (radix-2 / radix-4-Booth /
    nibble-serial), validated in interpret mode on CPU;
  * a pure-jnp path with identical semantics, used for CPU execution and for
    the 512-device dry-run lowering (Pallas TPU kernels do not lower on the
    CPU backend).

Both paths compute y = scale * (unpacked_int_W @ x) exactly (integer
accumulation is exact in fp32 for b<=8 and K<=2^15 per tile).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_weights, unpack_weights
from repro.core.quantize import quantize_symmetric


class QuantizedLinear(NamedTuple):
    """Weight-stationary quantized linear: y = x @ W (W: in_features x out).

    ``packed``: int8, shape (in_features * bits // 8, out_features) — K-axis
    packed.  ``scale``: float32 (1, out_features).  ``bits``: python int.
    """

    packed: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    in_features: int
    out_features: int


def quantize_linear(w: jnp.ndarray, bits: int = 8) -> QuantizedLinear:
    """Quantize a float (K, N) weight matrix into engine storage format."""
    k, n = w.shape
    q, scale = quantize_symmetric(w, bits, axis=0)
    packed = pack_weights(q, bits, axis=0)
    return QuantizedLinear(packed, scale, bits, k, n)


def dequantize_linear(qlin: QuantizedLinear, dtype=jnp.float32) -> jnp.ndarray:
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)
    return (q.astype(jnp.float32) * qlin.scale).astype(dtype)


# ---------------------------------------------------------------------------
# engine forward
# ---------------------------------------------------------------------------


def gemv(
    qlin: QuantizedLinear,
    x: jnp.ndarray,
    *,
    radix: int = 1,
    use_pallas: bool = False,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = x @ W for engine weights.  ``x``: (..., in_features).

    ``radix`` selects how many weight bits each bit-serial pass retires
    (1 = IMAGine radix-2 baseline, 2 = slice4/Booth-radix-4, 4 = nibble
    pass); semantics are identical, the knob exists so the kernel can be
    swept exactly like the paper sweeps its PE variants.
    """
    if use_pallas:
        from repro.kernels.bitplane_gemv import ops as _ops

        return _ops.bitplane_gemv(
            qlin.packed, qlin.scale, x, bits=qlin.bits, radix=radix,
            interpret=interpret, out_dtype=out_dtype,
        )
    return gemv_reference(qlin, x, out_dtype=out_dtype)


def gemv_reference(qlin: QuantizedLinear, x: jnp.ndarray, out_dtype=jnp.float32):
    """Pure-jnp engine path (also the dry-run lowering path).

    Reads the packed int8 weights (b/8 bytes per weight of HBO traffic —
    what the roofline memory term sees), unpacks in-register, and contracts
    at int32->fp32 precision.
    """
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)  # (K, N) int8
    acc = jnp.einsum(
        "...k,kn->...n",
        x.astype(jnp.float32),
        q.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return (acc * qlin.scale).astype(out_dtype)


def gemv_bit_serial_reference(
    qlin: QuantizedLinear, x: jnp.ndarray, radix: int = 1, out_dtype=jnp.float32
):
    """Bit-serial oracle: explicitly walks bit-planes like the FPGA engine.

    y = scale * sum_d  digit_weight_d * (plane_d @ x)

    where planes are ``radix``-bit digits of the two's-complement code, the
    top digit carrying negative weight.  Numerically identical to
    :func:`gemv_reference`; used by kernel tests and the ISA cross-check.
    """
    bits = qlin.bits
    if bits % radix != 0:
        raise ValueError(f"radix {radix} must divide bits {bits}")
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)
    u = q.astype(jnp.int32) & ((1 << bits) - 1)  # two's complement code
    n_digits = bits // radix
    acc = jnp.zeros(x.shape[:-1] + (qlin.out_features,), jnp.float32)
    for d in range(n_digits):
        digit = (u >> (d * radix)) & ((1 << radix) - 1)
        weight = float(1 << (d * radix))
        if d == n_digits - 1:
            # top digit: its MSB is the sign bit of the two's complement code
            sign_bit = (digit >> (radix - 1)) & 1
            digit = digit - (sign_bit << radix)
        partial = jnp.einsum(
            "...k,kn->...n",
            x.astype(jnp.float32),
            digit.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
        acc = acc + weight * partial
    return (acc * qlin.scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# model-integration helper
# ---------------------------------------------------------------------------


def engine_dense(
    w_or_qlin,
    x: jnp.ndarray,
    *,
    engine_bits: int = 0,
    radix: int = 1,
    use_pallas: bool = False,
    out_dtype=None,
):
    """Uniform linear application used by the serving path of every model.

    If ``engine_bits == 0`` (engine disabled) ``w_or_qlin`` is a plain dense
    matrix and this is a straight matmul (the dry-run baseline).  Otherwise
    ``w_or_qlin`` is a :class:`QuantizedLinear` and the IMAGine engine runs.
    """
    if engine_bits == 0:
        w = w_or_qlin
        out_dtype = out_dtype or w.dtype
        return jnp.einsum("...k,kn->...n", x, w).astype(out_dtype)
    out_dtype = out_dtype or x.dtype
    return gemv(w_or_qlin, x, radix=radix, use_pallas=use_pallas,
                out_dtype=out_dtype)
