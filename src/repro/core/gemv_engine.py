"""DEPRECATED legacy surface of the IMAGine GEMV engine.

The engine's real API now lives in :mod:`repro.engine`:
``PackedLinear`` (unified weight pytree), the backend registry, and
``EnginePlan`` (resolved dispatch).  This module keeps the original
entry points alive as thin shims:

  * ``QuantizedLinear`` / ``quantize_linear`` — the old NamedTuple weight
    container (convert with ``repro.engine.as_packed``);
  * ``gemv(..., use_pallas=, interpret=)`` — the old boolean dispatch,
    now mapped onto a one-off ``EnginePlan``;
  * ``engine_dense`` — the old model-integration helper.

``gemv_reference`` and ``gemv_bit_serial_reference`` remain the named
numerical oracles (they are the ``reference`` / ``bit_serial`` backends'
definitions and are still imported by kernel tests and the ISA
cross-check).

Both paths compute y = scale * (unpacked_int_W @ x) exactly (integer
accumulation is exact in fp32 for b<=8 and K<=2^15 per tile).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_weights, unpack_weights
from repro.core.quantize import quantize_symmetric


class QuantizedLinear(NamedTuple):
    """Weight-stationary quantized linear: y = x @ W (W: in_features x out).

    ``packed``: int8, shape (in_features * bits // 8, out_features) — K-axis
    packed.  ``scale``: float32 (1, out_features).  ``bits``: python int.
    """

    packed: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    in_features: int
    out_features: int


def quantize_linear(w: jnp.ndarray, bits: int = 8) -> QuantizedLinear:
    """Quantize a float (K, N) weight matrix into engine storage format."""
    k, n = w.shape
    q, scale = quantize_symmetric(w, bits, axis=0)
    packed = pack_weights(q, bits, axis=0)
    return QuantizedLinear(packed, scale, bits, k, n)


def dequantize_linear(qlin: QuantizedLinear, dtype=jnp.float32) -> jnp.ndarray:
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)
    return (q.astype(jnp.float32) * qlin.scale).astype(dtype)


# ---------------------------------------------------------------------------
# engine forward
# ---------------------------------------------------------------------------


def gemv(
    qlin: QuantizedLinear,
    x: jnp.ndarray,
    *,
    radix: int = 1,
    use_pallas: bool = False,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """DEPRECATED shim — y = x @ W for engine weights via an EnginePlan.

    The old boolean pair maps onto backend names: ``use_pallas=False`` ->
    ``reference``; ``use_pallas=True`` -> ``pallas_interpret`` /
    ``pallas_tpu`` depending on ``interpret``.  New code should resolve a
    plan once (``repro.engine.resolve_plan``) and call ``plan.apply``.
    """
    from repro.engine import EnginePlan, as_packed

    backend = ("pallas_interpret" if interpret else "pallas_tpu") \
        if use_pallas else "reference"
    plan = EnginePlan(backend=backend, bits=qlin.bits, radix=radix)
    return plan.apply(as_packed(qlin), x, out_dtype=out_dtype)


def gemv_reference(qlin: QuantizedLinear, x: jnp.ndarray, out_dtype=jnp.float32):
    """Pure-jnp engine path (also the dry-run lowering path).

    Reads the packed int8 weights (b/8 bytes per weight of HBO traffic —
    what the roofline memory term sees), unpacks in-register, and contracts
    at int32->fp32 precision.
    """
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)  # (K, N) int8
    acc = jnp.einsum(
        "...k,kn->...n",
        x.astype(jnp.float32),
        q.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return (acc * qlin.scale).astype(out_dtype)


def gemv_bit_serial_reference(
    qlin: QuantizedLinear, x: jnp.ndarray, radix: int = 1, out_dtype=jnp.float32
):
    """Bit-serial oracle: explicitly walks bit-planes like the FPGA engine.

    y = scale * sum_d  digit_weight_d * (plane_d @ x)

    where planes are ``radix``-bit digits of the two's-complement code, the
    top digit carrying negative weight.  Numerically identical to
    :func:`gemv_reference`; used by kernel tests and the ISA cross-check.
    """
    bits = qlin.bits
    if bits % radix != 0:
        raise ValueError(f"radix {radix} must divide bits {bits}")
    q = unpack_weights(qlin.packed, qlin.bits, axis=0)
    u = q.astype(jnp.int32) & ((1 << bits) - 1)  # two's complement code
    n_digits = bits // radix
    acc = jnp.zeros(x.shape[:-1] + (qlin.out_features,), jnp.float32)
    for d in range(n_digits):
        digit = (u >> (d * radix)) & ((1 << radix) - 1)
        weight = float(1 << (d * radix))
        if d == n_digits - 1:
            # top digit: its MSB is the sign bit of the two's complement code
            sign_bit = (digit >> (radix - 1)) & 1
            digit = digit - (sign_bit << radix)
        partial = jnp.einsum(
            "...k,kn->...n",
            x.astype(jnp.float32),
            digit.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
        acc = acc + weight * partial
    return (acc * qlin.scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# model-integration helper
# ---------------------------------------------------------------------------


def engine_dense(
    w_or_qlin,
    x: jnp.ndarray,
    *,
    engine_bits: int = 0,
    radix: int = 1,
    use_pallas: bool = False,
    out_dtype=None,
):
    """DEPRECATED shim — use ``repro.models.layers.dense`` with an
    ``EnginePlan`` (or ``plan.apply`` directly).

    If ``engine_bits == 0`` (engine disabled) ``w_or_qlin`` is a plain dense
    matrix and this is a straight matmul (the dry-run baseline).  Otherwise
    ``w_or_qlin`` is a :class:`QuantizedLinear` and the IMAGine engine runs.
    """
    if engine_bits == 0:
        w = w_or_qlin
        out_dtype = out_dtype or w.dtype
        return jnp.einsum("...k,kn->...n", x, w).astype(out_dtype)
    out_dtype = out_dtype or x.dtype
    return gemv(w_or_qlin, x, radix=radix, use_pallas=use_pallas,
                out_dtype=out_dtype)
