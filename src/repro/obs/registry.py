"""Metrics registry: counters, gauges and bounded-bucket histograms.

Host-side, allocation-light instruments for the serving hot path.  An
instrument is identified by ``(name, sorted(labels))``; looking one up
twice returns the same object, so call sites may either cache the handle
(hot loops) or re-look it up (cold paths — a dict get per call).

Design constraints, in order:

* **Bounded state.**  Histograms hold fixed bucket counts (plus sum /
  count / min / max), never raw samples — a million-request run costs
  the same memory as a ten-request run.  Percentiles are estimated by
  linear interpolation inside the owning bucket (error bounded by the
  bucket width; ``tests/test_obs.py`` pins this against the exact
  ``benchmarks.common.percentile``).

* **Cheap observation.**  ``Counter.inc`` / ``Histogram.observe`` are a
  few attribute ops and a ``bisect`` — no locks (the serving loop is
  single-threaded host code, like the scheduler and allocator).

* **Two snapshots.**  :meth:`MetricsRegistry.to_dict` is the structured
  form the benches consume (``BENCH_obs.json`` etc.);
  :meth:`MetricsRegistry.prometheus_text` is the standard exposition
  format (``# TYPE`` headers, ``name{label="v"} value`` lines,
  cumulative ``_bucket{le=...}`` histogram series).

The *disabled* path never reaches this module: when ``repro.obs`` is
off, engines carry the no-op ``NULL_TELEMETRY`` and no registry exists
at all (see ``repro.obs.telemetry``).
"""

from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Tuple

# serve-path latencies span ~100us (a host-side step phase) to ~10s (a
# long request's end-to-end time); buckets are roughly log-spaced so the
# percentile estimate's bucket-width error stays proportional everywhere
DEFAULT_LATENCY_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        self.value += n


class Gauge:
    """A value that goes up and down (pool occupancy, queue depth)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Bounded-bucket histogram with percentile estimation.

    ``bounds`` are the finite upper bucket edges; an implicit ``+Inf``
    bucket catches the tail.  ``counts[i]`` holds observations ``v``
    with ``bounds[i-1] < v <= bounds[i]`` (Prometheus ``le`` semantics).
    """

    __slots__ = ("name", "labels", "bounds", "counts", "sum", "count",
                 "min", "max")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 buckets=DEFAULT_LATENCY_BUCKETS):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.name = name
        self.labels = labels
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # + the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def percentile(self, q: float) -> Optional[float]:
        """Estimate the q-th percentile (q in 0..100) by linear
        interpolation inside the owning bucket.

        The rank convention matches ``benchmarks.common.percentile``
        (``pos = (count - 1) * q / 100`` over the sorted samples), so
        the estimate differs from the exact answer by at most the width
        of the bucket the rank lands in (the observed min/max clamp the
        open-ended first and +Inf buckets).
        """
        if not self.count:
            return None
        target = (self.count - 1) * q / 100.0
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c > target:
                lo = self.min if i == 0 else self.bounds[i - 1]
                hi = self.max if i == len(self.bounds) else self.bounds[i]
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (target - cum + 0.5) / c  # mid-rank within bucket
                return lo + min(max(frac, 0.0), 1.0) * (hi - lo)
            cum += c
        return self.max

    def to_dict(self) -> Dict:
        return {
            "count": self.count,
            "sum": round(self.sum, 9),
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "buckets": {str(b): c
                        for b, c in zip(self.bounds, self.counts)},
            "inf": self.counts[-1],
        }


class MetricsRegistry:
    """One namespace of instruments; the engine owns one per telemetry.

    ``counter`` / ``gauge`` / ``histogram`` create-or-return: the first
    call with a ``(name, labels)`` pair creates the instrument, later
    calls return the same object.  A name is bound to one instrument
    kind — re-registering it as another kind raises.
    """

    def __init__(self):
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._hists: Dict[Tuple, Histogram] = {}
        self._kinds: Dict[str, str] = {}

    def _claim(self, name: str, kind: str) -> None:
        have = self._kinds.setdefault(name, kind)
        if have != kind:
            raise ValueError(
                f"metric {name!r} already registered as a {have}, "
                f"cannot re-register as a {kind}")

    def counter(self, name: str, **labels) -> Counter:
        self._claim(name, "counter")
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter(name, key[1])
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        self._claim(name, "gauge")
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge(name, key[1])
        return g

    def histogram(self, name: str, buckets=DEFAULT_LATENCY_BUCKETS,
                  **labels) -> Histogram:
        self._claim(name, "histogram")
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(name, key[1], buckets)
        return h

    # ------------------------------------------------------------ snapshots
    def to_dict(self) -> Dict:
        """Structured snapshot (the form the benches consume)."""
        return {
            "counters": {
                name + _fmt_labels(lk): c.value
                for (name, lk), c in sorted(self._counters.items())},
            "gauges": {
                name + _fmt_labels(lk): g.value
                for (name, lk), g in sorted(self._gauges.items())},
            "histograms": {
                name + _fmt_labels(lk): h.to_dict()
                for (name, lk), h in sorted(self._hists.items())},
        }

    def prometheus_text(self) -> str:
        """Prometheus exposition-format snapshot."""
        lines: List[str] = []
        typed = set()

        def header(name, kind):
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, lk), c in sorted(self._counters.items()):
            header(name, "counter")
            lines.append(f"{name}{_fmt_labels(lk)} {c.value}")
        for (name, lk), g in sorted(self._gauges.items()):
            header(name, "gauge")
            lines.append(f"{name}{_fmt_labels(lk)} {g.value}")
        for (name, lk), h in sorted(self._hists.items()):
            header(name, "histogram")
            cum = 0
            for b, c in zip(h.bounds, h.counts):
                cum += c
                le = dict(lk)
                le["le"] = repr(b)
                lines.append(
                    f"{name}_bucket{_fmt_labels(_label_key(le))} {cum}")
            le = dict(lk)
            le["le"] = "+Inf"
            lines.append(
                f"{name}_bucket{_fmt_labels(_label_key(le))} {h.count}")
            lines.append(f"{name}_sum{_fmt_labels(lk)} {h.sum}")
            lines.append(f"{name}_count{_fmt_labels(lk)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")
