"""The instrumentation surface the serving stack calls.

One :class:`Telemetry` object bundles the three observability pieces —
metrics registry, per-request span timelines, Chrome tracer — behind a
flat set of ``on_*`` hooks that the engine, scheduler, allocator and
prefix cache invoke at their transition points.  The hooks take plain
values (rids, counts, clock readings), never engine objects, so the obs
package depends on nothing in ``repro.serve``.

:data:`NULL_TELEMETRY` is the disabled path: a singleton with the same
method surface where every hook is ``pass`` and every context manager
is a shared ``nullcontext``.  The serving stack calls hooks
unconditionally; with obs off, each call is one attribute lookup plus a
no-op invocation — no clocks read (``now()`` returns 0.0), no state
mutated anywhere (pinned by ``tests/test_obs.py``).
"""

from __future__ import annotations

import contextlib
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.obs import clock as _clock
from repro.obs import spans
from repro.obs.costs import CostLedger, OpCost
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import RequestTimeline
from repro.obs.trace import (
    CACHE_TID,
    ENGINE_TID,
    MEM_TID,
    PAGES_TID,
    SCHED_TID,
    ChromeTracer,
)

try:  # optional: align host spans with XLA device profiles
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax always present in-container
    _TraceAnnotation = None

_NULLCTX = contextlib.nullcontext()


class Telemetry:
    """Live instrumentation: registry + timelines + (optional) tracer.

    ``clock`` is injectable (defaults to the serve-path clock) so tests
    drive every timestamp manually.  ``trace=False`` keeps the metrics
    and timelines but skips Chrome-event collection (the overhead-bench
    "metrics-on" configuration); ``jax_annotations=True`` additionally
    wraps prefill/decode dispatch in ``jax.profiler.TraceAnnotation``
    scopes.  Finished timelines are kept in a bounded deque
    (``max_timelines``) so week-long runs do not grow host memory.
    """

    enabled = True

    def __init__(self, clock=None, *, trace: bool = True,
                 jax_annotations: bool = False, max_timelines: int = 1024):
        self.clock = clock or _clock.now
        self.registry = MetricsRegistry()
        self.tracer = ChromeTracer(self.clock) if trace else None
        self._jax_ann = jax_annotations and _TraceAnnotation is not None
        self.timelines: Dict[int, RequestTimeline] = {}
        self._finished: Deque[int] = deque()
        self._max_timelines = max_timelines
        self._step_n = 0
        self._n_slots = 0
        self.costs = CostLedger()
        self._last_pages = (0, 0, 0)  # (free, cached, evictable)
        # per-op (flops, bytes) counter pairs, resolved once: on_costs
        # runs on every prefill/decode dispatch and labeled registry
        # lookups are the hot part of the charge
        self._cost_counters: Dict[str, tuple] = {}
        # hot-path instruments resolved once: the per-token and per-step
        # hooks fire hundreds of times per serve and the create-or-return
        # registry lookup (label-key build + dict probes) costs more than
        # the inc/observe itself
        reg = self.registry
        self._c_tokens = reg.counter("serve_tokens_generated_total")
        self._c_steps = reg.counter("serve_steps_total")
        self._c_prefill_tokens = reg.counter("serve_prefill_tokens_total")
        self._h_step = reg.histogram("serve_step_s")
        self._h_tpot = reg.histogram("serve_tpot_s")
        self._h_ttft = reg.histogram("serve_ttft_s")
        self._h_prefill = reg.histogram("serve_prefill_chunk_s")
        self._h_decode = reg.histogram("serve_decode_step_s")
        self._g_pages = (reg.gauge("pages_free"), reg.gauge("pages_cached"),
                        reg.gauge("pages_evictable"))

    # ------------------------------------------------------------ plumbing
    def now(self) -> float:
        return self.clock()

    def attach_engine(self, n_slots: int, mode: str) -> None:
        """Label the trace tracks once the engine geometry is known."""
        self._n_slots = n_slots
        tr = self.tracer
        if tr is None:
            return
        tr.thread_name(ENGINE_TID, f"engine.step ({mode})")
        for s in range(n_slots):
            tr.thread_name(1 + s, f"lane {s}")
        tr.thread_name(SCHED_TID, "scheduler")
        tr.thread_name(CACHE_TID, "prefix-cache")
        tr.thread_name(PAGES_TID, "pages")
        tr.thread_name(MEM_TID, "memory")

    def _timeline(self, rid: int) -> Optional[RequestTimeline]:
        return self.timelines.get(rid)

    def _finish(self, rid: int) -> None:
        self._finished.append(rid)
        while len(self.timelines) > self._max_timelines and self._finished:
            self.timelines.pop(self._finished.popleft(), None)

    # --------------------------------------------------------- step framing
    def step_begin(self) -> None:
        self._step_n += 1
        if self.tracer is not None:
            self.tracer.begin(ENGINE_TID, "step",
                              args={"n": self._step_n})

    def step_end(self, t0: float) -> None:
        t1 = self.clock()
        self._c_steps.inc()
        self._h_step.observe(t1 - t0)
        if self.tracer is not None:
            # sample pool occupancy into the "memory" track once per step
            free, cached, evictable = self._last_pages
            self.tracer.counter(MEM_TID, "memory",
                                {"free": free, "cached": cached,
                                 "evictable": evictable})
            self.tracer.end(ENGINE_TID, "step", t=t1)

    def phase(self, name: str):
        """Span a step phase (admit/prefill/decode) on the engine track."""
        if self.tracer is None:
            return _NULLCTX
        return self._phase_ctx(name)

    @contextlib.contextmanager
    def _phase_ctx(self, name: str):
        self.tracer.begin(ENGINE_TID, name)
        try:
            yield
        finally:
            self.tracer.end(ENGINE_TID, name)

    def annotate(self, name: str):
        """``jax.profiler.TraceAnnotation`` scope (no-op unless enabled)."""
        if self._jax_ann:
            return _TraceAnnotation(name)
        return _NULLCTX

    # ----------------------------------------------------- request lifecycle
    def on_submit(self, rid: int, prompt_len: int, t: float) -> None:
        self.registry.counter("serve_requests_submitted_total").inc()
        self.registry.counter("serve_prompt_tokens_total").inc(prompt_len)
        self.timelines[rid] = RequestTimeline(rid, t)

    def on_shed(self, reason: str) -> None:
        # refused before a Request exists: no rid, no timeline — count by
        # reason and mark the scheduler track
        self.registry.counter("serve_requests_shed_total",
                              reason=reason).inc()
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "shed", args={"reason": reason})

    def on_admit(self, rid: int, slot: int, cached_tokens: int) -> None:
        t = self.clock()
        self.registry.counter("serve_admissions_total").inc()
        tl = self._timeline(rid)
        if tl is not None:
            if tl.first(spans.ADMITTED) is None:
                self.registry.histogram("serve_queue_wait_s").observe(
                    t - tl.submit_t)
            tl.transition(spans.ADMITTED, t)
            tl.transition(spans.PREFILLING, t)
            tl.cached_tokens = max(tl.cached_tokens, cached_tokens)
        if self.tracer is not None:
            self.tracer.instant(
                SCHED_TID, "admit",
                args={"rid": rid, "slot": slot,
                      "cached_tokens": cached_tokens})

    def on_preempt(self, rid: int, slot: int) -> None:
        t = self.clock()
        self.registry.counter("serve_preemptions_total").inc()
        tl = self._timeline(rid)
        if tl is not None:
            tl.transition(spans.PREEMPTED, t)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "preempt",
                                args={"rid": rid, "slot": slot})

    def on_prefill(self, lanes: List[Tuple[int, int, int]],
                   t0: float) -> None:
        """One batched chunked-prefill dispatch landed.

        ``lanes``: ``(slot, rid, n_tokens)`` per participating lane;
        ``t0``: clock reading just before dispatch.
        """
        t1 = self.clock()
        n_total = sum(n for _, _, n in lanes)
        self._c_prefill_tokens.inc(n_total)
        self._h_prefill.observe(t1 - t0)
        for slot, rid, n in lanes:
            tl = self._timeline(rid)
            if tl is not None:
                tl.prefill_spans.append((t0, t1, n))
            if self.tracer is not None:
                self.tracer.complete(1 + slot, "prefill", t0, t1,
                                     args={"rid": rid, "tokens": n})

    def on_decode(self, lanes: List[Tuple[int, int]], t0: float) -> None:
        """One batched decode-step dispatch landed (``(slot, rid)``)."""
        t1 = self.clock()
        self._h_decode.observe(t1 - t0)
        if self.tracer is not None:
            for slot, rid in lanes:
                self.tracer.complete(1 + slot, "decode", t0, t1,
                                     args={"rid": rid})

    def on_first_token(self, rid: int, ttft_s: float, t: float) -> None:
        self._h_ttft.observe(ttft_s)
        self._c_tokens.inc()
        tl = self._timeline(rid)
        if tl is not None:
            tl.transition(spans.DECODING, t)
            tl.token(t)

    def on_token(self, rid: int, t: float) -> None:
        self._c_tokens.inc()
        tl = self._timeline(rid)
        if tl is not None:
            if tl.last_token_t is not None:
                self._h_tpot.observe(t - tl.last_token_t)
            tl.token(t)

    def on_retire(self, rid: int, reason: str, n_out: int) -> None:
        t = self.clock()
        self.registry.counter("serve_requests_retired_total",
                              reason=reason).inc()
        tl = self._timeline(rid)
        if tl is not None:
            tl.transition(spans.RETIRED, t)
            self.registry.histogram("serve_e2e_s").observe(t - tl.submit_t)
            self._finish(rid)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "retire",
                                args={"rid": rid, "tokens": n_out})

    def on_cancel(self, rid: int, reason: str) -> None:
        t = self.clock()
        self.registry.counter("serve_requests_cancelled_total",
                              reason=reason).inc()
        tl = self._timeline(rid)
        if tl is not None:
            tl.transition(spans.TIMED_OUT if reason == "timed_out"
                          else spans.CANCELLED, t)
            self._finish(rid)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "cancel",
                                args={"rid": rid, "reason": reason})

    # --------------------------------------------------- faults / robustness
    def on_fault(self, rid: int, kind: str) -> None:
        """A per-request fault was detected (``kind``: ``step_fault`` /
        ``nan_logits``) — before the retry-vs-quarantine decision."""
        self.registry.counter("serve_faults_total", kind=kind).inc()
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "fault",
                                args={"rid": rid, "kind": kind})

    def on_retry(self, rid: int, kind: str, attempt: int) -> None:
        """A faulted request was requeued for a recompute-style retry."""
        t = self.clock()
        self.registry.counter("serve_retries_total", kind=kind).inc()
        # everything charged to the request so far will be recomputed
        self.costs.mark_retry(rid)
        tl = self._timeline(rid)
        if tl is not None:
            # like preemption, a retry loops the request back to QUEUED
            tl.transition(spans.PREEMPTED, t)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "retry",
                                args={"rid": rid, "kind": kind,
                                      "attempt": attempt})

    def on_quarantine(self, rid: int, kind: str, n_out: int) -> None:
        """A request exhausted its retry budget and was quarantined
        (``finish_reason="error"``)."""
        t = self.clock()
        self.registry.counter("serve_requests_quarantined_total",
                              kind=kind).inc()
        tl = self._timeline(rid)
        if tl is not None:
            tl.transition(spans.ERRORED, t)
            self._finish(rid)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "quarantine",
                                args={"rid": rid, "kind": kind,
                                      "tokens": n_out})

    def on_audit(self, level: int, ok: bool) -> None:
        """One invariant audit pass completed (``ok=False`` means it
        raised — counted before the AuditError propagates)."""
        self.registry.counter("serve_audits_total").inc()
        if not ok:
            self.registry.counter("serve_audit_failures_total").inc()

    def on_chaos(self, site: str) -> None:
        """The chaos injector fired a fault at ``site``."""
        self.registry.counter("serve_chaos_injected_total", site=site).inc()
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "chaos", args={"site": site})

    def on_frontend_shed(self, reason: str) -> None:
        """The streaming front-end shed a submission (by reason)."""
        self.registry.counter("frontend_shed_total", reason=reason).inc()

    def on_frontend_timeout(self) -> None:
        """The front-end's deadline sweep timed out a live stream."""
        self.registry.counter("frontend_timeouts_total").inc()

    # -------------------------------------------------- prefix cache / pages
    def on_cache_hit(self, rid: int, tokens: int, cow: bool) -> None:
        self.registry.counter("prefix_cache_hits_total").inc()
        self.registry.counter("prefix_cache_hit_tokens_total").inc(tokens)
        if cow:
            self.registry.counter("prefix_cache_cow_forks_total").inc()
        if self.tracer is not None:
            self.tracer.instant(
                CACHE_TID, "hit",
                args={"rid": rid, "tokens": tokens, "cow": cow})

    def on_cache_miss(self, rid: int) -> None:
        self.registry.counter("prefix_cache_misses_total").inc()
        if self.tracer is not None:
            self.tracer.instant(CACHE_TID, "miss", args={"rid": rid})

    def on_cache_insert(self, n_pages: int) -> None:
        self.registry.counter("prefix_cache_inserted_pages_total").inc(
            n_pages)
        if self.tracer is not None:
            self.tracer.instant(CACHE_TID, "insert",
                                args={"pages": n_pages})

    def on_cache_evict(self, n_pages: int) -> None:
        self.registry.counter("prefix_cache_evicted_pages_total").inc(
            n_pages)
        if self.tracer is not None:
            self.tracer.instant(CACHE_TID, "evict",
                                args={"pages": n_pages})

    def on_pages(self, free: int, cached: int = 0,
                 evictable: int = 0) -> None:
        g_free, g_cached, g_evictable = self._g_pages
        g_free.set(free)
        g_cached.set(cached)
        g_evictable.set(evictable)
        self._last_pages = (free, cached, evictable)
        if self.tracer is not None:
            self.tracer.counter(PAGES_TID, "pages",
                                {"free": free, "cached": cached,
                                 "evictable": evictable})

    # ------------------------------------------------------------ cost ledger
    def on_costs(self, op_costs: Dict[str, OpCost], rids=()) -> None:
        """Charge one dispatch's analytic op→cost table (see
        ``repro.obs.costs``) to the ledger, attributed evenly across the
        participating requests, and mirror per-op totals into the
        registry."""
        self.costs.charge(op_costs, rids)
        cache = self._cost_counters
        for op, c in op_costs.items():
            pair = cache.get(op)
            if pair is None:
                pair = cache[op] = (
                    self.registry.counter("serve_cost_flops_total", op=op),
                    self.registry.counter("serve_cost_bytes_total", op=op))
            pair[0].inc(c.flops)
            pair[1].inc(c.bytes)

    # ---------------------------------------------------- snapshot / restore
    def on_restore(self, rids, t: Optional[float] = None) -> None:
        """Requests were restored mid-flight from a snapshot: any stale
        non-terminal timeline for a restored rid is discarded and a fresh
        one opened — restored requests must never dangle in a live span
        state they can no longer leave."""
        t = self.clock() if t is None else t
        rids = list(rids)
        for rid in rids:
            self.registry.counter("serve_requests_restored_total").inc()
            self.timelines[rid] = RequestTimeline(rid, t)
        if self.tracer is not None:
            self.tracer.instant(SCHED_TID, "restore",
                                args={"restored": len(rids)})

    def close_open_timelines(self, state: str = spans.ERRORED,
                             t: Optional[float] = None) -> int:
        """Force every non-terminal timeline into ``state`` (default
        ``errored``).  For engines abandoned mid-flight — killed before a
        snapshot restore, or shut down with requests in flight — so no
        span dangles in a live state.  Returns the number closed."""
        t = self.clock() if t is None else t
        closed = 0
        # _finish may evict over-cap rows from self.timelines: snapshot
        for rid, tl in list(self.timelines.items()):
            if tl.state not in spans.TERMINAL:
                tl.transition(state, t)
                self._finish(rid)
                closed += 1
        return closed

    # -------------------------------------------------------------- outputs
    def snapshot(self) -> Dict:
        """The structured snapshot ``ServeEngine.metrics()`` embeds."""
        states: Dict[str, int] = {}
        for tl in self.timelines.values():
            states[tl.state] = states.get(tl.state, 0) + 1
        return {
            "steps": self._step_n,
            "request_states": states,
            "metrics": self.registry.to_dict(),
            "costs": self.costs.snapshot(),
        }

    def prometheus_text(self) -> str:
        return self.registry.prometheus_text()

    def export_chrome_trace(self, path: str) -> Optional[str]:
        """Write the Chrome trace JSON; None when tracing is off."""
        if self.tracer is None:
            return None
        return self.tracer.write(path)


class NullTelemetry:
    """The disabled path: same surface, every hook a no-op.

    No registry, no tracer, no timelines, no clock reads — constructing
    engines with obs off costs one shared singleton reference, and every
    instrumentation call site costs an attribute lookup plus an empty
    call.  ``tests/test_obs.py`` pins that a serve run through this
    object mutates nothing.
    """

    enabled = False
    registry = None
    tracer = None
    costs = None
    timelines: Dict[int, RequestTimeline] = {}

    def now(self) -> float:
        return 0.0

    def attach_engine(self, n_slots, mode):
        pass

    def step_begin(self):
        pass

    def step_end(self, t0):
        pass

    def phase(self, name):
        return _NULLCTX

    def annotate(self, name):
        return _NULLCTX

    def on_submit(self, rid, prompt_len, t):
        pass

    def on_shed(self, reason):
        pass

    def on_admit(self, rid, slot, cached_tokens):
        pass

    def on_preempt(self, rid, slot):
        pass

    def on_prefill(self, lanes, t0):
        pass

    def on_decode(self, lanes, t0):
        pass

    def on_first_token(self, rid, ttft_s, t):
        pass

    def on_token(self, rid, t):
        pass

    def on_retire(self, rid, reason, n_out):
        pass

    def on_cancel(self, rid, reason):
        pass

    def on_fault(self, rid, kind):
        pass

    def on_retry(self, rid, kind, attempt):
        pass

    def on_quarantine(self, rid, kind, n_out):
        pass

    def on_audit(self, level, ok):
        pass

    def on_chaos(self, site):
        pass

    def on_frontend_shed(self, reason):
        pass

    def on_frontend_timeout(self):
        pass

    def on_cache_hit(self, rid, tokens, cow):
        pass

    def on_cache_miss(self, rid):
        pass

    def on_cache_insert(self, n_pages):
        pass

    def on_cache_evict(self, n_pages):
        pass

    def on_pages(self, free, cached=0, evictable=0):
        pass

    def on_costs(self, op_costs, rids=()):
        pass

    def on_restore(self, rids, t=None):
        pass

    def close_open_timelines(self, state=None, t=None):
        return 0

    def snapshot(self) -> Dict:
        return {}

    def prometheus_text(self) -> str:
        return ""

    def export_chrome_trace(self, path) -> Optional[str]:
        return None


NULL_TELEMETRY = NullTelemetry()
