"""Analytic per-op cost models + the per-step serve-path cost ledger.

This module is the ONE place the repo prices an engine op in FLOPs and
HBM/VMEM bytes:

  * ``gemv_cost`` — a :class:`~repro.engine.PackedLinear` (or dense) apply,
    per (shape, bits, partition): 2·K·N FLOPs/token against ``bits/8``
    bytes/weight of stationary traffic — the paper's roofline argument.
  * ``decode_attn_bytes`` / ``prefill_attn_bytes`` — the gather-vs-fused
    paged-attention traffic models (moved here from
    ``repro.kernels.paged_attention.ops``, which now re-exports them;
    ``attn_bench`` / ``kernel_bench`` import from here).
  * ``decode_attn_flops`` / ``prefill_attn_flops`` — the matching compute
    models over the *padded* logical view the gather backend attends.
  * ``fork_bytes`` / ``kv_write_bytes`` — prefix-cache COW tail-page forks
    and the per-step KV scatter into the page pool.
  * ``decode_step_costs`` / ``prefill_chunk_costs`` — whole-step op→cost
    tables for the paged serve path, built from :func:`linear_specs` (the
    live param tree) or :func:`specs_from_dims` (pure dimensions), and
    cross-validated against ``jax.jit(...).lower().compile()`` via
    ``repro.roofline.analysis.compiled_costs`` in ``tests/test_costs.py``
    (modeled-vs-XLA FLOPs mismatch beyond tolerance is a test failure).
  * :class:`CostLedger` — per-op + per-request accumulation, including
    retry-wasted work from the ``repro.ft`` chaos path; owned by
    ``repro.obs.Telemetry`` and surfaced as
    ``ServeEngine.metrics()["costs"]``.

No serve/model imports here (obs never imports serve): param trees and
model configs are duck-typed.

The elementwise constants below (``RMSNORM_FLOPS_PER_ELEM`` …) price the
non-matmul ops exactly the way ``repro.roofline.hlo_cost`` counts them —
1 FLOP per arithmetic element, transcendentals counted into ``flops`` too
— so the ledger and the HLO analyzer agree on what a "FLOP" is.  They are
small corrections: at serving shapes the dots dominate.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

__all__ = [
    "OpCost",
    "LinearSpec",
    "ModelDims",
    "gemv_cost",
    "decode_attn_bytes",
    "prefill_attn_bytes",
    "decode_attn_flops",
    "prefill_attn_flops",
    "fork_bytes",
    "kv_write_bytes",
    "linear_specs",
    "specs_from_dims",
    "model_dims",
    "decode_step_costs",
    "prefill_chunk_costs",
    "CostLedger",
]


# ---------------------------------------------------------------------------
# cost record
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpCost:
    """FLOPs + HBM/VMEM bytes of one op class for one step."""

    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, other: "OpCost") -> "OpCost":
        return OpCost(self.flops + other.flops, self.bytes + other.bytes)

    def scaled(self, k: float) -> "OpCost":
        return OpCost(self.flops * k, self.bytes * k)


def total_cost(op_costs: Dict[str, OpCost]) -> OpCost:
    t = OpCost()
    for c in op_costs.values():
        t = t + c
    return t


# ---------------------------------------------------------------------------
# GEMV backend apply
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Shape/precision of one linear on the serve path.

    ``stack``: leading multiplicity — scanned layers or stacked experts
    (a spec with ``stack=L`` is applied once per layer per step).
    ``bits``: 0 = dense (float) weights, else the engine's packed width.
    ``weight_itemsize``: bytes/element of the *stored* dense weight
    (2 for bf16 params); ignored when ``bits`` is set.
    """

    name: str
    in_features: int
    out_features: int
    bits: int = 0
    stack: int = 1
    bias: bool = False
    partition: Optional[str] = None
    weight_itemsize: int = 2

    @property
    def key(self) -> str:
        return f"gemv/{self.name}"


def gemv_cost(
    spec: LinearSpec,
    *,
    tokens: int,
    act_itemsize: int = 4,
) -> OpCost:
    """One application of ``spec`` to ``tokens`` activation rows.

    FLOPs: the dot (2·K·N per token) + bias add + per-output-channel
    scale apply on the quantized path.  Bytes: the stationary weight read
    once (``bits/8`` bytes/weight with the engine — the paper's
    memory-capacity scaling — else the dense itemsize), scales + bias,
    and the activation stream in/out.
    """
    k, n = spec.in_features, spec.out_features
    flops = 2.0 * k * n * tokens
    if spec.bias:
        flops += n * tokens
    if spec.bits:
        flops += n * tokens                      # fold per-channel scales
        weight = k * n * (spec.bits / 8.0)
        weight += n * 4                          # f32 scales
    else:
        weight = k * n * spec.weight_itemsize
    if spec.bias:
        weight += n * spec.weight_itemsize
    acts = (k + n) * tokens * act_itemsize
    return OpCost(flops, weight + acts)


# ---------------------------------------------------------------------------
# paged attention: bytes (THE model — kernels/paged_attention re-exports)
# ---------------------------------------------------------------------------


def decode_attn_bytes(
    backend: str,
    *,
    batch: int,
    context: int,
    n_kv_heads: int,
    head_dim: int,
    n_q_heads: int,
    page_size: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> int:
    """Modeled HBM bytes moved by ONE layer's decode-attention read path.

    ``gather`` (the reference backend) materializes the logical KV view
    before attending — per K and per V it pays pool read + view write +
    view read (3× the view), and the int8 path pays the same 3× for each
    scale pool.  The fused kernel (``pallas_interpret`` / ``pallas_tpu``)
    reads each mapped page exactly once per (lane, kv head) and never
    writes an intermediate: 1× the view (+ 1× scales), plus the block
    table itself.  Q read and O write are identical on both paths and
    included for honest totals.
    """
    kv_isz = 1 if kv_bits else act_itemsize
    n_blocks = max(1, math.ceil(context / page_size))
    view = batch * n_blocks * page_size * n_kv_heads * head_dim * kv_isz
    scale_view = (batch * n_blocks * page_size * n_kv_heads * 2
                  if kv_bits else 0)  # bf16 scales
    qo = 2 * batch * n_q_heads * head_dim * act_itemsize  # Q read + O write
    tables = batch * n_blocks * 4                         # int32 block table
    if backend == "gather":
        return 2 * 3 * view + 2 * 3 * scale_view + qo + tables
    if backend in ("pallas_interpret", "pallas_tpu"):
        return 2 * view + 2 * scale_view + qo + tables
    raise ValueError(f"unknown attention backend {backend!r}")


def prefill_attn_bytes(
    backend: str,
    *,
    batch: int,
    chunk: int,
    context: int,
    n_kv_heads: int,
    head_dim: int,
    n_q_heads: int,
    page_size: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> int:
    """Modeled HBM bytes moved by ONE layer's chunked-prefill read path.

    Same accounting as :func:`decode_attn_bytes` with a ``chunk``-token
    query block instead of one token: ``gather`` materializes the full
    logical view (pool read + view write + view read, 3× per K/V and per
    scale pool) before ``attend_dense`` reads it; the fused prefill grid
    streams each mapped page once per (lane, kv head), 1× the view.  The
    chunk's own K/V scatter into the pool is identical on both paths and
    excluded.  Q read and O write cover the whole chunk.
    """
    kv_isz = 1 if kv_bits else act_itemsize
    n_blocks = max(1, math.ceil(context / page_size))
    view = batch * n_blocks * page_size * n_kv_heads * head_dim * kv_isz
    scale_view = (batch * n_blocks * page_size * n_kv_heads * 2
                  if kv_bits else 0)
    qo = 2 * batch * chunk * n_q_heads * head_dim * act_itemsize
    tables = batch * n_blocks * 4
    if backend == "gather":
        return 2 * 3 * view + 2 * 3 * scale_view + qo + tables
    if backend in ("pallas_interpret", "pallas_tpu"):
        return 2 * view + 2 * scale_view + qo + tables
    raise ValueError(f"unknown attention backend {backend!r}")


# ---------------------------------------------------------------------------
# paged attention: FLOPs
# ---------------------------------------------------------------------------

# elementwise pricing constants, matched to repro.roofline.hlo_cost's
# 1-FLOP-per-element accounting (transcendentals count into flops there):
#   softmax over S scores/row: max-reduce + subtract + exp + sum-reduce +
#   divide, plus the causal/window compare + select over the score grid.
SOFTMAX_FLOPS_PER_SCORE = 7.0
#   rms_norm over d elems: square + mean-reduce + rsqrt + 3 muls/adds.
RMSNORM_FLOPS_PER_ELEM = 6.0
#   rope on (H, Dh): angle mul + sin + cos on Dh/2, then 6 mul/adds on
#   each rotated half -> ~4.5 per (head, dim) element.
ROPE_FLOPS_PER_ELEM = 4.5
#   silu(gate)*up (logistic counts 1) or gelu: ~4 per hidden element.
ACT_FLOPS_PER_ELEM = 4.0
#   int8 KV quantize: abs + max-reduce + divide + clamp + round per elem.
QUANT_FLOPS_PER_ELEM = 6.0


def decode_attn_flops(
    *,
    batch: int,
    context: int,
    n_q_heads: int,
    head_dim: int,
    kv_bits: int = 0,
) -> float:
    """ONE layer's decode-attention FLOPs over the padded logical view.

    Both backends compute the same math: q·K over every (padded) logical
    position (masking, not slicing, hides unwritten slots), softmax, p·V.
    ``context`` must be the PADDED view length — ``n_blocks * page_size``
    — which is what the engine actually attends.
    """
    qk_pv = 4.0 * batch * context * n_q_heads * head_dim
    soft = SOFTMAX_FLOPS_PER_SCORE * batch * n_q_heads * context
    if kv_bits:
        soft += 2.0 * batch * n_q_heads * context  # fold k/v scales into p
    return qk_pv + soft


def prefill_attn_flops(
    *,
    batch: int,
    chunk: int,
    context: int,
    n_q_heads: int,
    head_dim: int,
    kv_bits: int = 0,
) -> float:
    """ONE layer's chunked-prefill attention FLOPs (``chunk`` query rows
    against the padded ``context``-long logical view)."""
    qk_pv = 4.0 * batch * chunk * context * n_q_heads * head_dim
    soft = SOFTMAX_FLOPS_PER_SCORE * batch * n_q_heads * chunk * context
    if kv_bits:
        soft += 2.0 * batch * n_q_heads * chunk * context
    return qk_pv + soft


# ---------------------------------------------------------------------------
# page-pool traffic: KV scatter + COW forks
# ---------------------------------------------------------------------------


def kv_write_bytes(
    *,
    tokens: int,
    n_layers: int,
    n_kv_heads: int,
    head_dim: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> float:
    """Scatter of ``tokens`` new K/V entries into the page pool, all
    layers.  XLA aliases the pool buffer, so traffic is the touched
    region read+write (2×), per K and per V, plus int8 scale entries."""
    kv_isz = 1 if kv_bits else act_itemsize
    per_tok = 2 * n_kv_heads * head_dim * kv_isz          # K + V entries
    if kv_bits:
        per_tok += 2 * n_kv_heads * 2                     # bf16 scales
    return 2.0 * tokens * n_layers * per_tok


def kv_write_flops(
    *,
    tokens: int,
    n_layers: int,
    n_kv_heads: int,
    head_dim: int,
    kv_bits: int = 0,
) -> float:
    """Scatter combine fn (1/elem, matching hlo_cost) + int8 quantize."""
    elems = tokens * n_layers * 2 * n_kv_heads * head_dim
    flops = float(elems)
    if kv_bits:
        flops += QUANT_FLOPS_PER_ELEM * elems
    return flops


def fork_bytes(
    *,
    n_layers: int,
    page_size: int,
    n_kv_heads: int,
    head_dim: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> float:
    """One prefix-cache copy-on-write tail-page fork: read + write of a
    whole K page and V page across every layer (plus int8 scale pages) —
    exactly what ``PageAllocator.fork_tail_page`` copies."""
    kv_isz = 1 if kv_bits else act_itemsize
    page = page_size * n_kv_heads * head_dim * kv_isz
    per_layer = 2 * 2 * page                              # rd+wr, K and V
    if kv_bits:
        per_layer += 2 * 2 * page_size * n_kv_heads * 2   # scale pages
    return float(n_layers * per_layer)


# ---------------------------------------------------------------------------
# linear specs: from a live param tree or from pure dimensions
# ---------------------------------------------------------------------------


def _is_packed(p: Any) -> bool:
    return (hasattr(p, "packed") and hasattr(p, "bits")
            and hasattr(p, "in_features"))


def linear_specs(params: Any, prefix: str = "") -> List[LinearSpec]:
    """Walk a (possibly engine-quantized) param tree into LinearSpecs.

    Duck-typed: ``PackedLinear`` leaves carry their own bits/shape;
    ``{"w"[, "bias"]}`` dicts are dense linears (stacked leading axes —
    scanned layers, experts — become ``stack``).  Norm scales, embeddings
    and other raw arrays are not linears and are skipped (they are priced
    in the "other" bucket of the step models).
    """
    out: List[LinearSpec] = []
    if _is_packed(params):
        packed = params.packed
        lead = packed.shape[:-2] if getattr(packed, "ndim", 2) > 2 else ()
        stack = 1
        for d in lead:
            stack *= int(d)
        out.append(LinearSpec(
            name=prefix or "linear",
            in_features=int(params.in_features),
            out_features=int(params.out_features),
            bits=int(params.bits),
            stack=stack,
            bias=getattr(params, "bias", None) is not None,
            partition=getattr(params, "partition", None),
        ))
        return out
    if isinstance(params, dict):
        w = params.get("w")
        if w is not None and getattr(w, "ndim", 0) >= 2 \
                and not isinstance(w, dict):
            stack = 1
            for d in w.shape[:-2]:
                stack *= int(d)
            out.append(LinearSpec(
                name=prefix or "linear",
                in_features=int(w.shape[-2]),
                out_features=int(w.shape[-1]),
                bits=0,
                stack=stack,
                bias="bias" in params,
                weight_itemsize=int(getattr(
                    getattr(w, "dtype", None), "itemsize", 2) or 2),
            ))
            return out
        for key in sorted(params):
            sub = params[key]
            name = f"{prefix}/{key}" if prefix else str(key)
            if isinstance(sub, dict) or _is_packed(sub):
                out.extend(linear_specs(sub, name))
    return out


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """The dimensions the step cost models need, decoupled from
    ``ModelConfig`` (tests can synthesize them directly)."""

    n_layers: int
    d_model: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_gated: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False


def model_dims(cfg: Any) -> ModelDims:
    """Extract :class:`ModelDims` from a ``ModelConfig`` (duck-typed)."""
    return ModelDims(
        n_layers=int(cfg.n_layers),
        d_model=int(cfg.d_model),
        n_q_heads=int(cfg.n_heads),
        n_kv_heads=int(cfg.n_kv_heads),
        head_dim=int(cfg.resolved_head_dim),
        d_ff=int(cfg.d_ff),
        vocab_size=int(cfg.vocab_size),
        mlp_gated=bool(cfg.mlp_gated),
        qkv_bias=bool(cfg.qkv_bias),
        tie_embeddings=bool(cfg.tie_embeddings),
    )


def specs_from_dims(
    dims: ModelDims,
    weight_bits: int = 0,
    *,
    weight_itemsize: int = 2,
) -> List[LinearSpec]:
    """Synthesize the dense-family per-layer linears + LM head from pure
    dimensions — the same shapes ``linear_specs`` recovers from a live
    param tree, so tests and the engine price GEMVs through one path."""
    d, dh = dims.d_model, dims.head_dim
    hq, hkv, l = dims.n_q_heads, dims.n_kv_heads, dims.n_layers

    def spec(name, k, n, stack=l, bias=False, part=None):
        return LinearSpec(name=name, in_features=k, out_features=n,
                          bits=weight_bits, stack=stack, bias=bias,
                          partition=part,
                          weight_itemsize=weight_itemsize)

    out = [
        spec("layers/attn/wq", d, hq * dh, bias=dims.qkv_bias, part="col"),
        spec("layers/attn/wk", d, hkv * dh, bias=dims.qkv_bias, part="col"),
        spec("layers/attn/wv", d, hkv * dh, bias=dims.qkv_bias, part="col"),
        spec("layers/attn/wo", hq * dh, d, part="row"),
        spec("layers/mlp/w_up", d, dims.d_ff, part="col"),
        spec("layers/mlp/w_down", dims.d_ff, d, part="row"),
    ]
    if dims.mlp_gated:
        out.insert(4, spec("layers/mlp/w_gate", d, dims.d_ff, part="col"))
    # tied embeddings still pay the full logits dot; bits never applies to
    # the tied embedding table (quantize_params packs lm_head only).
    out.append(spec("lm_head", d, dims.vocab_size, stack=1,
                    bias=False, part="col")
               if not dims.tie_embeddings else
               LinearSpec(name="lm_head", in_features=d,
                          out_features=dims.vocab_size, bits=0, stack=1,
                          weight_itemsize=weight_itemsize))
    return out


# ---------------------------------------------------------------------------
# whole-step models
# ---------------------------------------------------------------------------


def _with_lm_head(dims: ModelDims, specs, weight_bits: int):
    """Specs for one step, guaranteed to include the logits dot.

    ``linear_specs`` of a tied-embedding param tree finds no ``lm_head``
    leaf (the embedding table is a raw array), but the model still pays
    the full ``d × vocab`` einsum per logit token — synthesize that spec
    from dims so the engine's live-tree tables price it too.
    """
    if specs is None:
        return specs_from_dims(dims, weight_bits)
    specs = list(specs)
    if not any(s.name.endswith("lm_head") for s in specs):
        specs.append(LinearSpec(
            name="lm_head", in_features=dims.d_model,
            out_features=dims.vocab_size, bits=0, stack=1))
    return specs


def _other_decode(dims: ModelDims, tokens: int, logit_tokens: int,
                  act_itemsize: int) -> OpCost:
    """Everything that is neither a GEMV, paged attention, nor the KV
    scatter: embed gather, norms, RoPE, residual adds, MLP activation,
    final norm.  Priced per hlo_cost's 1-FLOP/element convention."""
    d, dh = dims.d_model, dims.head_dim
    hq, hkv, l = dims.n_q_heads, dims.n_kv_heads, dims.n_layers
    per_tok = 0.0
    per_tok += l * 2 * RMSNORM_FLOPS_PER_ELEM * d            # ln1 + ln2
    per_tok += l * ROPE_FLOPS_PER_ELEM * (hq + hkv) * dh     # rope q, k
    per_tok += l * 2 * d                                     # residuals
    # gated: silu(gate) * up (logistic + 2 muls); plain: tanh-approx gelu.
    act_per_elem = 3.0 if dims.mlp_gated else ACT_FLOPS_PER_ELEM
    per_tok += l * act_per_elem * dims.d_ff
    flops = per_tok * tokens
    flops += RMSNORM_FLOPS_PER_ELEM * d * logit_tokens       # final norm
    nbytes = 2.0 * tokens * d * act_itemsize                 # embed gather
    nbytes += 2.0 * l * 4 * tokens * d * act_itemsize        # norm/res/act
    return OpCost(flops, nbytes)


def decode_step_costs(
    dims: ModelDims,
    *,
    batch: int,
    context: int,
    page_size: int,
    attn_backend: str = "gather",
    weight_bits: int = 0,
    kv_bits: int = 0,
    act_itemsize: int = 4,
    specs: Optional[Sequence[LinearSpec]] = None,
) -> Dict[str, OpCost]:
    """Op → cost table for ONE paged decode step over ``batch`` lanes.

    ``context`` is the PADDED logical view length each lane attends —
    ``max_blocks * page_size`` in the engine.  ``specs`` defaults to
    :func:`specs_from_dims`; pass :func:`linear_specs` of the live param
    tree to price the actual (possibly packed) weights.
    """
    specs = _with_lm_head(dims, specs, weight_bits)
    padded = max(1, math.ceil(context / page_size)) * page_size
    out: Dict[str, OpCost] = {}
    for s in specs:
        c = gemv_cost(s, tokens=batch, act_itemsize=act_itemsize)
        out[s.key] = out.get(s.key, OpCost()) + c.scaled(s.stack)
    out["attn_decode"] = OpCost(
        dims.n_layers * decode_attn_flops(
            batch=batch, context=padded, n_q_heads=dims.n_q_heads,
            head_dim=dims.head_dim, kv_bits=kv_bits),
        dims.n_layers * decode_attn_bytes(
            attn_backend, batch=batch, context=padded,
            n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
            n_q_heads=dims.n_q_heads, page_size=page_size,
            kv_bits=kv_bits, act_itemsize=act_itemsize),
    )
    out["kv_write"] = OpCost(
        kv_write_flops(tokens=batch, n_layers=dims.n_layers,
                       n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
                       kv_bits=kv_bits),
        kv_write_bytes(tokens=batch, n_layers=dims.n_layers,
                       n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
                       kv_bits=kv_bits, act_itemsize=act_itemsize),
    )
    out["other"] = _other_decode(dims, batch, batch, act_itemsize)
    return out


def prefill_chunk_costs(
    dims: ModelDims,
    *,
    batch: int,
    chunk: int,
    context: int,
    page_size: int,
    attn_backend: str = "gather",
    weight_bits: int = 0,
    kv_bits: int = 0,
    act_itemsize: int = 4,
    specs: Optional[Sequence[LinearSpec]] = None,
) -> Dict[str, OpCost]:
    """Op → cost table for ONE chunked-prefill step (``chunk`` tokens per
    lane).  The LM head runs on the last token only (``prefill_chunk``
    computes logits for one position per lane)."""
    specs = _with_lm_head(dims, specs, weight_bits)
    padded = max(1, math.ceil(context / page_size)) * page_size
    tokens = batch * chunk
    out: Dict[str, OpCost] = {}
    for s in specs:
        t = batch if s.name.endswith("lm_head") else tokens
        c = gemv_cost(s, tokens=t, act_itemsize=act_itemsize)
        out[s.key] = out.get(s.key, OpCost()) + c.scaled(s.stack)
    out["attn_prefill"] = OpCost(
        dims.n_layers * prefill_attn_flops(
            batch=batch, chunk=chunk, context=padded,
            n_q_heads=dims.n_q_heads, head_dim=dims.head_dim,
            kv_bits=kv_bits),
        dims.n_layers * prefill_attn_bytes(
            attn_backend, batch=batch, chunk=chunk, context=padded,
            n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
            n_q_heads=dims.n_q_heads, page_size=page_size,
            kv_bits=kv_bits, act_itemsize=act_itemsize),
    )
    out["kv_write"] = OpCost(
        kv_write_flops(tokens=tokens, n_layers=dims.n_layers,
                       n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
                       kv_bits=kv_bits),
        kv_write_bytes(tokens=tokens, n_layers=dims.n_layers,
                       n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
                       kv_bits=kv_bits, act_itemsize=act_itemsize),
    )
    out["other"] = _other_decode(dims, tokens, batch, act_itemsize)
    return out


def fork_cost(
    dims: ModelDims,
    *,
    page_size: int,
    kv_bits: int = 0,
    act_itemsize: int = 4,
) -> Dict[str, OpCost]:
    """Op table for one prefix-cache COW tail-page fork (pure copies)."""
    return {"cow_fork": OpCost(0.0, fork_bytes(
        n_layers=dims.n_layers, page_size=page_size,
        n_kv_heads=dims.n_kv_heads, head_dim=dims.head_dim,
        kv_bits=kv_bits, act_itemsize=act_itemsize))}


# ---------------------------------------------------------------------------
# the ledger
# ---------------------------------------------------------------------------

Rid = Union[int, str]


class CostLedger:
    """Per-op + per-request FLOPs/bytes accumulation for one engine.

    ``charge(op_costs, rids)`` adds a step's op table to the per-op
    totals and attributes the step total evenly across the charged
    requests.  ``mark_retry(rid)`` snapshots everything charged to a
    request so far as *wasted* — a retried request replays its prompt and
    emitted tokens from scratch, so all prior work is re-done
    (``wasted_*`` monotonically tracks the last restart point).  Request
    rows are bounded FIFO; evicted rows stay in the op totals.
    """

    def __init__(self, max_requests: int = 4096):
        self.max_requests = int(max_requests)
        self.by_op: Dict[str, List[float]] = {}
        self.by_request: "OrderedDict[Rid, Dict[str, float]]" = OrderedDict()
        self.evicted_requests = 0

    # ------------------------------------------------------------------
    def _row(self, rid: Rid) -> Dict[str, float]:
        row = self.by_request.get(rid)
        if row is None:
            row = {"flops": 0.0, "bytes": 0.0,
                   "wasted_flops": 0.0, "wasted_bytes": 0.0,
                   "retries": 0}
            self.by_request[rid] = row
            while len(self.by_request) > self.max_requests:
                self.by_request.popitem(last=False)
                self.evicted_requests += 1
        return row

    def charge(
        self,
        op_costs: Dict[str, OpCost],
        rids: Iterable[Rid] = (),
    ) -> None:
        tot_f = tot_b = 0.0
        for op, c in op_costs.items():
            cur = self.by_op.setdefault(op, [0.0, 0.0])
            cur[0] += c.flops
            cur[1] += c.bytes
            tot_f += c.flops
            tot_b += c.bytes
        rids = list(rids)
        if rids:
            share_f = tot_f / len(rids)
            share_b = tot_b / len(rids)
            for rid in rids:
                row = self._row(rid)
                row["flops"] += share_f
                row["bytes"] += share_b

    def mark_retry(self, rid: Rid) -> None:
        row = self._row(rid)
        row["wasted_flops"] = row["flops"]
        row["wasted_bytes"] = row["bytes"]
        row["retries"] += 1

    # ------------------------------------------------------------------
    @property
    def total_flops(self) -> float:
        return sum(v[0] for v in self.by_op.values())

    @property
    def total_bytes(self) -> float:
        return sum(v[1] for v in self.by_op.values())

    def request(self, rid: Rid) -> Optional[Dict[str, float]]:
        row = self.by_request.get(rid)
        return dict(row) if row is not None else None

    def snapshot(self) -> Dict[str, Any]:
        return {
            "total_flops": self.total_flops,
            "total_bytes": self.total_bytes,
            "wasted_flops": sum(r["wasted_flops"]
                                for r in self.by_request.values()),
            "wasted_bytes": sum(r["wasted_bytes"]
                                for r in self.by_request.values()),
            "by_op": {op: {"flops": v[0], "bytes": v[1]}
                      for op, v in sorted(self.by_op.items())},
            "requests": {str(rid): dict(row)
                         for rid, row in self.by_request.items()},
            "evicted_requests": self.evicted_requests,
        }
