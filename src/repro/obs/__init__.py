"""Serve-path observability: metrics, span timelines, step tracing.

The package has one process-wide switch, :data:`enabled`.  Components
that want instrumentation call :func:`telemetry` at construction time:
with the switch off (the default) they get the shared no-op
:data:`~repro.obs.telemetry.NULL_TELEMETRY` and the serve path stays a
true zero — no clocks read, no state allocated.  With the switch on
they get a live :class:`~repro.obs.telemetry.Telemetry` carrying a
:class:`~repro.obs.registry.MetricsRegistry`, per-request
:class:`~repro.obs.spans.RequestTimeline` records and (optionally) a
:class:`~repro.obs.trace.ChromeTracer`.

Typical use::

    import repro.obs as obs

    obs.enable()                     # metrics + timelines + Chrome trace
    eng = ServeEngine(...)           # picks up a live telemetry
    eng.run()
    print(eng.metrics()["obs"])      # structured snapshot
    eng.obs.export_chrome_trace("trace.json")   # load in Perfetto
    obs.disable()

``enable(trace=False)`` keeps metrics/timelines but skips trace-event
collection; ``enable(jax_annotations=True)`` additionally wraps the
prefill/decode dispatches in ``jax.profiler.TraceAnnotation`` scopes so
host spans line up with an XLA device profile.  See
``docs/observability.md``.
"""

from __future__ import annotations

from typing import Optional

from repro.obs import clock
from repro.obs import costs  # noqa: F401  (public surface)
from repro.obs.costs import (  # noqa: F401
    CostLedger,
    LinearSpec,
    ModelDims,
    OpCost,
    decode_step_costs,
    fork_cost,
    gemv_cost,
    linear_specs,
    model_dims,
    prefill_chunk_costs,
    specs_from_dims,
    total_cost,
)
from repro.obs.registry import (  # noqa: F401  (public surface)
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.spans import RequestTimeline  # noqa: F401
from repro.obs.telemetry import (  # noqa: F401
    NULL_TELEMETRY,
    NullTelemetry,
    Telemetry,
)
from repro.obs.trace import ChromeTracer, validate_trace  # noqa: F401

# process-wide switch + the options enable() captured
enabled = False
_trace = True
_jax_annotations = False
_global_registry: Optional[MetricsRegistry] = None


def enable(trace: bool = True, jax_annotations: bool = False) -> None:
    """Turn instrumentation on for subsequently built components."""
    global enabled, _trace, _jax_annotations
    enabled = True
    _trace = trace
    _jax_annotations = jax_annotations


def disable() -> None:
    """Back to the no-op path for subsequently built components."""
    global enabled
    enabled = False


def telemetry(clock_fn=None):
    """The telemetry for a component built *now*: live iff enabled."""
    if not enabled:
        return NULL_TELEMETRY
    return Telemetry(clock_fn, trace=_trace,
                     jax_annotations=_jax_annotations)


def global_registry() -> MetricsRegistry:
    """A process-wide registry for code with no engine in hand (the
    bench timer helpers feed this).  Created lazily; survives
    enable()/disable() flips so accumulated bench walls persist."""
    global _global_registry
    if _global_registry is None:
        _global_registry = MetricsRegistry()
    return _global_registry
