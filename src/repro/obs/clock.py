"""The serve-path clock: the only place ``time.perf_counter`` may live.

Everything under ``repro.serve`` reads time through :func:`now` (or an
injected callable defaulting to it) — CI greps the serve package for raw
``perf_counter`` calls.  Centralizing the clock keeps every timestamp in
the stack (request TTFT, span timelines, Chrome-trace ``ts`` fields) on
one monotonic timebase, and makes the whole serving layer testable with
a manual clock: inject a fake ``clock`` into ``ServeEngine`` /
``ServeFrontend`` / ``Telemetry`` and time only moves when the test says
so.
"""

from __future__ import annotations

import time


def now() -> float:
    """Monotonic seconds (the process-wide serve-path timebase)."""
    return time.perf_counter()


def sleep(seconds: float) -> None:
    """The one legal sleep on timed paths (benchmarks, retry backoff):
    hand-rolled ``time.sleep`` next to hand-rolled timestamps is how
    wall-clock reads sneak back in, so both ride this module."""
    if seconds > 0:
        time.sleep(seconds)
